"""Live width-swap subsystem: WidthPlans applied to real params.

The equivalence contract: slicing a layer to a planned width must equal
running the full model with the dropped channels zeroed — channel for
channel, over random plans (property-tested), for both FFN hidden dims
and attention heads (MHA and GQA).  Swapping is lossless (the canonical
tree is retained; the full-width plan returns it bit for bit) and warm
swaps to an already-seen plan come from the plan cache with zero new
array allocations (leaf identity, pinned here via ``SwapEvent``).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config, reduced_config
from repro.core import TPU_V5E, ModuleRef, snap_heads
from repro.models import (
    decoder_layer_refs, forward, init_decode_state, init_params,
)
from repro.serving import (
    TrafficClass, WidthPlan, WidthSwapper, serving_templates,
)

pytestmark = pytest.mark.swap

HW = TPU_V5E


def make_cfg(arch="qwen1.5-0.5b", **kw):
    kw.setdefault("d_model", 32)
    kw.setdefault("n_layers", 3)
    kw.setdefault("n_heads", 4)
    kw.setdefault("d_ff", 48)
    kw.setdefault("vocab", 64)
    return reduced_config(get_config(arch), **kw)


def make_plan(widths, modules, name="t", tokens=256):
    return WidthPlan(traffic=TrafficClass(name, tokens), widths=widths,
                     latency_s=1.0, baseline_latency_s=2.0,
                     satisfied=True, modules=modules)


def fwd(params, cfg, toks):
    # disable_jit turns the layer scan into a Python loop: no XLA
    # compile per sliced shape set, which keeps the property test in
    # the quick tier.
    with jax.disable_jit():
        logits, _, _ = forward(params, cfg, tokens=toks, mode="prefill")
    return np.asarray(logits.astype(jnp.float32))


@pytest.fixture(scope="module")
def mha():
    cfg = make_cfg()
    assert cfg.n_kv_heads == cfg.n_heads  # the MHA case
    params = init_params(jax.random.PRNGKey(0), cfg)
    _, modules = serving_templates(cfg, HW, tokens=256,
                                   sites=("mlp", "attn"))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(1, 6)).astype(np.int32))
    return cfg, params, modules, toks


@pytest.fixture(scope="module")
def gqa():
    cfg = make_cfg("deepseek-7b", n_heads=4)
    if cfg.n_kv_heads == cfg.n_heads:  # force a GQA ratio if needed
        cfg = dataclasses.replace(cfg, n_kv_heads=cfg.n_heads // 2)
    params = init_params(jax.random.PRNGKey(1), cfg)
    _, modules = serving_templates(cfg, HW, tokens=256,
                                   sites=("mlp", "attn"))
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(1, 6)).astype(np.int32))
    return cfg, params, modules, toks


# ---------------------------------------------------------------------------
# the equivalence property
# ---------------------------------------------------------------------------
class TestSlicedEqualsZeroed:
    """Sliced-params forward == full-params forward with the dropped
    channels zeroed, for random plans (the tentpole's contract)."""

    def test_fixed_plan(self, mha):
        """One deterministic mixed plan — the quick sanity anchor for
        the property below."""
        cfg, params, modules, toks = mha
        sw = WidthSwapper(params, cfg)
        widths = {"mlp0": cfg.d_ff // 3, "mlp2": cfg.d_ff // 2,
                  "attn0": cfg.head_dim, "attn1": 3 * cfg.head_dim}
        mlp_w, heads = sw.realize(widths, modules)
        sliced = sw.materialize(mlp_w, heads)
        zeroed = sw.materialize(mlp_w, heads, pad_to_full=True)
        np.testing.assert_allclose(fwd(sliced, cfg, toks),
                                   fwd(zeroed, cfg, toks),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_random_plans_mha(self, mha, seed):
        self._check(mha, seed)

    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(0, 10**6))
    def test_random_plans_gqa(self, gqa, seed):
        self._check(gqa, seed)

    def _check(self, fixture, seed):
        cfg, params, modules, toks = fixture
        rng = np.random.default_rng(seed)
        widths = {}
        for name, ref in modules.items():
            if rng.random() < 0.3:
                continue  # unplanned layers keep canonical width
            if ref.site == "mlp":
                widths[name] = int(rng.integers(1, cfg.d_ff + 1))
            else:
                widths[name] = int(rng.integers(
                    1, cfg.n_heads * cfg.head_dim + 1))
        sw = WidthSwapper(params, cfg)
        mlp_w, heads = sw.realize(widths, modules)
        sliced = sw.materialize(mlp_w, heads)
        zeroed = sw.materialize(mlp_w, heads, pad_to_full=True)
        np.testing.assert_allclose(fwd(sliced, cfg, toks),
                                   fwd(zeroed, cfg, toks),
                                   rtol=1e-5, atol=1e-5)

    def test_single_unit_stack_and_extra_layers(self):
        """recurrentgemma's 3-layer cycle at n_layers=4: the stack has
        ONE unit (leading axis of size 1 — the group type, not the lid
        count, decides the stacked layout) plus a leftover 'extra'
        layer; both must slice correctly."""
        cfg = make_cfg("recurrentgemma-2b", n_layers=4)
        assert cfg.n_layers % len(cfg.block_pattern) != 0
        params = init_params(jax.random.PRNGKey(2), cfg)
        assert "extra" in params["decoder"]
        _, modules = serving_templates(cfg, HW, sites=("mlp", "attn"))
        toks = jnp.asarray(np.random.default_rng(2).integers(
            0, cfg.vocab_size, size=(1, 6)).astype(np.int32))
        sw = WidthSwapper(params, cfg)
        widths = {name: (cfg.d_ff // 2 if ref.site == "mlp"
                         else cfg.head_dim)
                  for name, ref in modules.items()}
        mlp_w, heads = sw.realize(widths, modules)
        sliced = sw.materialize(mlp_w, heads)
        zeroed = sw.materialize(mlp_w, heads, pad_to_full=True)
        np.testing.assert_allclose(fwd(sliced, cfg, toks),
                                   fwd(zeroed, cfg, toks),
                                   rtol=1e-5, atol=1e-5)

    def test_realized_widths_respect_snapping(self, mha):
        cfg, params, modules, _ = mha
        sw = WidthSwapper(params, cfg)
        widths = {"attn0": cfg.head_dim + 1, "mlp1": 10**9, "mlp2": -5}
        mlp_w, heads = sw.realize(widths, modules)
        assert heads[0] == snap_heads(cfg.head_dim + 1, cfg.head_dim,
                                      cfg.n_heads, cfg.n_kv_heads)
        assert mlp_w[1] == cfg.d_ff     # clamped to canonical
        assert mlp_w[2] == 1            # floor


# ---------------------------------------------------------------------------
# round-trips and the plan cache
# ---------------------------------------------------------------------------
class TestSwapRoundTrip:
    def test_swap_back_bit_for_bit(self, mha):
        """Down-swap then full-width swap returns the canonical pytree
        itself: identical leaf objects, hence bit-for-bit."""
        cfg, params, modules, _ = mha
        sw = WidthSwapper(params, cfg)
        down = make_plan({"mlp0": cfg.d_ff // 2, "attn1": cfg.head_dim},
                         modules, "down")
        narrow, ev = sw.apply(down)
        assert not ev.cache_hit
        assert narrow is not params
        back, _ = sw.apply(make_plan({}, modules, "full"))
        assert back is params
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(params)):
            assert a is b

    def test_warm_swap_is_allocation_free(self, mha):
        """A second swap to an already-seen plan is a cache hit — the
        SAME pytree object, zero new array allocations — and swap_log
        records it."""
        cfg, params, modules, _ = mha
        sw = WidthSwapper(params, cfg)
        plan = make_plan({"mlp0": cfg.d_ff // 2}, modules)
        cold, ev_cold = sw.apply(plan)
        warm, ev_warm = sw.apply(plan)
        assert not ev_cold.cache_hit and ev_warm.cache_hit
        assert warm is cold
        for a, b in zip(jax.tree.leaves(cold), jax.tree.leaves(warm)):
            assert a is b
        # equal realized widths from a *different* plan share the entry
        again, ev3 = sw.apply(make_plan({"mlp0": cfg.d_ff // 2},
                                        modules, "other"))
        assert ev3.cache_hit and again is cold

    def test_plan_cache_is_lru_bounded(self, mha):
        cfg, params, modules, _ = mha
        sw = WidthSwapper(params, cfg, max_plans=1)
        a = make_plan({"mlp0": cfg.d_ff // 2}, modules, "a")
        b = make_plan({"mlp1": cfg.d_ff // 2}, modules, "b")
        sw.apply(a)
        sw.apply(b)                      # evicts a
        _, ev = sw.apply(a)
        assert not ev.cache_hit          # a was rebuilt

    def test_plan_without_modules_raises(self, mha):
        cfg, params, _, _ = mha
        sw = WidthSwapper(params, cfg)
        with pytest.raises(ValueError, match="module mapping"):
            sw.apply(make_plan({"mlp0": 32}, None))

    def test_unknown_name_and_wrong_site_raise(self, mha):
        cfg, params, modules, _ = mha
        sw = WidthSwapper(params, cfg)
        with pytest.raises(ValueError, match="no address"):
            sw.realize({"nope": 8}, modules)
        with pytest.raises(ValueError, match="decoder layers"):
            sw.realize({"far": 8}, {"far": ModuleRef(99, "mlp")})


# ---------------------------------------------------------------------------
# transactional swaps: rollback from injected failures
# ---------------------------------------------------------------------------
class TestGuardedSwap:
    """Property: a failure injected at EVERY possible swap step leaves
    the live tree bit-for-bit the canonical tree, and a subsequent clean
    swap succeeds (the tentpole's transactional contract)."""

    def test_rollback_at_every_step(self, mha):
        from repro.serving import SWAP_STEPS
        from repro.serving.chaos import SwapFailureInjector

        cfg, params, modules, toks = mha
        pristine = [np.asarray(leaf).copy()
                    for leaf in jax.tree.leaves(params)]
        widths = {"mlp0": cfg.d_ff // 2, "attn1": cfg.head_dim}
        for step in SWAP_STEPS:
            sw = WidthSwapper(
                params, cfg,
                fault_hook=SwapFailureInjector(1.0, steps=(step,)))
            live, ev = sw.apply_guarded(make_plan(widths, modules))
            assert ev.outcome == "rolled_back", step
            assert "InjectedFault" in ev.error
            # the live tree IS the canonical object, and the canonical
            # tree is bit-for-bit untouched by the failed swap
            assert live is params
            for leaf, ref in zip(jax.tree.leaves(live), pristine):
                np.testing.assert_array_equal(np.asarray(leaf), ref)
            # the plan cache never holds a partially built tree: entries
            # are only written after materialization completes
            if step in ("begin", "realize", "materialize", "commit"):
                assert not sw._cache, step
            # a subsequent clean swap succeeds and realizes the widths
            sw.fault_hook = None
            ok_params, ok_ev = sw.apply_guarded(make_plan(widths, modules))
            assert ok_ev.outcome == "ok", step
            realized = dict(ok_ev.realized)
            assert realized["mlp0"] == cfg.d_ff // 2
            assert ok_params is not params

    def test_guard_is_transparent_on_success(self, mha):
        """Without faults, apply_guarded == apply (same tree objects,
        same event contents, cache behavior preserved)."""
        cfg, params, modules, _ = mha
        sw = WidthSwapper(params, cfg)
        plan = make_plan({"mlp0": cfg.d_ff // 2}, modules)
        cold, ev_cold = sw.apply_guarded(plan)
        warm, ev_warm = sw.apply_guarded(plan)
        assert ev_cold.outcome == ev_warm.outcome == "ok"
        assert not ev_cold.cache_hit and ev_warm.cache_hit
        assert warm is cold

    def test_guard_still_raises_on_missing_modules(self, mha):
        """A plan without a module mapping is a caller bug, not a
        runtime fault: the guard must not swallow it."""
        cfg, params, _, _ = mha
        sw = WidthSwapper(params, cfg)
        with pytest.raises(ValueError, match="module mapping"):
            sw.apply_guarded(make_plan({"mlp0": 32}, None))


# ---------------------------------------------------------------------------
# templates and addressing
# ---------------------------------------------------------------------------
class TestServingTemplates:
    def test_matched_pair(self, mha):
        cfg, _, _, _ = mha
        templates, modules = serving_templates(cfg, HW, tokens=128,
                                               sites=("mlp", "attn"))
        assert {t.layer.name for t in templates} == set(modules)
        for t in templates:
            ref = modules[t.layer.name]
            full = cfg.d_ff if ref.site == "mlp" \
                else cfg.n_heads * cfg.head_dim
            assert t.layer.width == full
            assert t.candidates.max() <= full  # slice-only, never wider
            assert t.candidates.size > 0

    @pytest.mark.parametrize("fixture_name", ["mha", "gqa"])
    def test_attn_candidates_on_realizable_grid(self, fixture_name,
                                                request):
        """Attention candidates are generated on the realizable grid
        (whole GQA head groups): snap_heads is the identity on every
        candidate, so ladder/planner widths materialize as planned with
        no swap-time re-snap (the ROADMAP head-quantum mismatch)."""
        cfg, _, _, _ = request.getfixturevalue(fixture_name)
        templates, modules = serving_templates(cfg, HW, tokens=128,
                                               sites=("mlp", "attn"))
        g = cfg.n_heads // max(cfg.n_kv_heads, 1)
        q = g * cfg.head_dim
        for t in templates:
            if modules[t.layer.name].site != "attn":
                continue
            assert (t.candidates % q == 0).all()
            assert t.candidates.max() == cfg.n_heads * cfg.head_dim
            for c in t.candidates.tolist():
                snapped = snap_heads(c, cfg.head_dim, cfg.n_heads,
                                     cfg.n_kv_heads) * cfg.head_dim
                assert snapped == c

    def test_non_dense_layers_skipped(self):
        cfg = make_cfg("recurrentgemma-2b")   # rglru/rglru/local pattern
        templates, modules = serving_templates(cfg, HW,
                                               sites=("mlp", "attn"))
        kinds = [r["kind"] for r in decoder_layer_refs(cfg)]
        n_attn = sum(k in ("attn", "local") for k in kinds)
        assert sum(r.site == "attn" for r in modules.values()) == n_attn
        assert all(ref.site in ("mlp", "attn")
                   for ref in modules.values())

    def test_refs_cover_every_layer_in_order(self, mha):
        cfg, params, _, _ = mha
        refs = decoder_layer_refs(cfg)
        assert len(refs) == cfg.n_layers
        stacked = [r for r in refs if r["group"] == "stack"]
        assert [r["index"] for r in stacked] == sorted(
            r["index"] for r in stacked)
        for r in refs:  # every address resolves into the real pytree
            group = params["decoder"][r["group"]]
            assert r["key"] in group


# ---------------------------------------------------------------------------
# KV state re-shaping at the boundary
# ---------------------------------------------------------------------------
class TestReshapeStates:
    def _random_states(self, cfg, b=2, max_len=16, seed=0):
        states = init_decode_state(cfg, b, max_len)
        rng = np.random.default_rng(seed)
        return jax.tree.map(
            lambda x: jnp.asarray(
                rng.standard_normal(x.shape).astype(np.float32)
            ).astype(x.dtype), states)

    def test_shrink_slices_grow_zero_fills(self, mha):
        cfg, params, modules, _ = mha
        sw = WidthSwapper(params, cfg)
        full = np.full(cfg.n_layers, cfg.n_heads, np.int64)
        half = np.maximum(full // 2, 1)
        states = self._random_states(cfg)

        down = sw.reshape_states(states, full, half)
        kv = cfg.n_kv_heads // 2
        for leafname in ("k", "v"):
            src = states["stack"]["u0"][leafname]
            dst = down["stack"]["u0"][leafname]
            assert dst.shape[-2] == kv
            np.testing.assert_array_equal(np.asarray(dst),
                                          np.asarray(src[..., :kv, :]))
        back = sw.reshape_states(down, half, full)
        for leafname in ("k", "v"):
            src = states["stack"]["u0"][leafname]
            dst = back["stack"]["u0"][leafname]
            assert dst.shape == src.shape
            np.testing.assert_array_equal(
                np.asarray(dst[..., :kv, :]), np.asarray(src[..., :kv, :]))
            assert not np.asarray(dst[..., kv:, :]).any()  # fresh heads

    def test_noop_when_heads_unchanged(self, mha):
        cfg, params, _, _ = mha
        sw = WidthSwapper(params, cfg)
        full = np.full(cfg.n_layers, cfg.n_heads, np.int64)
        states = self._random_states(cfg)
        same = sw.reshape_states(states, full, full)
        for a, b in zip(jax.tree.leaves(same), jax.tree.leaves(states)):
            assert a is b
        assert sw.reshape_states(None, full, full) is None
