"""Preemption fault-tolerance: SIGTERM mid-run -> clean checkpoint ->
resumed run completes with no lost steps."""

import os
import signal
import subprocess
import sys
import time

from repro.train import checkpoint
import pytest

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow


def _launch(ckpt_dir: str, steps: int):
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "qwen1.5-0.5b", "--reduced", "--d-model", "32",
         "--n-layers", "2", "--steps", str(steps), "--batch", "2",
         "--seq", "32", "--ckpt-dir", ckpt_dir, "--ckpt-every", "5",
         "--log-every", "5"],
        env={"PYTHONPATH": "src", "PATH": os.environ.get("PATH", ""),
             "HOME": os.environ.get("HOME", "/root")},
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)


def test_sigterm_checkpoints_and_resumes(tmp_path):
    ckpt = str(tmp_path)
    proc = _launch(ckpt, steps=2000)   # would run ~forever
    # wait for training to actually start making progress
    deadline = time.time() + 300
    while time.time() < deadline:
        if checkpoint.latest_step(ckpt):
            break
        time.sleep(1.0)
    assert checkpoint.latest_step(ckpt), "no checkpoint before preemption"
    proc.send_signal(signal.SIGTERM)
    out, _ = proc.communicate(timeout=180)
    assert proc.returncode == 0, out
    assert "preempted at step" in out, out[-800:]
    step = checkpoint.latest_step(ckpt)
    assert step and step >= 5

    # relaunch: resumes from the preemption checkpoint and finishes
    proc2 = _launch(ckpt, steps=step + 5)
    out2, _ = proc2.communicate(timeout=300)
    assert proc2.returncode == 0, out2
    assert f"resumed from step {step}" in out2, out2[-800:]
    assert "final loss" in out2
