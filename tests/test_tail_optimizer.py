"""Algorithm 2 behaviour — paper §4.3."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st


from repro.core import (
    LayerShape, TPU_V5E, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates, discretize_pruning_space,
    snap_down, snap_nearest, snap_up, tunable_from_profile,
)
from repro.core.profiler import analytic_profile

HW = TPU_V5E
MODEL = WaveQuantizationModel(HW)
OPT = TailEffectOptimizer(MODEL)


def make_tl(width, shard=16, tokens=4096, d_in=4096, name="l"):
    layer = LayerShape(name, tokens=tokens, d_in=d_in, width=width,
                       shard_out=shard)
    cands = analytic_candidates(HW, layer, max_width=int(width * 1.6))
    return TunableLayer(layer=layer, candidates=cands,
                        params_per_unit=d_in)


@st.composite
def layer_sets(draw):
    n = draw(st.integers(2, 8))
    widths = [draw(st.integers(1024, 16384)) for _ in range(n)]
    return [make_tl(w, name=f"L{i}") for i, w in enumerate(widths)]


class TestLatencyOriented:
    @given(layers=layer_sets(), tau_frac=st.floats(0.01, 0.2))
    @settings(max_examples=25, deadline=None)
    def test_never_increases_latency(self, layers, tau_frac):
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        res = OPT.optimize_latency(layers, tau=tau_frac * total_p,
                                   delta=0.95)
        assert res.latency_new_s <= res.latency_old_s + 1e-15

    @given(layers=layer_sets())
    @settings(max_examples=25, deadline=None)
    def test_param_gain_bounded_by_final_tau(self, layers):
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        tau = 0.05 * total_p
        res = OPT.optimize_latency(layers, tau=tau, delta=0.9)
        # Eq. 7: |PG| stays within the (possibly loosened) tau window, up
        # to one quantum step of slack (a single balancing move that
        # improves |PG| may land past the far edge of the window).
        q_step = max(MODEL.width_quantum(tl.layer.shard_out)
                     * tl.params_per_unit for tl in layers)
        assert abs(res.param_gain) < res.tau_final + q_step + 1e-9

    def test_misaligned_layers_gain(self):
        """Layers just above a wave edge give near-free latency wins."""
        layers = [make_tl(2048 * k + 256, name=f"L{k}") for k in
                  range(2, 6)]
        res = OPT.optimize_latency(layers, tau=1e9, delta=0.95)
        assert res.latency_reduction > 0.05

    def test_aligned_layers_constraint_respected(self):
        """At wave-aligned widths there is no FREE gain: any latency win
        must spend a full wave of parameters, and Eq. 7 keeps the total
        parameter change inside (-tau, tau)."""
        layers = [make_tl(2048 * k, name=f"L{k}") for k in range(2, 6)]
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        tau = 0.05 * total_p
        res = OPT.optimize_latency(layers, tau=tau, delta=0.99999)
        assert res.latency_new_s <= res.latency_old_s
        assert -res.tau_final < res.param_gain < res.tau_final
        for mv in res.moves:
            if mv.kind == "down":
                assert mv.latency_gain_s > 0   # no pointless moves


class TestAccuracyOriented:
    @given(layers=layer_sets())
    @settings(max_examples=25, deadline=None)
    def test_free_capacity(self, layers):
        """Eq. 6: params grow, latency never grows (slack=0)."""
        res = OPT.optimize_accuracy(layers, latency_slack=0.0)
        assert res.latency_new_s <= res.latency_old_s + 1e-15
        assert res.param_gain >= 0

    def test_fills_wave(self):
        layers = [make_tl(11008)]   # deepseek d_ff at TP16: 5.375 waves
        res = OPT.optimize_accuracy(layers)
        assert res.new_widths["l"] == 12288   # right edge of wave 6
        assert res.latency_new_s == pytest.approx(res.latency_old_s)

    def test_slack_buys_wave_jumps(self):
        layers = [make_tl(2048 * 4, name=f"L{k}") for k in range(3)]
        res0 = OPT.optimize_accuracy(layers, latency_slack=0.0)
        res1 = OPT.optimize_accuracy(layers, latency_slack=0.3)
        assert res1.param_gain > res0.param_gain


class TestMeasuredTables:
    """Algorithm 2 over measured LayerProfile tables (the paper's nvprof
    flow): the optimizer only reads latency/params arrays, so feeding it
    a profile that matches the analytic model must reproduce the analytic
    results with ZERO model sweeps."""

    def _measured_layers(self, n=4):
        analytic, measured = [], []
        for k in range(n):
            tl = make_tl(2048 * (k + 2) + 256, name=f"L{k}")
            analytic.append(tl)
            widths = np.unique(np.append(tl.candidates, tl.layer.width))
            prof = analytic_profile(HW, tl.layer, widths)
            measured.append(TunableLayer(
                layer=tl.layer, candidates=tl.candidates,
                params_per_unit=tl.params_per_unit, measured=prof))
        return analytic, measured

    def test_latency_mode_matches_analytic(self):
        analytic, measured = self._measured_layers()
        model = WaveQuantizationModel(HW)
        res_m = TailEffectOptimizer(model).optimize_latency(
            measured, tau=1e9, delta=0.95)
        assert model.eval_calls == 0          # never touched the model
        res_a = OPT.optimize_latency(analytic, tau=1e9, delta=0.95)
        assert res_m.new_widths == res_a.new_widths
        assert res_m.moves == res_a.moves

    def test_accuracy_mode_matches_analytic(self):
        analytic, measured = self._measured_layers()
        model = WaveQuantizationModel(HW)
        res_m = TailEffectOptimizer(model).optimize_accuracy(
            measured, latency_slack=0.2)
        assert model.eval_calls == 0
        res_a = OPT.optimize_accuracy(analytic, latency_slack=0.2)
        assert res_m.new_widths == res_a.new_widths

    def test_missing_width_raises(self):
        tl = make_tl(4096 + 256, name="L")
        prof = analytic_profile(HW, tl.layer, tl.candidates)  # no start!
        bad = TunableLayer(layer=tl.layer, candidates=tl.candidates,
                           params_per_unit=tl.params_per_unit,
                           measured=prof)
        with pytest.raises(ValueError, match="missing"):
            OPT.optimize_latency([bad], tau=1e9)

    def test_tunable_from_profile_end_to_end(self):
        """Candidates AND latencies both derived from the profile table
        (paper Eq. 4 then Algorithm 2) — no analytic model involved."""
        shape = LayerShape("L", tokens=4096, d_in=4096, width=11008,
                           shard_out=16)
        q = 16 * HW.lane
        widths = np.unique(np.append(
            np.arange(q // 4, 16384 + 1, q // 4), shape.width))
        prof = analytic_profile(HW, shape, widths)
        tl = tunable_from_profile(shape, prof, params_per_unit=4096)
        assert tl.measured is prof
        model = WaveQuantizationModel(HW)
        res = TailEffectOptimizer(model).optimize_accuracy([tl])
        assert model.eval_calls == 0
        assert res.new_widths["L"] == 12288   # right edge of wave 6
        assert res.latency_new_s == pytest.approx(res.latency_old_s)


class TestSnap:
    @given(width=st.integers(1, 20000))
    @settings(max_examples=50, deadline=None)
    def test_snap_relations(self, width):
        layer = LayerShape("l", 128, 128, width, shard_out=16)
        c = analytic_candidates(HW, layer, max_width=25000)
        up, down = snap_up(c, width), snap_down(c, width)
        if up is not None:
            assert up > width
        if down is not None:
            assert down < width
        near = snap_nearest(c, width)
        assert near in c

    def test_discretize_pruning_space(self):
        layers = [make_tl(8192, name=f"L{i}") for i in range(3)]
        target = {"L0": 3000, "L1": 5000, "L2": 8000}
        snapped = discretize_pruning_space(layers, target)
        for name, w in snapped.items():
            assert w % (16 * HW.lane) == 0
