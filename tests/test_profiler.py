"""Profiler table builders: stacked model-level sweeps and the hlo sweep's
shared compile cache."""

import numpy as np
import pytest

from repro.core import LayerShape, TPU_V5E
from repro.core import profiler

HW = TPU_V5E


def make_layers(n=4):
    return [LayerShape(f"l{i}", tokens=2048, d_in=1024 + 128 * i,
                       width=4096, shard_out=16) for i in range(n)]


class TestAnalyticStack:
    def test_stack_matches_per_layer(self):
        """``analytic_profile_stack`` rows are bit-for-bit the per-layer
        ``analytic_profile`` sweeps."""
        layers = make_layers()
        widths = [np.arange(512, 8193, 512) for _ in layers]
        stacked = profiler.analytic_profile_stack(HW, layers, widths)
        assert len(stacked) == len(layers)
        for layer, w, prof in zip(layers, widths, stacked):
            single = profiler.analytic_profile(HW, layer, w)
            assert prof.name == layer.name and prof.source == "analytic"
            for f in ("widths", "latency_s", "utilization", "throughput",
                      "waves"):
                np.testing.assert_array_equal(
                    getattr(single, f), getattr(prof, f), err_msg=f)

    def test_ragged_width_vectors(self):
        layers = make_layers(3)
        widths = [np.arange(128, 1025, 128), np.array([4096]),
                  np.arange(256, 4097, 256)]
        stacked = profiler.analytic_profile_stack(HW, layers, widths)
        for w, prof in zip(widths, stacked):
            assert len(prof.widths) == len(w)


@pytest.mark.slow
class TestHloProfile:
    def test_widths_length_and_jit_reuse(self):
        """Regression for the per-width ``jax.jit`` rebuild: the sweep
        must return one row per width and reuse ONE module-level jit
        across the whole sweep (and across calls)."""
        layer = LayerShape("l", tokens=64, d_in=64, width=256)
        widths = [64, 128, 256]
        prof = profiler.hlo_profile(HW, layer, widths)
        for f in ("widths", "latency_s", "utilization", "throughput",
                  "waves"):
            assert len(getattr(prof, f)) == len(widths), f
        jit_first = profiler._matmul_jit()
        prof2 = profiler.hlo_profile(HW, layer, widths)
        assert profiler._matmul_jit() is jit_first
        np.testing.assert_array_equal(prof.latency_s, prof2.latency_s)
        assert (prof.throughput > 0).all()
