"""Properties of the wave-quantization (tail-effect) model — paper §3."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st


from repro.core import (
    GridWaveModel, LayerShape, TPU_V5E, TPU_V4, WaveQuantizationModel,
    analytic_candidates, ceil_div, profile_candidates, staircase_edges,
)

HW = TPU_V5E


def make_layer(width=4096, shard=16, tokens=2048, d_in=1024):
    return LayerShape("l", tokens=tokens, d_in=d_in, width=width,
                      shard_out=shard)


class TestStaircase:
    def test_latency_is_staircase(self):
        """L(width) only changes at quantum boundaries (paper Fig. 1/3)."""
        m = WaveQuantizationModel(HW)
        layer = make_layer(shard=4)
        q = m.width_quantum(4)
        widths = np.arange(64, 4 * q + 1, 64)
        lat = [m.evaluate(layer.with_width(int(w))).latency_s
               for w in widths]
        for i in range(1, len(widths)):
            same_wave = ceil_div(int(widths[i]), q) == ceil_div(
                int(widths[i - 1]), q)
            if same_wave:
                assert lat[i] == lat[i - 1], (widths[i - 1], widths[i])

    @given(width=st.integers(1, 50000), shard=st.sampled_from([1, 4, 16]),
           tokens=st.sampled_from([256, 4096]))
    @settings(max_examples=60, deadline=None)
    def test_monotone_nondecreasing(self, width, shard, tokens):
        m = WaveQuantizationModel(HW)
        layer = make_layer(width=width, shard=shard, tokens=tokens)
        p1 = m.evaluate(layer)
        p2 = m.evaluate(layer.with_width(width + 1))
        assert p2.latency_s >= p1.latency_s - 1e-15

    @given(width=st.integers(1, 50000), shard=st.sampled_from([1, 8, 16]))
    @settings(max_examples=60, deadline=None)
    def test_ceil_formula(self, width, shard):
        """waves == ceil(ceil(width/shard) / lane) — paper Eq. 3."""
        m = WaveQuantizationModel(HW)
        layer = make_layer(width=width, shard=shard)
        assert m.waves(layer) == ceil_div(ceil_div(width, shard), HW.lane)

    @given(width=st.integers(1, 50000))
    @settings(max_examples=60, deadline=None)
    def test_utilization_bounds(self, width):
        m = WaveQuantizationModel(HW)
        p = m.evaluate(make_layer(width=width))
        assert 0.0 < p.utilization <= 1.0
        # utilization == 1 requires all three dims tile-aligned
        if width % m.width_quantum(16) == 0:
            assert p.utilization == pytest.approx(1.0)

    def test_padded_at_least_useful(self):
        m = WaveQuantizationModel(HW)
        for w in (1, 100, 2047, 2048, 2049, 11008):
            p = m.evaluate(make_layer(width=w))
            assert p.padded_flops >= p.flops


class TestCandidates:
    def test_analytic_are_quantum_multiples(self):
        layer = make_layer(shard=16)
        c = analytic_candidates(HW, layer, max_width=10000)
        assert (c % (16 * HW.lane) == 0).all()

    @given(shard=st.sampled_from([1, 2, 4, 8, 16]),
           max_w=st.integers(2048, 30000))
    @settings(max_examples=30, deadline=None)
    def test_profile_subset_of_analytic(self, shard, max_w):
        """Eq. 4 argmax(UxT) on profiled tables finds only wave-aligned
        widths.  In the memory-bound plateau latency has no stairs, so the
        profile sees ONE segment there (its right edge is still aligned) —
        profiled candidates are a subset of the analytic quanta, and the
        top candidate always agrees."""
        m = WaveQuantizationModel(HW)
        layer = make_layer(width=max_w, shard=shard)
        q = m.width_quantum(shard)
        widths = np.arange(q // 4, max_w + 1, q // 4)
        w, lat, util, thr = m.staircase_arrays(layer, widths)
        prof = profile_candidates(w, util, thr)
        ana = analytic_candidates(HW, layer, max_width=int(widths[-1]))
        prof_set = set(int(x) for x in prof)
        ana_set = set(int(x) for x in ana)
        # every profiled candidate is wave-aligned, EXCEPT possibly the
        # final-range argmax whose closing edge the sweep never observed
        extra = prof_set - ana_set
        assert extra <= {max(prof_set)}, (sorted(extra), sorted(prof_set))
        assert len(prof) >= 1
        confirmed = [a for a in ana_set if a < max(w)]
        for a in confirmed:
            pass  # confirmed edges are detectable where latency steps

    def test_profile_matches_analytic_compute_bound(self):
        """In the compute-bound regime every wave edge is detectable and
        the profiled set equals the analytic set exactly."""
        m = WaveQuantizationModel(HW)
        layer = LayerShape("l", tokens=65536, d_in=8192, width=16384,
                           shard_out=16)
        q = m.width_quantum(16)
        widths = np.arange(q // 4, 16384 + 1, q // 4)
        w, lat, util, thr = m.staircase_arrays(layer, widths)
        prof = profile_candidates(w, util, thr)
        ana = analytic_candidates(HW, layer, max_width=16384)
        assert set(int(x) for x in prof) == set(int(x) for x in ana)

    def test_edges_from_latency(self):
        m = WaveQuantizationModel(HW)
        layer = make_layer(shard=16)
        widths = np.arange(256, 8193, 256)
        w, lat, _, _ = m.staircase_arrays(layer, widths)
        edges = staircase_edges(w, lat)
        q = m.width_quantum(16)
        interior = edges[:-1]
        assert (interior % q == 0).all()


class TestGridWave:
    """Paper Eq. 3 verbatim on Pallas grids (Fig. 5 verification)."""

    def test_blocks_and_waves(self):
        gw = GridWaveModel(TPU_V4, block_flops=2.0 * 256 * 256 * 512)
        b = gw.blocks_for(1024, 1024, 512, 256, 256, 512)
        assert b == 4 * 4 * 1
        r = gw.evaluate(b)
        assert r.waves == ceil_div(b, TPU_V4.cores_per_chip)
        assert r.latency_s == pytest.approx(r.waves * gw.delta_l)

    @given(m_=st.integers(1, 4096), n=st.integers(1, 4096))
    @settings(max_examples=40, deadline=None)
    def test_ceiling_effect(self, m_, n):
        """A partial last block costs a full block (the tail)."""
        gw = GridWaveModel(HW, block_flops=1e9)
        b1 = gw.blocks_for(m_, n, 512, 256, 256, 512)
        b2 = gw.blocks_for(ceil_div(m_, 256) * 256, ceil_div(n, 256) * 256,
                           512, 256, 256, 512)
        assert b1 == b2   # padding to block edges adds no blocks
