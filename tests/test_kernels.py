"""Pallas kernels vs. pure-jnp oracles — shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 4e-2}


class TestMatmul:
    @pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 384, 512),
                                       (100, 130, 70), (64, 257, 129)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, m, n, k, dtype):
        x, w = rand(1, (m, k), dtype), rand(2, (k, n), dtype)
        out = ops.matmul(x, w, block_m=64, block_n=64, block_k=64,
                         force="pallas_interpret")
        expect = ref.matmul_ref(x, w)
        np.testing.assert_allclose(
            out.astype(jnp.float32), expect.astype(jnp.float32),
            rtol=TOL[dtype], atol=TOL[dtype] * 8)

    def test_grid_blocks_matches_ceil(self):
        from repro.kernels.matmul_tiled import grid_blocks
        assert grid_blocks(100, 130, 70, 64, 64, 64) == 2 * 3 * 2


class TestFlashAttention:
    @pytest.mark.parametrize("mask,window", [("causal", 0), ("none", 0),
                                             ("local", 96)])
    @pytest.mark.parametrize("b,s,h,kv,dh", [(2, 256, 8, 2, 64),
                                             (1, 128, 4, 4, 32),
                                             (2, 128, 4, 1, 64)])
    def test_vs_ref(self, mask, window, b, s, h, kv, dh):
        q = rand(1, (b, s, h, dh), jnp.float32)
        k = rand(2, (b, s, kv, dh), jnp.float32)
        v = rand(3, (b, s, kv, dh), jnp.float32)
        out = ops.flash_attention(q, k, v, mask_kind=mask, window=window,
                                  block_q=64, block_kv=64,
                                  force="pallas_interpret")
        expect = ref.attention_ref(q, k, v, mask_kind=mask, window=window)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q = rand(1, (1, 128, 4, 32), jnp.bfloat16)
        k = rand(2, (1, 128, 2, 32), jnp.bfloat16)
        v = rand(3, (1, 128, 2, 32), jnp.bfloat16)
        out = ops.flash_attention(q, k, v, block_q=64, block_kv=64,
                                  force="pallas_interpret")
        expect = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   expect.astype(jnp.float32),
                                   rtol=5e-2, atol=5e-2)


class TestRGLRU:
    @pytest.mark.parametrize("b,t,w,ct,bw", [(2, 64, 128, 8, 128),
                                             (1, 32, 256, 4, 128),
                                             (3, 16, 128, 16, 64)])
    def test_vs_ref(self, b, t, w, ct, bw):
        a = jax.random.uniform(jax.random.PRNGKey(1), (b, t, w),
                               jnp.float32, 0.3, 0.999)
        x = rand(2, (b, t, w), jnp.float32)
        h0 = rand(3, (b, w), jnp.float32)
        from repro.kernels.rglru import rglru_pallas
        y, h = rglru_pallas(a, x, h0, chunk_t=ct, block_w=bw,
                            interpret=True)
        yr, hr = ref.rglru_ref(a, x, h0)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(h, hr, rtol=1e-5, atol=1e-5)


class TestRWKV6:
    @pytest.mark.parametrize("b,t,h,dh,chunk", [(2, 64, 2, 64, 16),
                                                (1, 32, 4, 32, 32),
                                                (2, 128, 1, 64, 32)])
    def test_vs_ref(self, b, t, h, dh, chunk):
        r = rand(1, (b, t, h, dh), jnp.float32)
        k = rand(2, (b, t, h, dh), jnp.float32)
        v = rand(3, (b, t, h, dh), jnp.float32)
        lw = -jnp.exp(jnp.clip(rand(4, (b, t, h, dh), jnp.float32), -8, 1))
        u = rand(5, (h, dh), jnp.float32) * 0.1
        out = ops.rwkv6(r, k, v, lw, u, chunk=chunk,
                        force="pallas_interpret")
        expect = ref.rwkv6_ref(r, k, v, lw, u)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


class TestMoeGMM:
    @pytest.mark.parametrize("e,c,d,f", [(4, 128, 256, 128),
                                         (2, 256, 128, 256),
                                         (8, 128, 128, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, e, c, d, f, dtype):
        x = rand(1, (e, c, d), dtype)
        w = rand(2, (e, d, f), dtype)
        out = ops.moe_gmm(x, w, force="pallas_interpret")
        expect = ref.moe_gmm_ref(x, w)
        np.testing.assert_allclose(
            out.astype(jnp.float32), expect.astype(jnp.float32),
            rtol=TOL[dtype], atol=TOL[dtype] * 8)
