"""Pallas kernels vs. pure-jnp oracles — shape/dtype sweeps, interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# full XLA compiles: quick tier skips with -m "not slow"; the kernels CI
# tier runs this file (plus the staircase differential + autotuner
# suites) with -m kernels.
pytestmark = [pytest.mark.slow, pytest.mark.kernels]

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) \
        .astype(dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 4e-2}


class TestMatmul:
    @pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 384, 512),
                                       (100, 130, 70), (64, 257, 129)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, m, n, k, dtype):
        x, w = rand(1, (m, k), dtype), rand(2, (k, n), dtype)
        out = ops.matmul(x, w, block_m=64, block_n=64, block_k=64,
                         force="pallas_interpret")
        expect = ref.matmul_ref(x, w)
        np.testing.assert_allclose(
            out.astype(jnp.float32), expect.astype(jnp.float32),
            rtol=TOL[dtype], atol=TOL[dtype] * 8)

    def test_grid_blocks_matches_ceil(self):
        from repro.kernels.matmul_tiled import grid_blocks
        assert grid_blocks(100, 130, 70, 64, 64, 64) == 2 * 3 * 2


class TestFlashAttention:
    @pytest.mark.parametrize("mask,window", [("causal", 0), ("none", 0),
                                             ("local", 96)])
    @pytest.mark.parametrize("b,s,h,kv,dh", [(2, 256, 8, 2, 64),
                                             (1, 128, 4, 4, 32),
                                             (2, 128, 4, 1, 64)])
    def test_vs_ref(self, mask, window, b, s, h, kv, dh):
        q = rand(1, (b, s, h, dh), jnp.float32)
        k = rand(2, (b, s, kv, dh), jnp.float32)
        v = rand(3, (b, s, kv, dh), jnp.float32)
        out = ops.flash_attention(q, k, v, mask_kind=mask, window=window,
                                  block_q=64, block_kv=64,
                                  force="pallas_interpret")
        expect = ref.attention_ref(q, k, v, mask_kind=mask, window=window)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q = rand(1, (1, 128, 4, 32), jnp.bfloat16)
        k = rand(2, (1, 128, 2, 32), jnp.bfloat16)
        v = rand(3, (1, 128, 2, 32), jnp.bfloat16)
        out = ops.flash_attention(q, k, v, block_q=64, block_kv=64,
                                  force="pallas_interpret")
        expect = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   expect.astype(jnp.float32),
                                   rtol=5e-2, atol=5e-2)


class TestRGLRU:
    @pytest.mark.parametrize("b,t,w,ct,bw", [(2, 64, 128, 8, 128),
                                             (1, 32, 256, 4, 128),
                                             (3, 16, 128, 16, 64)])
    def test_vs_ref(self, b, t, w, ct, bw):
        a = jax.random.uniform(jax.random.PRNGKey(1), (b, t, w),
                               jnp.float32, 0.3, 0.999)
        x = rand(2, (b, t, w), jnp.float32)
        h0 = rand(3, (b, w), jnp.float32)
        from repro.kernels.rglru import rglru_pallas
        y, h = rglru_pallas(a, x, h0, chunk_t=ct, block_w=bw,
                            interpret=True)
        yr, hr = ref.rglru_ref(a, x, h0)
        np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(h, hr, rtol=1e-5, atol=1e-5)


class TestRWKV6:
    @pytest.mark.parametrize("b,t,h,dh,chunk", [(2, 64, 2, 64, 16),
                                                (1, 32, 4, 32, 32),
                                                (2, 128, 1, 64, 32)])
    def test_vs_ref(self, b, t, h, dh, chunk):
        r = rand(1, (b, t, h, dh), jnp.float32)
        k = rand(2, (b, t, h, dh), jnp.float32)
        v = rand(3, (b, t, h, dh), jnp.float32)
        lw = -jnp.exp(jnp.clip(rand(4, (b, t, h, dh), jnp.float32), -8, 1))
        u = rand(5, (h, dh), jnp.float32) * 0.1
        out = ops.rwkv6(r, k, v, lw, u, chunk=chunk,
                        force="pallas_interpret")
        expect = ref.rwkv6_ref(r, k, v, lw, u)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


class TestMoeGMM:
    @pytest.mark.parametrize("e,c,d,f", [(4, 128, 256, 128),
                                         (2, 256, 128, 256),
                                         (8, 128, 128, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, e, c, d, f, dtype):
        x = rand(1, (e, c, d), dtype)
        w = rand(2, (e, d, f), dtype)
        out = ops.moe_gmm(x, w, force="pallas_interpret")
        expect = ref.moe_gmm_ref(x, w)
        np.testing.assert_allclose(
            out.astype(jnp.float32), expect.astype(jnp.float32),
            rtol=TOL[dtype], atol=TOL[dtype] * 8)


class TestTileBoundaries:
    """Shapes exactly one element over/under block edges: the partial
    last tile is where the tail effect lives, and where padding bugs
    hide.  All dims one-over force the pad path; one-under exercises the
    clamp-to-dim path."""

    @pytest.mark.parametrize("m,n,k", [(63, 65, 64), (65, 63, 63),
                                       (64, 64, 65), (127, 129, 128),
                                       (129, 127, 127), (65, 65, 65)])
    def test_matmul_edges(self, m, n, k):
        x, w = rand(1, (m, k), jnp.float32), rand(2, (k, n), jnp.float32)
        out = ops.matmul(x, w, block_m=64, block_n=64, block_k=64,
                         force="pallas_interpret")
        np.testing.assert_allclose(out, ref.matmul_ref(x, w),
                                   rtol=2e-4, atol=2e-3)

    @pytest.mark.parametrize("mask,window", [("causal", 0), ("local", 48)])
    @pytest.mark.parametrize("s", [63, 65, 127, 129])
    def test_flash_edges(self, mask, window, s):
        q = rand(1, (1, s, 4, 32), jnp.float32)
        k = rand(2, (1, s, 2, 32), jnp.float32)
        v = rand(3, (1, s, 2, 32), jnp.float32)
        out = ops.flash_attention(q, k, v, mask_kind=mask, window=window,
                                  block_q=64, block_kv=64,
                                  force="pallas_interpret")
        expect = ref.attention_ref(q, k, v, mask_kind=mask, window=window)
        np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)

    def test_flash_unmasked_cannot_pad_kv(self):
        q = rand(1, (1, 64, 4, 32), jnp.float32)
        k = rand(2, (1, 65, 2, 32), jnp.float32)
        v = rand(3, (1, 65, 2, 32), jnp.float32)
        with pytest.raises(ValueError, match="mask_kind"):
            ops.flash_attention(q, k, v, mask_kind="none", block_q=64,
                                block_kv=64, force="pallas_interpret")

    @pytest.mark.parametrize("e,c,d,f", [(2, 33, 31, 32), (1, 31, 33, 33),
                                         (2, 65, 64, 63)])
    def test_moe_edges(self, e, c, d, f):
        x = rand(1, (e, c, d), jnp.float32)
        w = rand(2, (e, d, f), jnp.float32)
        out = ops.moe_gmm(x, w, block_c=32, block_f=32, block_d=32,
                          force="pallas_interpret")
        np.testing.assert_allclose(out, ref.moe_gmm_ref(x, w),
                                   rtol=2e-4, atol=2e-3)


class TestPaddedTailInvariant:
    """Zero-padded lanes must contribute EXACTLY zero: accumulating a
    0 * 0 tile is an IEEE no-op, so the padded kernel run is bit-identical
    to the unpadded one on the valid region, and exactly 0 outside it."""

    def test_matmul_padded_lanes_exact_zero(self):
        from repro.kernels.matmul_tiled import matmul_pallas
        m, n, k, b = 100, 120, 70, 64
        x, w = rand(1, (m, k), jnp.float32), rand(2, (k, n), jnp.float32)
        pad = lambda d: (-d) % b
        xp = jnp.pad(x, ((0, pad(m)), (0, pad(k))))
        wp = jnp.pad(w, ((0, pad(k)), (0, pad(n))))
        out = matmul_pallas(xp, wp, block_m=b, block_n=b, block_k=b,
                            interpret=True)
        assert np.all(np.asarray(out[m:, :]) == 0.0)
        assert np.all(np.asarray(out[:, n:]) == 0.0)
        # Garbage in x's padded K lanes times w's zero rows must be
        # bit-identical to zeros-times-zeros padding: the padded lanes
        # contribute exactly 0.0 to the accumulator either way.
        xg = xp.at[:, k:].set(1e6)
        alt = matmul_pallas(xg, wp, block_m=b, block_n=b, block_k=b,
                            interpret=True)
        assert np.array_equal(np.asarray(out), np.asarray(alt))
        np.testing.assert_allclose(np.asarray(out[:m, :n]),
                                   np.asarray(ref.matmul_ref(x, w)),
                                   rtol=2e-4, atol=2e-3)

    def test_moe_padded_d_exact_noop(self):
        from repro.kernels.moe_gmm import moe_gmm_pallas
        e, c, d, f, b = 2, 64, 96, 64, 32
        x = rand(1, (e, c, d), jnp.float32)
        w = rand(2, (e, d, f), jnp.float32)
        xp = jnp.pad(x, ((0, 0), (0, 0), (0, 32)))
        wp = jnp.pad(w, ((0, 0), (0, 32), (0, 0)))
        out = moe_gmm_pallas(xp, wp, block_c=b, block_f=b, block_d=b,
                             interpret=True)
        base = moe_gmm_pallas(x, w, block_c=b, block_f=b, block_d=b,
                              interpret=True)
        assert np.array_equal(np.asarray(out), np.asarray(base))

    def test_flash_padded_kv_is_masked_out(self):
        """Causal padding appends kv positions strictly in the future of
        every real query row — the padded output must equal the unpadded
        kernel run on the same blocks."""
        from repro.kernels.flash_attention import flash_attention_pallas
        s, b = 128, 64
        q = rand(1, (1, s, 4, 32), jnp.float32)
        k = rand(2, (1, s, 2, 32), jnp.float32)
        v = rand(3, (1, s, 2, 32), jnp.float32)
        qp = jnp.pad(q, ((0, 0), (0, b), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, b), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, b), (0, 0), (0, 0)))
        out = flash_attention_pallas(qp, kp, vp, mask_kind="causal",
                                     block_q=b, block_kv=b,
                                     interpret=True)[:, :s]
        base = flash_attention_pallas(q, k, v, mask_kind="causal",
                                      block_q=b, block_kv=b,
                                      interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-6, atol=1e-6)


class TestDivisibilityErrors:
    """The silent min(block, dim) clamp used to trip a bare assert on
    non-divisible shapes; now each kernel raises a padding-hint error."""

    def test_matmul_pallas_clear_error(self):
        from repro.kernels.matmul_tiled import matmul_pallas
        x, w = rand(1, (100, 64), jnp.float32), rand(2, (64, 64),
                                                     jnp.float32)
        with pytest.raises(ValueError, match="[Pp]ad"):
            matmul_pallas(x, w, block_m=64, block_n=64, block_k=64,
                          interpret=True)

    def test_flash_pallas_clear_error(self):
        from repro.kernels.flash_attention import flash_attention_pallas
        q = rand(1, (1, 100, 4, 32), jnp.float32)
        k = rand(2, (1, 100, 2, 32), jnp.float32)
        v = rand(3, (1, 100, 2, 32), jnp.float32)
        with pytest.raises(ValueError, match="[Pp]ad"):
            flash_attention_pallas(q, k, v, block_q=64, block_kv=64,
                                   interpret=True)

    def test_moe_pallas_clear_error(self):
        from repro.kernels.moe_gmm import moe_gmm_pallas
        x = rand(1, (2, 100, 64), jnp.float32)
        w = rand(2, (2, 64, 64), jnp.float32)
        with pytest.raises(ValueError, match="[Pp]ad"):
            moe_gmm_pallas(x, w, block_c=64, block_f=64, block_d=64,
                           interpret=True)

    @pytest.mark.parametrize("m,n,k", [(100, 130, 70)])
    def test_ops_matmul_pad_path_regression(self, m, n, k):
        """ops.matmul must absorb non-divisible shapes (the pad path) —
        both with explicit blocks and with the defaults."""
        x, w = rand(1, (m, k), jnp.float32), rand(2, (k, n), jnp.float32)
        expect = ref.matmul_ref(x, w)
        for kwargs in ({"block_m": 64, "block_n": 64, "block_k": 64}, {}):
            out = ops.matmul(x, w, force="pallas_interpret", **kwargs)
            np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-3)
