"""Hedged multi-replica serving: chunked prefill under fire, width
-variant hedging, health-aware routing and zero-loss failover.

Every scenario runs the real reduced model on per-replica virtual
clocks with seeded injectors, so assertions are exact — ledger sums,
who migrated, who won each hedge pair, run-twice trace equality — not
statistics.
"""

import numpy as np
import pytest

import jax

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serving import (
    Arrival, ContinuousServeEngine, HedgePolicy, ReplicaRouter, Request,
    ServingWidthPlanner, WidthVariantCompileCache,
)
from repro.serving.chaos import (
    ChunkFaultInjector, InjectedFault, ReplicaCrashInjector,
    ReplicaStallInjector, VirtualClock, modeled_batch_cost,
)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def arrivals_for(cfg, n, *, gap_s=0.002, plen=9, max_new=6, seed=1,
                 klass="small"):
    rng = np.random.default_rng(seed)
    return [Arrival(t=gap_s * i,
                    request=Request(
                        prompt=rng.integers(1, cfg.vocab_size, size=(plen,))
                        .astype(np.int32), max_new_tokens=max_new),
                    klass=klass)
            for i in range(n)]


def make_replica(cfg, params, *, slow=None, chunk_hook=None, cache=None,
                 slots=2, per_token_s=1e-4, overhead_s=1e-4):
    """One engine on its own VirtualClock with chunked prefill — the
    unit the router federates.  A shared compile cache keeps the fleet
    on one executable table (and one trace count)."""
    return ContinuousServeEngine(
        params, cfg, max_len=64, batch_slots=slots, clock=VirtualClock(),
        prefill_chunk=4, step_token_budget=8, chunk_fault_hook=chunk_hook,
        compile_cache=cache,
        batch_cost_fn=modeled_batch_cost(per_token_s, overhead_s=overhead_s,
                                         slow=slow))


def signature(results):
    return [(r.tokens.tolist(), round(r.latency_s, 12), r.shed, r.failed,
             r.hedged, r.won_by, r.migrations) for r in results]


# ---------------------------------------------------------------------------
# slot-exact cancellation (the hedge loser's contract)
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestCancel:
    def test_cancel_is_slot_exact(self, setup):
        """Cancelling one in-flight request frees only its slot: the
        neighbour decodes exactly the tokens it decodes in a run where
        no cancel ever happens."""
        cfg, params = setup
        rng = np.random.default_rng(5)
        keep = Request(prompt=rng.integers(1, cfg.vocab_size, size=(7,))
                       .astype(np.int32), max_new_tokens=8)
        victim = Request(prompt=rng.integers(1, cfg.vocab_size, size=(9,))
                         .astype(np.int32), max_new_tokens=8)

        solo = make_replica(cfg, params)
        r_solo = solo.submit(keep)
        while solo._outstanding():
            solo.step()
        want = solo.result(r_solo).tokens.tolist()

        eng = make_replica(cfg, params)
        r_keep = eng.submit(keep)
        r_victim = eng.submit(victim)
        for _ in range(4):
            eng.step()
        assert eng.cancel(r_victim) is True
        assert eng.cancel(r_victim) is False      # already terminal
        assert eng.cancel(10_000) is False        # unknown rid
        while eng._outstanding():
            eng.step()
        res_v = eng.result(r_victim)
        assert res_v.cancelled and res_v.shed and not res_v.deadline_missed
        assert eng.result(r_keep).tokens.tolist() == want
        led = eng.ledger()
        assert led.complete and led.finished == 1 and led.shed == 1

    def test_cancel_queued_request(self, setup):
        cfg, params = setup
        eng = make_replica(cfg, params, slots=2)
        rids = [eng.submit(a.request)
                for a in arrivals_for(cfg, 4, gap_s=0.0)]
        eng.step()                                # seats the first two
        assert eng.cancel(rids[-1]) is True       # still queued
        while eng._outstanding():
            eng.step()
        assert eng.result(rids[-1]).cancelled
        assert eng.ledger().complete


# ---------------------------------------------------------------------------
# hedge pairs: one logical request, exact accounting
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.chaos
class TestHedging:
    def _hedged_run(self, cfg, params, *, stall_factor=8.0, n=10):
        cache = WidthVariantCompileCache(cfg)
        stall = ReplicaStallInjector(stall_factor)
        router = ReplicaRouter(
            {"r0": make_replica(cfg, params, slow=stall, cache=cache),
             "r1": make_replica(cfg, params, cache=cache)},
            hedge=HedgePolicy(default_delay_s=0.01, rung=0),
            slow_factor=None)         # isolate hedging from health drain
        results = router.run(arrivals_for(cfg, n))
        return router, results

    def test_hedge_pair_is_one_ledger_entry(self, setup):
        """Router ledger counts logicals (submitted == finished + shed +
        failed with hedge pairs in flight), every engine's own ledger
        stays complete, and the losing leg is a cancelled shed on its
        engine — accounted exactly once at each level."""
        cfg, params = setup
        router, results = self._hedged_run(cfg, params)
        led = router.ledger()
        assert led.complete
        assert led.submitted == len(results) == 10
        assert led.finished + led.shed + led.failed == led.submitted
        assert led.hedged >= 1
        for r in router.replicas:
            el = r.engine.ledger()
            assert el.complete, el
        cancelled = sum(
            res.cancelled for r in router.replicas
            for res in r.engine._results.values())
        launched = len(router.hedge_log)
        resolved_cancels = sum(1 for lg in router._logicals
                               if lg.hedged and len(lg.results) < 2)
        assert cancelled == resolved_cancels
        assert led.hedged == launched

    def test_backup_wins_on_stalled_primary(self, setup):
        """With the primary replica stalled 8x, every hedged request is
        won by the backup leg and carries won_by='backup'."""
        cfg, params = setup
        router, results = self._hedged_run(cfg, params)
        hedged = [r for r in results if r.hedged]
        assert hedged
        assert all(r.won_by in ("primary", "backup") for r in hedged)
        assert router.ledger().hedge_wins_backup >= 1
        assert all(not r.hedged or r.won_by for r in results)

    def test_both_legs_fault_resolves_failed_not_lost(self, setup):
        """Every chunk on every replica faults: both legs of the pair
        fail terminally and the logical request resolves failed — the
        ledger still sums, nothing hangs or disappears."""
        cfg, params = setup

        def always():
            raise InjectedFault("permanent chunk fault")

        router = ReplicaRouter(
            {"r0": make_replica(cfg, params, chunk_hook=always),
             "r1": make_replica(cfg, params, chunk_hook=always)},
            hedge=HedgePolicy(default_delay_s=0.0, rung=0),
            slow_factor=None, max_migrations=0)
        results = router.run(arrivals_for(cfg, 3))
        led = router.ledger()
        assert led.complete and led.failed == 3, led
        assert all(r.failed and not r.shed for r in results)

    def test_hedge_rung_pins_and_releases_degrader(self, setup):
        """A rung>0 hedge pins the backup replica's degradation floor
        for the backup's lifetime and releases it at resolution — pins
        are balanced after the run."""
        cfg, params = setup
        from repro.core import TPU_V5E as HW
        from repro.serving import (
            DegradationController, DegradationLadder, TrafficClass,
            serving_templates,
        )
        templates, modules = serving_templates(cfg, HW, tokens=96,
                                               sites=("mlp",))
        planner = ServingWidthPlanner(HW, templates, modules=modules)
        traffic = [TrafficClass("small", 96)]
        planner.plan(traffic)
        ladder = DegradationLadder.build(planner, traffic,
                                         deltas=(0.8, 0.6))
        from repro.serving import AdmissionControl, WidthSwapper
        degraders = []

        def replica(stall=None):
            adm = AdmissionControl(max_queue_batches=8,
                                   target_batch_s=1.0)
            deg = DegradationController(ladder, down_patience=10 ** 6,
                                        up_patience=10 ** 6)
            degraders.append(deg)
            return ContinuousServeEngine(
                params, cfg, max_len=64, batch_slots=2,
                clock=VirtualClock(), prefill_chunk=4,
                swapper=WidthSwapper(params, cfg), admission=adm,
                degrader=deg,
                batch_cost_fn=modeled_batch_cost(1e-4, overhead_s=1e-4,
                                                 slow=stall))

        router = ReplicaRouter(
            {"r0": replica(ReplicaStallInjector(8.0)), "r1": replica()},
            hedge=HedgePolicy(default_delay_s=0.01, rung=1),
            slow_factor=None)
        router.run(arrivals_for(cfg, 8))
        led = router.ledger()
        assert led.complete and led.hedged >= 1
        assert all(ev.rung == 1 for ev in router.hedge_log)
        for deg in degraders:
            assert deg._pins == [], "hedge pin leaked past resolution"


# ---------------------------------------------------------------------------
# health-aware routing: drain, failover, zero loss
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.chaos
class TestRouterFailover:
    def test_crash_migrates_in_flight_zero_lost(self, setup):
        """Replica 0 dies mid-run: its in-flight requests are adopted by
        replica 1 with generated tokens intact; every logical request
        finishes and the crash is in the health log."""
        cfg, params = setup
        router = ReplicaRouter(
            {"r0": make_replica(cfg, params,
                                slow=ReplicaCrashInjector(at_step=2)),
             "r1": make_replica(cfg, params)},
            slow_factor=None)
        arrs = arrivals_for(cfg, 12, gap_s=0.001, max_new=10)
        results = router.run(arrs)
        led = router.ledger()
        assert led.complete and led.finished == 12 and led.failed == 0
        assert led.migrated >= 1
        assert [h.state for h in router.health_log] == ["dead"]
        assert any(r.migrations > 0 for r in results)
        dead = router.replicas[0].engine.ledger()
        assert dead.complete and dead.evicted >= 1

    def test_slow_replica_drained_by_ewma(self, setup):
        """A 20x straggler trips the EWMA health check: marked slow,
        drained, its work rehomed — and the fleet finishes everything."""
        cfg, params = setup
        router = ReplicaRouter(
            {"r0": make_replica(cfg, params,
                                slow=ReplicaStallInjector(20.0)),
             "r1": make_replica(cfg, params)},
            slow_factor=4.0, min_beats=4)
        results = router.run(arrivals_for(cfg, 16, gap_s=0.001,
                                          max_new=12))
        led = router.ledger()
        assert led.complete and led.finished == 16
        assert led.migrated >= 1
        assert [h.state for h in router.health_log] == ["slow"]
        assert "ewma" in router.health_log[0].reason

    def test_migration_budget_exhaustion_fails_accountably(self, setup):
        """Every replica crashing: once a request is out of migrations
        (or out of fleet) it fails terminally with a Result — the run
        ends, the ledger sums, nothing is silently dropped."""
        cfg, params = setup
        router = ReplicaRouter(
            {"r0": make_replica(cfg, params,
                                slow=ReplicaCrashInjector(at_step=2)),
             "r1": make_replica(cfg, params,
                                slow=ReplicaCrashInjector(at_step=4))},
            slow_factor=None, max_migrations=1)
        results = router.run(arrivals_for(cfg, 8, gap_s=0.001,
                                          max_new=10))
        led = router.ledger()
        assert led.complete
        assert led.failed >= 1
        assert led.finished + led.failed + led.shed == 8
        assert all(r is not None for r in results)

    def test_chunk_checkpoint_survives_migration(self, setup):
        """A replica dying mid-prefill hands its chunk checkpoint to the
        adopting replica; the request still decodes the exact tokens of
        an undisturbed run (head vectors match, so the checkpoint
        resumes instead of restarting)."""
        cfg, params = setup
        arrs = arrivals_for(cfg, 4, gap_s=0.0005, plen=21, max_new=6)
        baseline = ReplicaRouter(
            {"r0": make_replica(cfg, params),
             "r1": make_replica(cfg, params)},
            slow_factor=None).run([Arrival(a.t, a.request, a.klass)
                                   for a in arrs])
        router = ReplicaRouter(
            {"r0": make_replica(cfg, params,
                                slow=ReplicaCrashInjector(at_step=1)),
             "r1": make_replica(cfg, params)},
            slow_factor=None)
        results = router.run(arrs)
        assert router.ledger().complete
        for want, got in zip(baseline, results):
            assert want.tokens.tolist() == got.tokens.tolist()


# ---------------------------------------------------------------------------
# acceptance: stalled replica + mid-prefill faults, hedged beats unhedged
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.chaos
class TestHedgedAcceptance:
    N = 24

    def _run(self, cfg, params, *, hedge):
        """Straggler burst: replica 0 stalls 8x from the start, chunk
        prefills fault at a seeded rate on both replicas."""
        cache = WidthVariantCompileCache(cfg)
        router = ReplicaRouter(
            {"r0": make_replica(cfg, params,
                                slow=ReplicaStallInjector(8.0),
                                chunk_hook=ChunkFaultInjector(0.05,
                                                              seed=11),
                                cache=cache),
             "r1": make_replica(cfg, params,
                                chunk_hook=ChunkFaultInjector(0.05,
                                                              seed=12),
                                cache=cache)},
            hedge=(HedgePolicy(default_delay_s=0.01, rung=0)
                   if hedge else None),
            slow_factor=None)
        results = router.run(arrivals_for(cfg, self.N, gap_s=0.001,
                                          plen=13, max_new=8))
        return router, results

    @pytest.fixture(scope="class")
    def runs(self, setup):
        cfg, params = setup
        unhedged = self._run(cfg, params, hedge=False)
        hedged = self._run(cfg, params, hedge=True)
        return unhedged, hedged

    def test_zero_lost_under_chaos(self, runs):
        (r_un, un), (r_h, h) = runs
        for router, results in ((r_un, un), (r_h, h)):
            led = router.ledger()
            assert led.complete and led.submitted == self.N
            assert led.failed == 0 and led.shed == 0, led
            assert all(len(r.tokens) == 8 for r in results)
        # the chaos actually fired: chunk faults recovered from
        assert any(len(r.engine.chunk_log) > 0 for r in r_h.replicas)

    def test_hedged_p999_beats_unhedged(self, runs):
        (_, un), (r_h, h) = runs
        p_un = float(np.percentile([r.latency_s for r in un], 99.9))
        p_h = float(np.percentile([r.latency_s for r in h], 99.9))
        assert r_h.ledger().hedged >= 1
        assert p_h < p_un, (p_h, p_un)

    def test_run_twice_is_identical(self, setup, runs):
        cfg, params = setup
        (_, un), (_, h) = runs
        assert signature(self._run(cfg, params, hedge=False)[1]) \
            == signature(un)
        assert signature(self._run(cfg, params, hedge=True)[1]) \
            == signature(h)
