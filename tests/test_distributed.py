"""Multi-device semantics, run in subprocesses with 8 fake host devices
(XLA locks device count at first init, so these cannot share the main
pytest process)."""

import subprocess
import sys
import textwrap

import pytest

# full XLA compiles in subprocesses: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow

PREAMBLE = """
import os
# pin the CPU backend: without it jax probes for a TPU first (minutes of
# retried metadata fetches in this container) before falling back
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
"""


def run_sub(body: str):
    code = PREAMBLE + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


class TestFlashDecodeSharded:
    def test_matches_replicated(self):
        run_sub("""
        from repro.models.attention import flash_decode_sharded, \\
            decode_attention, update_cache_sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        b, s, h, kv, dh = 4, 64, 8, 2, 16
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, dh))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, dh))
        clen = jnp.asarray(40)
        out = jax.jit(lambda q,k,v: flash_decode_sharded(
            q, k, v, clen, mesh))(q, k, v)
        expect = decode_attention(q, k, v, clen)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=2e-2, atol=2e-2)
        # sharded cache write: only the owning shard commits
        new = jax.random.normal(jax.random.PRNGKey(3), (b, kv, dh))
        c2 = jax.jit(lambda c, n: update_cache_sharded(
            c, n, jnp.asarray(40), mesh))(k, new)
        ref = k.at[:, 40].set(new)
        np.testing.assert_allclose(np.asarray(c2), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("flash-decode OK")
        """)


class TestMoeEP:
    def test_ep_matches_single(self):
        run_sub("""
        from repro.models import moe as moe_lib
        from repro.parallel import sharding as shlib
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        d, e, f, k = 32, 8, 64, 2
        p = moe_lib.init_moe(jax.random.PRNGKey(0), d, e, f, False, f)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, d),
                              jnp.bfloat16)
        y1, _ = moe_lib.apply_moe_capacity(p, x, k, capacity_factor=8.0)
        with shlib.activity(mesh, {}):
            y2, _ = jax.jit(lambda p, x: moe_lib.apply_moe_capacity(
                p, x, k, capacity_factor=8.0, mesh=mesh))(p, x)
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32),
                                   rtol=6e-2, atol=6e-2)
        print("moe EP OK")
        """)


class TestShardedTrainStep:
    def test_tiny_arch_on_mesh(self):
        """Full train step on a (2,4) mesh with FSDP+TP param shardings;
        result must match the single-device step."""
        run_sub("""
        from repro.configs import get_config, reduced_config
        from repro.models import init_params
        from repro.train import TrainConfig, adamw_init, \\
            build_train_step, cosine_schedule
        from repro.parallel import sharding as shlib
        from repro.parallel.sharding import param_shardings
        cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=64,
                             n_layers=2, vocab=256)
        params = init_params(jax.random.PRNGKey(0), cfg)
        tc = TrainConfig(moe_strategy="dense")
        step = build_train_step(cfg, tc, cosine_schedule(1e-3, 2, 50))
        batch = {
          "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                       cfg.vocab_size),
          "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                       cfg.vocab_size)}
        opt = adamw_init(params)
        p_ref, _, m_ref = jax.jit(step)(params, opt, batch, jnp.asarray(0))

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shlib.activity(mesh, {}):
            sh = param_shardings(params, mesh)
            params_s = jax.device_put(params, sh)
            opt_s = adamw_init(params_s)
            p_m, _, m_m = jax.jit(step)(params_s, opt_s, batch,
                                        jnp.asarray(0))
        assert abs(float(m_ref["loss"]) - float(m_m["loss"])) < 1e-2, (
            float(m_ref["loss"]), float(m_m["loss"]))
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_m)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=3e-2, atol=3e-2)
        print("sharded train step OK, loss", float(m_m["loss"]))
        """)


class TestDiloco:
    def test_inner_steps_have_no_pod_collectives(self):
        run_sub("""
        from repro.configs import get_config, reduced_config
        from repro.models import init_params
        from repro.train import TrainConfig, adamw_init, \\
            build_train_step, cosine_schedule
        from repro.parallel import diloco
        from repro.core.hlo_analysis import parse_collectives
        from jax.sharding import NamedSharding
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=32,
                             n_layers=2, vocab=128)
        params = init_params(jax.random.PRNGKey(0), cfg)
        tc = TrainConfig(moe_strategy="dense")
        step = build_train_step(cfg, tc, cosine_schedule(1e-3, 2, 50))
        H, n_pods = 2, 2
        inner = diloco.build_inner_steps(step, H)
        pp = diloco.replicate_for_pods(params, n_pods)
        oo = diloco.replicate_for_pods(adamw_init(params), n_pods)
        batches = {
          "tokens": jax.random.randint(jax.random.PRNGKey(1),
                                       (n_pods, H, 4, 16), 0, 128),
          "labels": jax.random.randint(jax.random.PRNGKey(2),
                                       (n_pods, H, 4, 16), 0, 128)}
        shard = lambda t: jax.device_put(t, NamedSharding(mesh, P("pod")))
        pp = jax.tree.map(shard, pp)
        oo = jax.tree.map(shard, oo)
        batches = jax.tree.map(shard, batches)
        lowered = jax.jit(inner).lower(pp, oo, batches, jnp.asarray(0))
        compiled = lowered.compile()
        colls = parse_collectives(compiled.as_text())
        # inner steps must not communicate across pods: every collective
        # group must be a within-pod group (size <= 4 = data*model)
        for op in colls.ops:
            assert op.group_size <= 4, (op.kind, op.group_size, op.line)
        # run it + outer step
        pp2, oo2, losses = jax.jit(inner)(pp, oo, batches, jnp.asarray(0))
        outer = diloco.init_outer_state(params)
        pp3, outer2 = diloco.outer_step(pp2, outer, diloco.DilocoConfig(),
                                        mesh)
        # all pods equal after sync
        l0 = jax.tree.leaves(pp3)[0]
        np.testing.assert_allclose(np.asarray(l0[0], np.float32),
                                   np.asarray(l0[1], np.float32))
        print("diloco OK, inner losses", np.asarray(losses).ravel()[:2])
        """)


class TestElasticRestore:
    def test_checkpoint_rescales_onto_mesh(self, tmp_path):
        """Save unsharded (1-device layout), restore onto a (2,4) mesh with
        FSDP+TP shardings — the elastic-scaling path."""
        run_sub(f"""
        from repro.configs import get_config, reduced_config
        from repro.models import init_params
        from repro.train import checkpoint, adamw_init
        from repro.parallel.sharding import param_shardings
        from repro.parallel import sharding as shlib
        cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=64,
                             n_layers=2, vocab=256)
        params = init_params(jax.random.PRNGKey(0), cfg)
        checkpoint.save(r"{tmp_path}", 7, params)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shlib.activity(mesh, {{}}):
            sh = param_shardings(params, mesh)
            restored = checkpoint.restore(r"{tmp_path}", 7, params,
                                          shardings=sh)
        for (a, b) in zip(jax.tree.leaves(params),
                          jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually carry the mesh shardings
        leaf = jax.tree.leaves(restored)[0]
        assert len(leaf.sharding.device_set) >= 1
        some_sharded = any(
            l.sharding.num_devices if hasattr(l.sharding, 'num_devices')
            else len(l.sharding.device_set) > 1
            for l in jax.tree.leaves(restored))
        assert some_sharded
        print("elastic restore OK")
        """)


class TestCompressedPsum:
    def test_ef_converges_to_true_mean(self):
        run_sub("""
        from repro.compat import shard_map
        from repro.parallel.compression import compressed_psum_tree
        mesh = jax.make_mesh((8,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1, 64))
        true_mean = jnp.mean(x, 0)   # (1, 64)

        def f(x_loc, e_loc):
            out, e_new = compressed_psum_tree({"w": x_loc}, {"w": e_loc},
                                              "pod")
            return out["w"], e_new["w"]

        sm = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P(None), P("pod"))))
        e = jnp.zeros((8, 1, 64))
        outs = []
        for i in range(30):
            out, e = sm(x, e)
            outs.append(out)
        one_shot = np.abs(np.asarray(outs[0] - true_mean)).max()
        # with error feedback, the *time average* converges to the truth
        avg = jnp.mean(jnp.stack(outs), 0)
        err_final = np.abs(np.asarray(avg - true_mean)).max()
        assert err_final <= one_shot + 1e-6
        assert err_final < 0.02, err_final
        print("compressed psum OK", err_final)
        """)
