"""Launch-layer logic: cell rules, variants, microbatch sizing — these run
without building a mesh of 512 devices (pure functions of config)."""

import dataclasses

import jax
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st


from repro.configs import SHAPES, get_config, list_archs
from repro.models.transformer import VOCAB_QUANTUM, padded_vocab


class FakeMesh:
    """Duck-typed stand-in: cell_rules/apply_variant only read shape/names."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


class TestCellRules:
    def test_ragged_heads_replicate(self):
        from repro.launch.specs import cell_rules
        cfg = get_config("yi-34b")           # 56 heads, kv 8
        rules = cell_rules(MESH, cfg, 256)
        assert rules["heads"] is None
        assert rules["kv_heads"] is None

    def test_aligned_heads_shard(self):
        from repro.launch.specs import cell_rules
        cfg = get_config("deepseek-7b")      # 32 heads, kv 32
        rules = cell_rules(MESH, cfg, 256)
        assert "heads" not in rules and "kv_heads" not in rules

    def test_batch_one_replicates(self):
        from repro.launch.specs import cell_rules
        cfg = get_config("rwkv6-1.6b")
        rules = cell_rules(MESH, cfg, 1)
        assert rules["batch"] is None

    def test_big_model_fsdp_over_pods(self):
        from repro.launch.specs import cell_rules
        cfg = get_config("llama4-maverick-400b-a17b")
        rules = cell_rules(MESH_POD, cfg, 256)
        assert rules["fsdp"] == ("data", "pod")
        small = cell_rules(MESH_POD, get_config("qwen1.5-0.5b"), 256)
        assert "fsdp" not in small


class TestVariants:
    def test_padded_heads(self):
        from repro.launch.specs import apply_variant
        cfg = apply_variant(get_config("yi-34b"), "padded_heads", MESH)
        assert cfg.n_heads == 64 and cfg.n_kv_heads == 16
        assert cfg.head_dim == 128          # unchanged
        assert cfg.name.endswith("+padheads")
        # now shardable
        from repro.launch.specs import cell_rules
        rules = cell_rules(MESH, cfg, 256)
        assert "heads" not in rules

    def test_padded_heads_noop_when_aligned(self):
        from repro.launch.specs import apply_variant
        cfg = apply_variant(get_config("deepseek-7b"), "padded_heads", MESH)
        assert cfg.n_heads == 32 and cfg.n_kv_heads == 32

    def test_seq_parallel(self):
        from repro.launch.specs import apply_variant, cell_rules
        cfg = apply_variant(get_config("command-r-plus-104b"),
                            "seq_parallel", MESH)
        assert cfg.seq_parallel_acts
        rules = cell_rules(MESH, cfg, 256)
        assert rules["act_seq"] == "model"

    def test_none_identity(self):
        from repro.launch.specs import apply_variant
        cfg = get_config("yi-34b")
        assert apply_variant(cfg, "none", MESH) is cfg


class TestSizing:
    @given(arch=st.sampled_from(list_archs()))
    @settings(max_examples=10, deadline=None)
    def test_padded_vocab_quantum(self, arch):
        cfg = get_config(arch)
        vp = padded_vocab(cfg)
        assert vp % VOCAB_QUANTUM == 0
        assert 0 <= vp - cfg.vocab_size < VOCAB_QUANTUM
        assert vp % 16 == 0                 # always TP-shardable

    def test_microbatches_monotone_in_model_size(self):
        from repro.launch.specs import microbatches_for
        big = get_config("command-r-plus-104b")
        small = get_config("qwen1.5-0.5b")
        shape = SHAPES["train_4k"]
        assert microbatches_for(big, MESH, shape) >= \
            microbatches_for(small, MESH, shape)

    def test_microbatches_divide_batch(self):
        from repro.launch.specs import microbatches_for
        shape = SHAPES["train_4k"]
        for arch in list_archs():
            mb = microbatches_for(get_config(arch), MESH, shape)
            seqs_per_dev = shape.global_batch // 16
            assert seqs_per_dev % mb == 0, (arch, mb)
