"""MoE routing + capacity dispatch semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st


from repro.models import moe as moe_lib

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow


def setup_moe(d=32, e=8, f=64, shared=False, key=0):
    p = moe_lib.init_moe(jax.random.PRNGKey(key), d, e, f, shared, f)
    x = jax.random.normal(jax.random.PRNGKey(key + 1), (2, 16, d),
                          jnp.bfloat16)
    return p, x


class TestRouting:
    @given(k=st.sampled_from([1, 2, 4]))
    @settings(max_examples=6, deadline=None)
    def test_gates_normalized(self, k):
        p, x = setup_moe()
        gates, ids, aux = moe_lib.route(p, x, k)
        np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                                   rtol=1e-5)
        assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 8).all()
        assert float(aux["moe_lb_loss"]) >= 1.0 - 1e-5   # E*sum(f*p) >= 1

    def test_top1_ids_are_argmax(self):
        p, x = setup_moe()
        gates, ids, _ = moe_lib.route(p, x, 1)
        logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                            p["router"])
        np.testing.assert_array_equal(np.asarray(ids[..., 0]),
                                      np.asarray(jnp.argmax(logits, -1)))


class TestCapacity:
    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_high_capacity_matches_dense(self, k):
        p, x = setup_moe()
        yd, _ = moe_lib.apply_moe_dense(p, x, k)
        yc, _ = moe_lib.apply_moe_capacity(p, x, k, capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(yc, np.float32),
                                   np.asarray(yd, np.float32),
                                   rtol=6e-2, atol=6e-2)

    def test_low_capacity_drops_tokens(self):
        p, x = setup_moe()
        yd, _ = moe_lib.apply_moe_dense(p, x, 2)
        yc, _ = moe_lib.apply_moe_capacity(p, x, 2, capacity_factor=0.25)
        # some tokens dropped => some rows differ materially
        diff = np.abs(np.asarray(yc, np.float32)
                      - np.asarray(yd, np.float32)).max(axis=-1)
        assert (diff > 1e-3).any()

    def test_shared_expert_added(self):
        p, x = setup_moe(shared=True)
        y, _ = moe_lib.apply_moe_capacity(p, x, 1, capacity_factor=8.0)
        p2 = dict(p)
        p2.pop("shared")
        y2, _ = moe_lib.apply_moe_capacity(p2, x, 1, capacity_factor=8.0)
        assert np.abs(np.asarray(y, np.float32)
                      - np.asarray(y2, np.float32)).max() > 1e-4

    def test_grads_flow_through_dispatch(self):
        p, x = setup_moe()

        def loss(p):
            y, aux = moe_lib.apply_moe_capacity(p, x, 2,
                                                capacity_factor=2.0)
            return (jnp.sum(y.astype(jnp.float32) ** 2)
                    + aux["moe_lb_loss"])

        g = jax.grad(loss)(p)
        for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
            assert np.isfinite(np.asarray(leaf, np.float32)).all(), path
        # router must receive gradient (via gates and aux loss)
        assert np.abs(np.asarray(g["router"])).sum() > 0
        assert np.abs(np.asarray(g["experts"]["w_up"],
                                 np.float32)).sum() > 0
