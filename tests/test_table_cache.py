"""Disk-backed profile-table cache: round-trips, invalidation, and the
warm-cache optimizer fast path (zero model sweeps)."""

import numpy as np
import pytest

from repro.core import (
    LayerShape, ProfileTableCache, TPU_V4, TPU_V5E, TailEffectOptimizer,
    TunableLayer, WaveQuantizationModel, analytic_candidates,
    hardware_fingerprint,
)
from repro.core import table_cache as tc

HW = TPU_V5E


def make_layers(n=8, tokens=4096, d_in=4096):
    out = []
    for i in range(n):
        shape = LayerShape(f"l{i}", tokens=tokens, d_in=d_in,
                           width=2048 * (i % 4 + 2) + 256, shard_out=16)
        cands = analytic_candidates(HW, shape,
                                    max_width=int(shape.width * 1.6))
        out.append(TunableLayer(layer=shape, candidates=cands,
                                params_per_unit=d_in))
    return out


class TestRoundTrip:
    def test_stair_table_round_trip(self, tmp_path):
        """write -> reload through a separate cache instance (the
        separate-process case) -> identical StairTable arrays."""
        layer = LayerShape("l", tokens=2048, d_in=1024, width=4096,
                           shard_out=16)
        widths = np.arange(256, 8193, 256)
        table = WaveQuantizationModel(HW).evaluate_batch(layer, widths)
        ProfileTableCache(tmp_path).put_stair_table(HW, layer, table)

        reloaded = ProfileTableCache(tmp_path).get_stair_table(
            HW, layer, widths)
        assert reloaded is not None
        for f in ("widths", "latency_s", "utilization", "throughput",
                  "waves", "flops", "padded_flops"):
            np.testing.assert_array_equal(
                getattr(table, f), getattr(reloaded, f), err_msg=f)

    def test_raw_arrays_round_trip(self, tmp_path):
        layer = LayerShape("l", tokens=64, d_in=64, width=100)
        widths = np.array([1, 5, 128], dtype=np.int64)
        lat = np.array([1e-6, 2e-6, 3e-6])
        cache = ProfileTableCache(tmp_path)
        cache.put(HW, layer, widths, {"latency_s": lat})
        hit = ProfileTableCache(tmp_path).get(HW, layer, widths)
        assert hit is not None
        np.testing.assert_array_equal(hit["latency_s"], lat)
        assert cache.stats.writes == 1

    def test_name_and_width_excluded_from_key(self, tmp_path):
        """Two identically shaped layers share entries regardless of name
        and nominal width (the swept start width lives in the width
        vector, not the shape key)."""
        a = LayerShape("a", tokens=64, d_in=64, width=100)
        b = LayerShape("b", tokens=64, d_in=64, width=999)
        widths = np.array([128, 256], dtype=np.int64)
        cache = ProfileTableCache(tmp_path)
        cache.put(HW, a, widths, {"latency_s": np.array([1.0, 2.0])})
        assert cache.get(HW, b, widths) is not None


class TestInvalidation:
    def _seed(self, tmp_path):
        layer = LayerShape("l", tokens=64, d_in=64, width=100)
        widths = np.array([128, 256], dtype=np.int64)
        cache = ProfileTableCache(tmp_path)
        cache.put(HW, layer, widths, {"latency_s": np.array([1.0, 2.0])})
        return cache, layer, widths

    def test_hardware_mismatch_misses(self, tmp_path):
        cache, layer, widths = self._seed(tmp_path)
        assert cache.get(TPU_V4, layer, widths) is None
        assert hardware_fingerprint(TPU_V4) != hardware_fingerprint(HW)

    def test_shape_mismatch_misses(self, tmp_path):
        cache, layer, widths = self._seed(tmp_path)
        import dataclasses
        other = dataclasses.replace(layer, d_in=128)
        assert cache.get(HW, other, widths) is None

    def test_width_vector_mismatch_misses(self, tmp_path):
        cache, layer, widths = self._seed(tmp_path)
        assert cache.get(HW, layer, widths[:1]) is None
        assert cache.get(HW, layer, widths + 1) is None

    def test_version_bump_invalidates(self, tmp_path, monkeypatch):
        cache, layer, widths = self._seed(tmp_path)
        monkeypatch.setattr(tc, "CACHE_VERSION", tc.CACHE_VERSION + 1)
        assert ProfileTableCache(tmp_path).get(HW, layer, widths) is None

    def test_corrupt_entry_misses(self, tmp_path):
        cache, layer, widths = self._seed(tmp_path)
        [path] = list(cache.root.glob("??/*.npz"))
        path.write_bytes(b"not an npz")
        assert ProfileTableCache(tmp_path).get(HW, layer, widths) is None

    def test_clear(self, tmp_path):
        cache, layer, widths = self._seed(tmp_path)
        assert cache.clear() == 1
        assert cache.get(HW, layer, widths) is None


class TestFromEnv:
    def test_unset_disables(self, monkeypatch):
        monkeypatch.delenv(tc.CACHE_DIR_ENV, raising=False)
        assert ProfileTableCache.from_env() is None

    def test_unset_with_default(self, monkeypatch, tmp_path):
        monkeypatch.delenv(tc.CACHE_DIR_ENV, raising=False)
        cache = ProfileTableCache.from_env(default=str(tmp_path))
        assert cache is not None and cache.root == tmp_path

    @pytest.mark.parametrize("token", ["", "0", "off", "NONE", "Disabled"])
    def test_disable_tokens(self, monkeypatch, token):
        monkeypatch.setenv(tc.CACHE_DIR_ENV, token)
        assert ProfileTableCache.from_env() is None

    def test_env_dir_wins(self, monkeypatch, tmp_path):
        monkeypatch.setenv(tc.CACHE_DIR_ENV, str(tmp_path / "c"))
        cache = ProfileTableCache.from_env(default="/ignored")
        assert cache is not None and cache.root == tmp_path / "c"


class TestWarmOptimizer:
    def test_warm_optimize_latency_zero_sweeps(self, tmp_path):
        """Acceptance: a warm cache makes ``optimize_latency`` skip every
        model sweep (``eval_calls == 0``) and return identical results."""
        layers = make_layers()
        cold_model = WaveQuantizationModel(HW)
        cold = TailEffectOptimizer(cold_model,
                                   cache=ProfileTableCache(tmp_path))
        res_cold = cold.optimize_latency(layers, tau=1e9, delta=0.95)
        assert cold_model.eval_calls > 0

        warm_model = WaveQuantizationModel(HW)
        warm_cache = ProfileTableCache(tmp_path)
        warm = TailEffectOptimizer(warm_model, cache=warm_cache)
        res_warm = warm.optimize_latency(layers, tau=1e9, delta=0.95)
        assert warm_model.eval_calls == 0
        assert warm_model.eval_points == 0
        assert warm_cache.stats.hits == len(layers)
        assert res_warm.new_widths == res_cold.new_widths
        assert res_warm.moves == res_cold.moves
        assert res_warm.latency_new_s == res_cold.latency_new_s

    def test_warm_optimize_accuracy_zero_sweeps(self, tmp_path):
        layers = make_layers()
        cold = TailEffectOptimizer(WaveQuantizationModel(HW),
                                   cache=ProfileTableCache(tmp_path))
        res_cold = cold.optimize_accuracy(layers, latency_slack=0.1)
        warm_model = WaveQuantizationModel(HW)
        warm = TailEffectOptimizer(warm_model,
                                   cache=ProfileTableCache(tmp_path))
        res_warm = warm.optimize_accuracy(layers, latency_slack=0.1)
        assert warm_model.eval_calls == 0
        assert res_warm.new_widths == res_cold.new_widths

    def test_cached_equals_uncached(self, tmp_path):
        """Running through the cache must not change any result."""
        layers = make_layers()
        plain = TailEffectOptimizer(WaveQuantizationModel(HW))
        res_plain = plain.optimize_latency(layers, tau=1e9, delta=0.95)
        for _ in range(2):  # cold then warm
            cached = TailEffectOptimizer(WaveQuantizationModel(HW),
                                         cache=ProfileTableCache(tmp_path))
            res = cached.optimize_latency(layers, tau=1e9, delta=0.95)
            assert res.new_widths == res_plain.new_widths
            assert res.moves == res_plain.moves
            assert res.latency_new_s == res_plain.latency_new_s

    def test_stack_bundle_single_file(self, tmp_path):
        """Stacks >= bundle_min_layers cache as ONE whole-stack bundle:
        one file on disk, warm run one hit and zero sweeps, results
        identical to the per-layer granularity."""
        layers = make_layers(8)
        cold_cache = ProfileTableCache(tmp_path)
        cold = TailEffectOptimizer(WaveQuantizationModel(HW),
                                   cache=cold_cache, bundle_min_layers=4)
        res_cold = cold.optimize_latency(layers, tau=1e9, delta=0.95)
        assert len(list(cold_cache.root.glob("??/*.npz"))) == 1

        warm_model = WaveQuantizationModel(HW)
        warm_cache = ProfileTableCache(tmp_path)
        warm = TailEffectOptimizer(warm_model, cache=warm_cache,
                                   bundle_min_layers=4)
        res_warm = warm.optimize_latency(layers, tau=1e9, delta=0.95)
        assert warm_model.eval_calls == 0
        assert warm_cache.stats.hits == 1
        assert res_warm.new_widths == res_cold.new_widths
        assert res_warm.moves == res_cold.moves

        plain = TailEffectOptimizer(WaveQuantizationModel(HW))
        res_plain = plain.optimize_latency(layers, tau=1e9, delta=0.95)
        assert res_warm.new_widths == res_plain.new_widths

    def test_stack_bundle_invalidates_on_any_layer_change(self, tmp_path):
        layers = make_layers(8)
        opt = TailEffectOptimizer(WaveQuantizationModel(HW),
                                  cache=ProfileTableCache(tmp_path),
                                  bundle_min_layers=4)
        opt.optimize_latency(layers, tau=1e9, delta=0.95)
        import dataclasses
        changed = list(layers)
        changed[3] = dataclasses.replace(
            layers[3],
            layer=dataclasses.replace(layers[3].layer, d_in=8192))
        model = WaveQuantizationModel(HW)
        warm = TailEffectOptimizer(model, cache=ProfileTableCache(tmp_path),
                                   bundle_min_layers=4)
        warm.optimize_latency(changed, tau=1e9, delta=0.95)
        assert model.eval_calls > 0   # bundle missed -> one fresh sweep

    def test_partial_warm_sweeps_only_misses(self, tmp_path):
        """New layers added to a warm cache: only they are swept.
        (Shapes must be pairwise distinct here — the key ignores layer
        names, so repeated shapes would all hit.)"""
        layers = []
        for i in range(8):
            shape = LayerShape(f"l{i}", tokens=4096, d_in=4096,
                               width=2048 * (i + 2) + 256, shard_out=16)
            cands = analytic_candidates(HW, shape,
                                        max_width=int(shape.width * 1.6))
            layers.append(TunableLayer(layer=shape, candidates=cands,
                                       params_per_unit=4096))
        TailEffectOptimizer(
            WaveQuantizationModel(HW),
            cache=ProfileTableCache(tmp_path)).optimize_latency(
                layers[:5], tau=1e9, delta=0.95)
        model = WaveQuantizationModel(HW)
        cache = ProfileTableCache(tmp_path)
        opt = TailEffectOptimizer(model, cache=cache)
        res = opt.optimize_latency(layers, tau=1e9, delta=0.95)
        assert cache.stats.hits == 5
        assert model.eval_calls == 1           # one stacked sweep
        assert model.eval_points <= 3 * 3      # only the 3 missing layers
        plain = TailEffectOptimizer(WaveQuantizationModel(HW))
        assert res.new_widths == plain.optimize_latency(
            layers, tau=1e9, delta=0.95).new_widths


class TestEviction:
    """max_bytes size cap with least-recently-used eviction: long-lived
    NAS sweeps must not accumulate stale bundles without bound."""

    def _put(self, cache, i, n=64):
        layer = LayerShape("l", tokens=64 * (i + 1), d_in=64, width=100)
        widths = np.arange(1, n + 1, dtype=np.int64)
        cache.put(HW, layer, widths,
                  {"latency_s": np.full(n, float(i))})
        return layer, widths

    def _age(self, cache, seconds):
        import os
        import time
        now = time.time()
        for p in cache.root.glob("??/*.npz"):
            os.utime(p, (now - seconds, now - seconds))

    def test_cap_evicts_oldest_entry(self, tmp_path):
        cache = ProfileTableCache(tmp_path)      # no cap while filling
        la, wa = self._put(cache, 0)
        entry_bytes = cache.size_bytes()
        lb, wb = self._put(cache, 1)
        self._age(cache, 100)

        capped = ProfileTableCache(tmp_path,
                                   max_bytes=int(entry_bytes * 2.5))
        lc, wc = self._put(capped, 2)            # third entry bursts the cap
        assert capped.stats.evictions >= 1
        assert capped.get(HW, la, wa) is None    # oldest gone
        assert capped.get(HW, lc, wc) is not None
        assert capped.size_bytes() <= int(entry_bytes * 2.5)

    def test_read_hit_refreshes_lru_order(self, tmp_path):
        cache = ProfileTableCache(tmp_path)
        la, wa = self._put(cache, 0)
        entry_bytes = cache.size_bytes()
        lb, wb = self._put(cache, 1)
        self._age(cache, 100)

        capped = ProfileTableCache(tmp_path,
                                   max_bytes=int(entry_bytes * 2.5))
        assert capped.get(HW, la, wa) is not None   # touch A: now newest
        self._put(capped, 2)
        assert capped.get(HW, la, wa) is not None   # A survived the cap
        assert capped.get(HW, lb, wb) is None       # B was the LRU victim

    def test_just_written_entry_always_survives(self, tmp_path):
        """Even a cap smaller than one entry keeps the fresh write — a
        cache that evicts its own write would thrash at 100%."""
        cache = ProfileTableCache(tmp_path, max_bytes=1)
        la, wa = self._put(cache, 0)
        lb, wb = self._put(cache, 1)
        assert cache.get(HW, lb, wb) is not None
        assert cache.get(HW, la, wa) is None
        assert cache.stats.evictions == 1

    def test_no_cap_never_evicts(self, tmp_path):
        cache = ProfileTableCache(tmp_path)
        pairs = [self._put(cache, i) for i in range(6)]
        assert cache.stats.evictions == 0
        for layer, widths in pairs:
            assert cache.get(HW, layer, widths) is not None

    def test_stack_bundles_respect_cap(self, tmp_path):
        layers = [LayerShape(f"s{i}", tokens=64, d_in=64, width=100)
                  for i in range(3)]
        w2d = np.arange(12, dtype=np.int64).reshape(3, 4)
        counts = np.full(3, 4, dtype=np.int64)
        lat = np.ones((3, 4))
        probe = ProfileTableCache(tmp_path)
        probe.put_stack(HW, layers, w2d, counts, lat)
        bundle_bytes = probe.size_bytes()
        probe.clear()

        cache = ProfileTableCache(tmp_path,
                                  max_bytes=int(bundle_bytes * 1.5))
        cache.put_stack(HW, layers, w2d, counts, lat)
        self._age(cache, 100)
        other = [LayerShape(f"t{i}", tokens=128, d_in=64, width=100)
                 for i in range(3)]
        cache.put_stack(HW, other, w2d, counts, lat)
        assert cache.stats.evictions == 1
        assert cache.get_stack(HW, layers, w2d, counts) is None
        assert cache.get_stack(HW, other, w2d, counts) is not None
