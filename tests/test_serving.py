"""Serving engine behaviour."""

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.serving import Request, ServeEngine

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, max_len=48, batch_slots=4), cfg


def test_greedy_deterministic(engine):
    eng, cfg = engine
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    r1 = eng.generate([Request(prompt=prompt, max_new_tokens=8)])
    r2 = eng.generate([Request(prompt=prompt, max_new_tokens=8)])
    np.testing.assert_array_equal(r1[0].tokens, r2[0].tokens)
    assert len(r1[0].tokens) == 8
    assert (r1[0].tokens < cfg.vocab_size).all()


def test_batched_equals_single(engine):
    """Slot batching must not change a request's output (same-length
    prompts; left-padding is only exercised with mixed lengths)."""
    eng, cfg = engine
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
               for _ in range(3)]
    batch = eng.generate([Request(prompt=p, max_new_tokens=6)
                          for p in prompts])
    singles = [eng.generate([Request(prompt=p, max_new_tokens=6)])[0]
               for p in prompts]
    for b, s in zip(batch, singles):
        np.testing.assert_array_equal(b.tokens, s.tokens)


def test_eos_stops_early(engine):
    eng, cfg = engine
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    base = eng.generate([Request(prompt=prompt, max_new_tokens=8)])[0]
    eos = int(base.tokens[2])
    res = eng.generate([Request(prompt=prompt, max_new_tokens=8,
                                eos_id=eos)])[0]
    assert len(res.tokens) <= 8
    assert res.tokens[-1] == eos


def test_overflowing_slots(engine):
    eng, cfg = engine
    rng = np.random.default_rng(3)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,))
                    .astype(np.int32), max_new_tokens=4)
            for _ in range(6)]   # > batch_slots=4
    out = eng.generate(reqs)
    assert len(out) == 6
    for r in out:
        assert len(r.tokens) == 4


def test_planner_consulted_at_batch_boundaries(engine):
    """With a width planner attached, every generated batch records the
    plan selected for its token volume (the swap point for width
    configs)."""
    from repro.core import (LayerShape, TPU_V5E, TunableLayer,
                            analytic_candidates)
    from repro.serving import ServingWidthPlanner, TrafficClass

    eng, cfg = engine
    ref = LayerShape("ffn", tokens=4096, d_in=4096, width=11008,
                     shard_out=16)
    cands = analytic_candidates(TPU_V5E, ref, max_width=16384)
    templates = [TunableLayer(layer=ref, candidates=cands,
                              params_per_unit=4096)]
    planner = ServingWidthPlanner(TPU_V5E, templates)
    planner.plan([TrafficClass("decode", 64), TrafficClass("prefill", 4096)])

    eng.planner = planner
    eng.plan_log.clear()
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,))
                    .astype(np.int32), max_new_tokens=2)
            for _ in range(6)]   # > batch_slots=4 -> two batches
    eng.generate(reqs)
    eng.planner = None
    assert len(eng.plan_log) == 2
    for plan in eng.plan_log:
        assert plan.traffic.name == "decode"   # 4*8=32 tokens -> decode


def _planner_with_swap(cfg, tokens_classes=((("decode", 64),
                                             ("prefill", 4096)))):
    """Planner whose templates/modules address the engine's own model."""
    from repro.core import TPU_V5E
    from repro.serving import (ServingWidthPlanner, TrafficClass,
                               serving_templates)

    templates, modules = serving_templates(cfg, TPU_V5E, tokens=256,
                                           sites=("mlp",))
    planner = ServingWidthPlanner(TPU_V5E, templates, modules=modules)
    planner.plan([TrafficClass(n, t) for n, t in tokens_classes])
    return planner


def test_swap_applied_at_batch_boundaries(engine):
    """With a swapper attached the engine actually materializes the
    selected plan per batch: plan_log and swap_log stay 1:1 across a
    multi-batch generate, and the repeat boundary is a cache hit."""
    from repro.serving import WidthSwapper

    eng, cfg = engine
    planner = _planner_with_swap(cfg)
    eng.planner = planner
    eng.swapper = WidthSwapper(eng.params, cfg)
    eng.plan_log.clear()
    eng.swap_log.clear()
    try:
        rng = np.random.default_rng(6)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=(8,))
                        .astype(np.int32), max_new_tokens=2)
                for _ in range(6)]   # > batch_slots=4 -> two batches
        out = eng.generate(reqs)
    finally:
        eng.planner = None
        eng.swapper = None
    assert len(out) == 6
    assert all((r.tokens < cfg.vocab_size).all() for r in out)
    assert len(eng.plan_log) == len(eng.swap_log) == 2
    for plan, ev in zip(eng.plan_log, eng.swap_log):
        assert ev.plan_name == plan.traffic.name
    # same traffic class both batches: the second swap is served from
    # the plan cache (zero new array allocations)
    assert not eng.swap_log[0].cache_hit
    assert eng.swap_log[1].cache_hit
    assert eng.swap_log[0].key == eng.swap_log[1].key


def test_full_width_plan_keeps_outputs_bit_identical(engine):
    """A swap to the full-width plan uses the canonical params object,
    so outputs match a planner-less engine exactly."""
    from repro.serving import (ServingWidthPlanner, TrafficClass,
                               WidthPlan, WidthSwapper, serving_templates)
    from repro.core import TPU_V5E

    eng, cfg = engine
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
    base = eng.generate([Request(prompt=prompt, max_new_tokens=6)])[0]

    _, modules = serving_templates(cfg, TPU_V5E, sites=("mlp",))
    planner = ServingWidthPlanner(TPU_V5E, [], modules=modules)
    planner.plans["full"] = WidthPlan(
        traffic=TrafficClass("full", 64), widths={}, latency_s=1.0,
        baseline_latency_s=1.0, satisfied=True, modules=modules)
    eng.planner = planner
    eng.swapper = WidthSwapper(eng.params, cfg)
    try:
        swapped = eng.generate([Request(prompt=prompt,
                                        max_new_tokens=6)])[0]
    finally:
        eng.planner = None
        eng.swapper = None
    np.testing.assert_array_equal(base.tokens, swapped.tokens)
    assert eng.swap_log and eng.swap_log[-1].realized


def test_narrowed_plan_serves_on_sliced_params(engine):
    """A genuinely narrowed plan reaches the hardware: the engine
    prefills and decodes on the sliced pytree (new jit specialization)
    and still produces valid tokens."""
    from repro.serving import (ServingWidthPlanner, TrafficClass,
                               WidthPlan, WidthSwapper, serving_templates)
    from repro.core import TPU_V5E

    eng, cfg = engine
    _, modules = serving_templates(cfg, TPU_V5E, sites=("mlp",))
    narrow = {name: cfg.d_ff // 2 for name in modules}
    planner = ServingWidthPlanner(TPU_V5E, [], modules=modules)
    planner.plans["narrow"] = WidthPlan(
        traffic=TrafficClass("narrow", 64), widths=narrow, latency_s=1.0,
        baseline_latency_s=2.0, satisfied=True, modules=modules)
    eng.planner = planner
    eng.swapper = WidthSwapper(eng.params, cfg)
    eng.swap_log.clear()
    try:
        rng = np.random.default_rng(8)
        prompt = rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
        out = eng.generate([Request(prompt=prompt, max_new_tokens=4)])[0]
    finally:
        eng.planner = None
        eng.swapper = None
    assert len(out.tokens) == 4
    assert (out.tokens < cfg.vocab_size).all()
    realized = dict(eng.swap_log[-1].realized)
    for name in narrow:
        assert realized[name] == cfg.d_ff // 2


def test_mixed_temperature_batch(engine):
    """Greedy slots in a mixed greedy/sampled batch must match a pure
    greedy run (the hoisted use_t/temp arrays select per slot)."""
    eng, cfg = engine
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=(8,)).astype(np.int32)
               for _ in range(3)]
    greedy = eng.generate([Request(prompt=p, max_new_tokens=6)
                           for p in prompts])
    mixed = eng.generate([
        Request(prompt=prompts[0], max_new_tokens=6),
        Request(prompt=prompts[1], max_new_tokens=6, temperature=1.0),
        Request(prompt=prompts[2], max_new_tokens=6),
    ])
    np.testing.assert_array_equal(mixed[0].tokens, greedy[0].tokens)
    np.testing.assert_array_equal(mixed[2].tokens, greedy[2].tokens)
    assert (mixed[1].tokens < cfg.vocab_size).all()
