"""Minimal stand-in for ``hypothesis`` on environments without it.

The repo's property tests use a small surface of hypothesis —
``given``/``settings`` and the ``integers``/``floats``/``sampled_from``/
``composite`` strategies.  When the real package is importable the test
modules use it; otherwise they fall back to this shim, which draws a fixed
number of pseudo-random examples from a deterministic per-test seed so the
suite still collects and exercises the properties on minimal environments.

This is NOT a replacement for hypothesis: there is no shrinking, no edge-case
bias, and no example database.  It exists so `pytest -q` works out of the box.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib


class _Strategy:
    """A strategy is just a draw function rng -> value."""

    def __init__(self, draw_fn):
        self._draw_fn = draw_fn

    def draw(self, rng: random.Random):
        return self._draw_fn(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def lists(element: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [element.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def composite(fn):
    """``@st.composite`` — fn's first arg becomes the draw callable."""

    def builder(*args, **kwargs):
        def draw_value(rng):
            return fn(lambda strat: strat.draw(rng), *args, **kwargs)
        return _Strategy(draw_value)

    return builder


def settings(max_examples: int = 20, deadline=None, **_kw):
    """Records max_examples on the test function for ``given`` to read."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 20))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)
        # propagate a max_examples set by an outer @settings
        if hasattr(fn, "_fallback_max_examples"):
            wrapper._fallback_max_examples = fn._fallback_max_examples
        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategies])
        del wrapper.__wrapped__
        return wrapper

    return deco


class _StrategiesModule:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)
    lists = staticmethod(lists)
    composite = staticmethod(composite)


st = _StrategiesModule()
