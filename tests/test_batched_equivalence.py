"""Batched-vs-scalar equivalence: the table-driven engine is a pure
refactor of the seed scalar path (frozen in repro.core.scalar_ref).

 * ``evaluate_batch`` rows must equal per-width scalar evaluation
   bit-for-bit — same float op order, so not approx: ``==``.
 * The table-driven Algorithm 2 must return identical widths and moves to
   the seed implementation on the same scenarios.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    LayerShape, TPU_LITE, TPU_V5E, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates, staircase_edges,
)
from repro.core.scalar_ref import (
    ScalarTailEffectOptimizer, ScalarWaveModel, scalar_evaluate,
)

HW = TPU_V5E
MODEL = WaveQuantizationModel(HW)
OPT = TailEffectOptimizer(MODEL)
SCALAR_OPT = ScalarTailEffectOptimizer(ScalarWaveModel(HW))


@st.composite
def layer_shapes(draw):
    return LayerShape(
        name="l",
        tokens=draw(st.integers(1, 10000)),
        d_in=draw(st.integers(1, 10000)),
        width=draw(st.integers(1, 50000)),
        shard_in=draw(st.sampled_from([1, 2, 4, 8, 16])),
        shard_out=draw(st.sampled_from([1, 2, 3, 4, 8, 16])),
        dtype_bits=draw(st.sampled_from([16, 32])),
        flop_multiplier=draw(st.sampled_from([1.0, 0.5, 3.0])),
    )


def make_tl(width, shard=16, tokens=4096, d_in=4096, name="l",
            min_width=1, max_width=None):
    layer = LayerShape(name, tokens=tokens, d_in=d_in, width=width,
                       shard_out=shard)
    cands = analytic_candidates(HW, layer, max_width=int(width * 1.6))
    return TunableLayer(layer=layer, candidates=cands, params_per_unit=d_in,
                        min_width=min_width, max_width=max_width)


@st.composite
def layer_sets(draw):
    n = draw(st.integers(2, 8))
    out = []
    for i in range(n):
        w = draw(st.integers(1024, 16384))
        min_w = draw(st.sampled_from([1, 2048]))
        max_w = draw(st.sampled_from([None, int(w * 1.3)]))
        out.append(make_tl(w, name=f"L{i}", min_width=min_w,
                           max_width=max_w))
    return out


class TestEvaluateBatchEquivalence:
    @given(layer=layer_shapes(), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_bit_for_bit(self, layer, seed):
        """Every StairTable row equals the scalar evaluation of that width —
        exact equality, not approx."""
        rng = np.random.default_rng(seed)
        widths = rng.integers(1, 60000, size=13)
        table = MODEL.evaluate_batch(layer, widths)
        for i, w in enumerate(widths):
            assert scalar_evaluate(HW, layer.with_width(int(w))) \
                == table.point(i)

    @given(layer=layer_shapes())
    @settings(max_examples=40, deadline=None)
    def test_evaluate_wrapper(self, layer):
        """``evaluate`` (thin wrapper) equals the scalar path."""
        assert MODEL.evaluate(layer) == scalar_evaluate(HW, layer)

    @given(layer=layer_shapes(), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_latency_batch_column(self, layer, seed):
        """``latency_batch`` is exactly the latency column of
        ``evaluate_batch``."""
        rng = np.random.default_rng(seed)
        widths = rng.integers(1, 60000, size=13)
        np.testing.assert_array_equal(
            MODEL.latency_batch(layer, widths),
            MODEL.evaluate_batch(layer, widths).latency_s)

    def test_other_hardware(self):
        m = WaveQuantizationModel(TPU_LITE)
        layer = LayerShape("l", tokens=32, d_in=48, width=1, shard_out=1)
        widths = np.arange(1, 400, 7)
        table = m.evaluate_batch(layer, widths)
        for i, w in enumerate(widths):
            assert scalar_evaluate(TPU_LITE, layer.with_width(int(w))) \
                == table.point(i)

    def test_staircase_edges_matches_scan(self):
        """Vectorized edge detection equals the historical Python scan."""
        layer = LayerShape("l", tokens=2048, d_in=1024, width=1,
                           shard_out=16)
        widths = np.arange(256, 8193, 256)
        table = MODEL.evaluate_batch(layer, widths)
        lat = table.latency_s
        scan = []
        for i in range(len(widths) - 1):
            if lat[i + 1] > lat[i] * (1 + 1e-9):
                scan.append(int(widths[i]))
        scan.append(int(widths[-1]))
        np.testing.assert_array_equal(
            staircase_edges(widths, lat), np.array(sorted(set(scan))))


class TestOptimizerParity:
    @given(layers=layer_sets(), tau_frac=st.floats(0.01, 0.2))
    @settings(max_examples=20, deadline=None)
    def test_latency_parity(self, layers, tau_frac):
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        a = SCALAR_OPT.optimize_latency(layers, tau=tau_frac * total_p,
                                        delta=0.95)
        b = OPT.optimize_latency(layers, tau=tau_frac * total_p, delta=0.95)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves
        assert a.latency_new_s == b.latency_new_s
        assert a.tau_final == b.tau_final
        assert a.satisfied == b.satisfied
        assert a.params_new == pytest.approx(b.params_new)

    @given(layers=layer_sets(),
           slack=st.sampled_from([0.0, 0.05, 0.3]))
    @settings(max_examples=20, deadline=None)
    def test_accuracy_parity(self, layers, slack):
        a = SCALAR_OPT.optimize_accuracy(layers, latency_slack=slack)
        b = OPT.optimize_accuracy(layers, latency_slack=slack)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves
        assert a.latency_new_s == b.latency_new_s

    # The deterministic scenarios from test_tail_optimizer.py, pinned to the
    # seed behaviour.
    def test_misaligned_scenario_parity(self):
        layers = [make_tl(2048 * k + 256, name=f"L{k}") for k in range(2, 6)]
        a = SCALAR_OPT.optimize_latency(layers, tau=1e9, delta=0.95)
        b = OPT.optimize_latency(layers, tau=1e9, delta=0.95)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves

    def test_aligned_scenario_parity(self):
        layers = [make_tl(2048 * k, name=f"L{k}") for k in range(2, 6)]
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        a = SCALAR_OPT.optimize_latency(layers, tau=0.05 * total_p,
                                        delta=0.99999)
        b = OPT.optimize_latency(layers, tau=0.05 * total_p, delta=0.99999)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves

    def test_fills_wave_scenario_parity(self):
        layers = [make_tl(11008)]
        a = SCALAR_OPT.optimize_accuracy(layers)
        b = OPT.optimize_accuracy(layers)
        assert b.new_widths["l"] == 12288   # right edge of wave 6 (seed pin)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves

    def test_tables_reused_across_rounds(self):
        """The tau-loosening rounds must not rebuild tables, and latency
        mode sweeps only the reachable one-step probes: at most the start
        width plus its Eq. 8a/8b neighbours per layer, once per
        optimize_latency call, however many rounds run."""
        model = WaveQuantizationModel(HW)
        opt = TailEffectOptimizer(model)
        layers = [make_tl(2048 * k, name=f"L{k}") for k in range(2, 6)]
        model.eval_calls = model.eval_points = 0
        opt.optimize_latency(layers, tau=1.0, delta=0.0)  # forces 8 rounds
        assert model.eval_points <= 3 * len(layers)
        assert model.eval_calls <= len(layers)

    def test_accuracy_full_table_points(self):
        """Accuracy mode with slack walks waves, so it sweeps the whole
        candidate table exactly once."""
        model = WaveQuantizationModel(HW)
        opt = TailEffectOptimizer(model)
        layers = [make_tl(2048 * k, name=f"L{k}") for k in range(2, 6)]
        model.eval_calls = model.eval_points = 0
        opt.optimize_accuracy(layers, latency_slack=0.2)
        assert model.eval_points == sum(
            len(tl.candidates) + 1 for tl in layers)
