"""Batched-vs-scalar equivalence: the table-driven engine is a pure
refactor of the seed scalar path (frozen in repro.core.scalar_ref).

 * ``evaluate_batch`` rows must equal per-width scalar evaluation
   bit-for-bit — same float op order, so not approx: ``==``.
 * The stacked model-level sweep (``evaluate_model_batch`` /
   ``latency_model_batch``) must equal per-layer ``evaluate_batch`` — and
   hence the scalar path — bit-for-bit, row by row.
 * The table-driven Algorithm 2 must return identical widths and moves to
   the seed implementation on the same scenarios, and the stacked table
   build must equal the historical per-group build.

One deliberate deviation from the seed is pinned here instead: the
latency-round revert now removes the down-Move itself (not whatever Move
is last), so ``OptimizationResult.moves`` always replays to
``new_widths`` — on both the scalar and table-driven paths.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    LayerShape, TPU_LITE, TPU_V5E, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates, staircase_edges,
)
from repro.core.scalar_ref import (
    ScalarTailEffectOptimizer, ScalarWaveModel, scalar_evaluate,
)

HW = TPU_V5E
MODEL = WaveQuantizationModel(HW)
OPT = TailEffectOptimizer(MODEL)
SCALAR_OPT = ScalarTailEffectOptimizer(ScalarWaveModel(HW))


@st.composite
def layer_shapes(draw):
    return LayerShape(
        name="l",
        tokens=draw(st.integers(1, 10000)),
        d_in=draw(st.integers(1, 10000)),
        width=draw(st.integers(1, 50000)),
        shard_in=draw(st.sampled_from([1, 2, 4, 8, 16])),
        shard_out=draw(st.sampled_from([1, 2, 3, 4, 8, 16])),
        dtype_bits=draw(st.sampled_from([16, 32])),
        flop_multiplier=draw(st.sampled_from([1.0, 0.5, 3.0])),
    )


def make_tl(width, shard=16, tokens=4096, d_in=4096, name="l",
            min_width=1, max_width=None):
    layer = LayerShape(name, tokens=tokens, d_in=d_in, width=width,
                       shard_out=shard)
    cands = analytic_candidates(HW, layer, max_width=int(width * 1.6))
    return TunableLayer(layer=layer, candidates=cands, params_per_unit=d_in,
                        min_width=min_width, max_width=max_width)


@st.composite
def layer_sets(draw):
    n = draw(st.integers(2, 8))
    out = []
    for i in range(n):
        w = draw(st.integers(1024, 16384))
        min_w = draw(st.sampled_from([1, 2048]))
        max_w = draw(st.sampled_from([None, int(w * 1.3)]))
        out.append(make_tl(w, name=f"L{i}", min_width=min_w,
                           max_width=max_w))
    return out


class TestEvaluateBatchEquivalence:
    @given(layer=layer_shapes(), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_bit_for_bit(self, layer, seed):
        """Every StairTable row equals the scalar evaluation of that width —
        exact equality, not approx."""
        rng = np.random.default_rng(seed)
        widths = rng.integers(1, 60000, size=13)
        table = MODEL.evaluate_batch(layer, widths)
        for i, w in enumerate(widths):
            assert scalar_evaluate(HW, layer.with_width(int(w))) \
                == table.point(i)

    @given(layer=layer_shapes())
    @settings(max_examples=40, deadline=None)
    def test_evaluate_wrapper(self, layer):
        """``evaluate`` (thin wrapper) equals the scalar path."""
        assert MODEL.evaluate(layer) == scalar_evaluate(HW, layer)

    @given(layer=layer_shapes(), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_latency_batch_column(self, layer, seed):
        """``latency_batch`` is exactly the latency column of
        ``evaluate_batch``."""
        rng = np.random.default_rng(seed)
        widths = rng.integers(1, 60000, size=13)
        np.testing.assert_array_equal(
            MODEL.latency_batch(layer, widths),
            MODEL.evaluate_batch(layer, widths).latency_s)

    def test_other_hardware(self):
        m = WaveQuantizationModel(TPU_LITE)
        layer = LayerShape("l", tokens=32, d_in=48, width=1, shard_out=1)
        widths = np.arange(1, 400, 7)
        table = m.evaluate_batch(layer, widths)
        for i, w in enumerate(widths):
            assert scalar_evaluate(TPU_LITE, layer.with_width(int(w))) \
                == table.point(i)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_stacked_model_batch_bit_for_bit(self, seed):
        """Every ``ModelStairTable`` row equals the per-layer
        ``evaluate_batch`` sweep (and therefore the scalar path) exactly,
        across heterogeneous shapes, ragged width vectors and the padded
        tail cells."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 9))
        layers, widths = [], []
        for i in range(n):
            layers.append(LayerShape(
                name=f"l{i}",
                tokens=int(rng.integers(1, 10000)),
                d_in=int(rng.integers(1, 10000)),
                width=1,
                shard_in=int(rng.choice([1, 2, 4, 8, 16])),
                shard_out=int(rng.choice([1, 2, 3, 4, 8, 16])),
                dtype_bits=int(rng.choice([16, 32])),
                flop_multiplier=float(rng.choice([1.0, 0.5, 3.0])),
            ))
            widths.append(rng.integers(1, 60000,
                                       size=int(rng.integers(0, 24))))
        stacked = MODEL.evaluate_model_batch(layers, widths)
        for i, (layer, w) in enumerate(zip(layers, widths)):
            per_layer = MODEL.evaluate_batch(layer, w)
            row = stacked.layer_table(i)
            for f in ("widths", "latency_s", "utilization", "throughput",
                      "waves", "flops", "padded_flops"):
                np.testing.assert_array_equal(
                    getattr(per_layer, f), getattr(row, f), err_msg=f)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_latency_model_batch_column(self, seed):
        """``latency_model_batch`` rows are exactly the per-layer
        ``latency_batch`` vectors."""
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 7))
        layers = [LayerShape(f"l{i}", tokens=int(rng.integers(1, 8192)),
                             d_in=int(rng.integers(1, 8192)), width=1,
                             shard_out=int(rng.choice([1, 4, 16])))
                  for i in range(n)]
        widths = [rng.integers(1, 50000, size=int(rng.integers(1, 17)))
                  for _ in range(n)]
        rows = MODEL.latency_model_batch(layers, widths)
        for layer, w, row in zip(layers, widths, rows):
            np.testing.assert_array_equal(MODEL.latency_batch(layer, w),
                                          row)

    def test_staircase_edges_matches_scan(self):
        """Vectorized edge detection equals the historical Python scan."""
        layer = LayerShape("l", tokens=2048, d_in=1024, width=1,
                           shard_out=16)
        widths = np.arange(256, 8193, 256)
        table = MODEL.evaluate_batch(layer, widths)
        lat = table.latency_s
        scan = []
        for i in range(len(widths) - 1):
            if lat[i + 1] > lat[i] * (1 + 1e-9):
                scan.append(int(widths[i]))
        scan.append(int(widths[-1]))
        np.testing.assert_array_equal(
            staircase_edges(widths, lat), np.array(sorted(set(scan))))


class TestOptimizerParity:
    @given(layers=layer_sets(), tau_frac=st.floats(0.01, 0.2))
    @settings(max_examples=20, deadline=None)
    def test_latency_parity(self, layers, tau_frac):
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        a = SCALAR_OPT.optimize_latency(layers, tau=tau_frac * total_p,
                                        delta=0.95)
        b = OPT.optimize_latency(layers, tau=tau_frac * total_p, delta=0.95)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves
        assert a.latency_new_s == b.latency_new_s
        assert a.tau_final == b.tau_final
        assert a.satisfied == b.satisfied
        assert a.params_new == pytest.approx(b.params_new)

    @given(layers=layer_sets(),
           slack=st.sampled_from([0.0, 0.05, 0.3]))
    @settings(max_examples=20, deadline=None)
    def test_accuracy_parity(self, layers, slack):
        a = SCALAR_OPT.optimize_accuracy(layers, latency_slack=slack)
        b = OPT.optimize_accuracy(layers, latency_slack=slack)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves
        assert a.latency_new_s == b.latency_new_s

    # The deterministic scenarios from test_tail_optimizer.py, pinned to the
    # seed behaviour.
    def test_misaligned_scenario_parity(self):
        layers = [make_tl(2048 * k + 256, name=f"L{k}") for k in range(2, 6)]
        a = SCALAR_OPT.optimize_latency(layers, tau=1e9, delta=0.95)
        b = OPT.optimize_latency(layers, tau=1e9, delta=0.95)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves

    def test_aligned_scenario_parity(self):
        layers = [make_tl(2048 * k, name=f"L{k}") for k in range(2, 6)]
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        a = SCALAR_OPT.optimize_latency(layers, tau=0.05 * total_p,
                                        delta=0.99999)
        b = OPT.optimize_latency(layers, tau=0.05 * total_p, delta=0.99999)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves

    def test_fills_wave_scenario_parity(self):
        layers = [make_tl(11008)]
        a = SCALAR_OPT.optimize_accuracy(layers)
        b = OPT.optimize_accuracy(layers)
        assert b.new_widths["l"] == 12288   # right edge of wave 6 (seed pin)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves

    def test_tables_reused_across_rounds(self):
        """The tau-loosening rounds must not rebuild tables, and latency
        mode sweeps only the reachable one-step probes: at most the start
        width plus its Eq. 8a/8b neighbours per layer, once per
        optimize_latency call, however many rounds run."""
        model = WaveQuantizationModel(HW)
        opt = TailEffectOptimizer(model)
        layers = [make_tl(2048 * k, name=f"L{k}") for k in range(2, 6)]
        model.eval_calls = model.eval_points = 0
        opt.optimize_latency(layers, tau=1.0, delta=0.0)  # forces 8 rounds
        assert model.eval_points <= 3 * len(layers)
        assert model.eval_calls <= len(layers)

    def test_accuracy_full_table_points(self):
        """Accuracy mode with slack walks waves, so it sweeps the whole
        candidate table exactly once."""
        model = WaveQuantizationModel(HW)
        opt = TailEffectOptimizer(model)
        layers = [make_tl(2048 * k, name=f"L{k}") for k in range(2, 6)]
        model.eval_calls = model.eval_points = 0
        opt.optimize_accuracy(layers, latency_slack=0.2)
        assert model.eval_points == sum(
            len(tl.candidates) + 1 for tl in layers)


class TestStackedBuildParity:
    """The stacked table build equals the historical per-group build —
    including the vectorized shared-grid prep path and the min/max width
    fences."""

    @staticmethod
    def _assert_tables_equal(a, b, full):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            assert x.name == y.name and x.pos == y.pos
            assert x.lo == y.lo and x.hi == y.hi
            assert x.start_down == y.start_down and x.start_up == y.start_up
            assert x.start_width == y.start_width
            assert x.start_lat == y.start_lat
            assert x.start_par == y.start_par
            if full:
                np.testing.assert_array_equal(x.lat, y.lat)
            else:
                assert x.lat == y.lat

    @given(layers=layer_sets(), full=st.sampled_from([False, True]))
    @settings(max_examples=15, deadline=None)
    def test_unshared_grids(self, layers, full):
        grouped = OPT._build_tables(layers, full=full, stacked=False)
        stacked = OPT._build_tables(layers, full=full, stacked=True)
        self._assert_tables_equal(grouped, stacked, full)

    @given(seed=st.integers(0, 2**31 - 1),
           full=st.sampled_from([False, True]))
    @settings(max_examples=15, deadline=None)
    def test_shared_grid_vectorized_prep(self, seed, full):
        """Layers handed the SAME candidates array object take the
        vectorized cursor-math path; fences and cursors must still match
        the scalar prep exactly."""
        rng = np.random.default_rng(seed)
        cands = analytic_candidates(
            HW, LayerShape("r", 4096, 4096, 26000, shard_out=16),
            max_width=26000)
        layers = []
        for i in range(int(rng.integers(4, 10))):
            w = int(rng.integers(1024, 25000))
            min_w = int(rng.choice([1, 2048, 30000]))
            max_w = [None, int(w * 1.3), 100][int(rng.integers(0, 3))]
            shape = LayerShape(f"L{i}", tokens=4096, d_in=4096, width=w,
                               shard_out=16)
            layers.append(TunableLayer(layer=shape, candidates=cands,
                                       params_per_unit=4096,
                                       min_width=min_w, max_width=max_w))
        assert all(tl.candidates is cands for tl in layers)
        grouped = OPT._build_tables(layers, full=full, stacked=False)
        stacked = OPT._build_tables(layers, full=full, stacked=True)
        self._assert_tables_equal(grouped, stacked, full)

    def test_empty_candidates(self):
        shape = LayerShape("e", tokens=128, d_in=128, width=700,
                           shard_out=1)
        layers = [TunableLayer(layer=shape,
                               candidates=np.array([], dtype=np.int64),
                               params_per_unit=128),
                  make_tl(4096, name="n")]
        for full in (False, True):
            grouped = OPT._build_tables(layers, full=full, stacked=False)
            stacked = OPT._build_tables(layers, full=full, stacked=True)
            self._assert_tables_equal(grouped, stacked, full)


class TestRevertMoveLog:
    """The latency-round revert removes the down-Move itself; ``moves``
    must replay from ``old_widths`` to exactly ``new_widths`` on both
    engines (this was the seed's move-log quirk, now fixed on both
    sides)."""

    @staticmethod
    def _replay(res):
        widths = dict(res.old_widths)
        for mv in res.moves:
            assert widths[mv.layer] == mv.old_width, \
                f"move log out of order for {mv.layer}"
            widths[mv.layer] = mv.new_width
        return widths

    @staticmethod
    def _corner_layers():
        """Two layers engineered so the balance loop applies an up-move
        AFTER the down-move and the window is still missed: the down-move
        must be reverted while the up-move stays."""
        q = HW.lane  # shard_out=1 -> quantum 128
        a = LayerShape("A", tokens=8192, d_in=8192, width=4133,
                       shard_out=1)
        b = LayerShape("B", tokens=1024, d_in=1024, width=2048,
                       shard_out=1)
        return [
            TunableLayer(layer=a,
                         candidates=analytic_candidates(HW, a,
                                                        max_width=6400),
                         params_per_unit=1000.0),
            TunableLayer(layer=b,
                         candidates=analytic_candidates(HW, b,
                                                        max_width=6400),
                         params_per_unit=200.0),
        ]

    def test_corner_revert_keeps_up_move(self):
        layers = self._corner_layers()
        # tau tiny: A's down-move (dp = -37 * 1000) cannot be balanced
        # into the window even after B's up-move (+128 * 200), so the
        # down-move reverts while B's up-move stays applied.
        res = OPT.optimize_latency(layers, tau=100.0, delta=0.0,
                                   max_rounds=1)
        assert res.new_widths["A"] == 4133          # reverted
        assert res.new_widths["B"] == 2176          # up-move kept
        kinds = [(m.layer, m.kind) for m in res.moves]
        assert ("A", "down") not in kinds
        assert ("B", "up") in kinds
        assert self._replay(res) == res.new_widths

    def test_corner_parity_scalar_vs_batched(self):
        layers = self._corner_layers()
        a = SCALAR_OPT.optimize_latency(layers, tau=100.0, delta=0.0,
                                        max_rounds=1)
        b = OPT.optimize_latency(layers, tau=100.0, delta=0.0,
                                 max_rounds=1)
        assert a.new_widths == b.new_widths
        assert a.moves == b.moves
        assert self._replay(a) == a.new_widths

    @given(layers=layer_sets(), tau_frac=st.floats(0.001, 0.2))
    @settings(max_examples=20, deadline=None)
    def test_moves_always_replay_to_widths(self, layers, tau_frac):
        total_p = sum(tl.params(tl.layer.width) for tl in layers)
        res = OPT.optimize_latency(layers, tau=tau_frac * total_p,
                                   delta=0.95)
        assert self._replay(res) == res.new_widths
