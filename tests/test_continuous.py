"""Continuous-batching engine: in-flight joins, boundary transactions,
fault recovery, drain ledgers.

The model-backed scenarios run on the same tiny reduced config as the
chaos tier, a virtual clock, and seeded injectors — every assertion is
exact (ledger sums, who recovered, run-twice equality), not statistical.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core import TPU_V5E as HW
from repro.models import init_params
from repro.models import transformer as tfm
from repro.serving import (
    AdmissionControl, Arrival, ContinuousServeEngine,
    DegradationController, DegradationLadder, Ledger, Request, ServeEngine,
    ServingWidthPlanner, TrafficClass, WidthPlan, WidthSwapper,
    WidthVariantCompileCache, serving_templates,
)
from repro.serving.chaos import (
    CompileFailureInjector, InjectedFault, ReshapeFailureInjector,
    SwapFailureInjector, TailReport, TrafficLoad, VirtualClock,
    class_tail_reports, modeled_batch_cost, open_loop_arrivals,
)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def reqs_for(cfg, lens, *, max_new=6, seed=0, deadline_s=None):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, size=(pl,))
                    .astype(np.int32), max_new_tokens=max_new,
                    deadline_s=deadline_s) for pl in lens]


# ---------------------------------------------------------------------------
# ragged decode: the mechanism continuous batching stands on
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestRaggedDecode:
    def test_vector_pos_matches_scalar_pos(self, setup):
        """decode_step with a uniform (B,) pos vector must bit-match the
        scalar-pos path — same math, different indexing."""
        cfg, params = setup
        B, plen = 3, 7
        rng = np.random.default_rng(1)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                           size=(B, plen)).astype(np.int32))
        _, st, _ = tfm.forward(params, cfg, tokens=prompts, mode="prefill")
        st = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, 0)] * (x.ndim - 3)
                              + [(0, 32 - x.shape[-3]), (0, 0), (0, 0)]),
            st)
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B,))
                          .astype(np.int32))
        scalar_logits, scalar_st = tfm.decode_step(
            params, cfg, tok, jnp.asarray(plen, jnp.int32), st)
        vec_logits, vec_st = tfm.decode_step(
            params, cfg, tok, jnp.full((B,), plen, jnp.int32), st)
        np.testing.assert_allclose(np.asarray(scalar_logits),
                                   np.asarray(vec_logits),
                                   rtol=1e-4, atol=1e-4)
        for a, b in zip(jax.tree_util.tree_leaves(scalar_st),
                        jax.tree_util.tree_leaves(vec_st)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    def test_ragged_rows_match_independent_runs(self, setup):
        """Each slot at its own position must decode exactly what that
        request would decode alone — no cross-slot leakage."""
        cfg, params = setup
        lens = (5, 9, 3)
        eng = ContinuousServeEngine(params, cfg, max_len=32, batch_slots=3)
        results = eng.run(reqs_for(cfg, lens, max_new=5, seed=2))
        solo = ServeEngine(params, cfg, max_len=32, batch_slots=1)
        expected = solo.generate(reqs_for(cfg, lens, max_new=5, seed=2))
        for got, want in zip(results, expected):
            assert np.array_equal(got.tokens, want.tokens)


# ---------------------------------------------------------------------------
# the engine: joins, leaves, ledgers
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestContinuousEngine:
    def test_requests_join_in_flight(self, setup):
        """More requests than slots: later requests join as earlier ones
        leave — no batch barrier, every submission accounted for."""
        cfg, params = setup
        eng = ContinuousServeEngine(params, cfg, max_len=32, batch_slots=2)
        results = eng.run(reqs_for(cfg, (4, 8, 5, 6, 3), max_new=4))
        assert eng.join_count == 5
        assert all(len(r.tokens) == 4 for r in results)
        led = eng.ledger()
        assert led.complete and led.finished == 5

    def test_short_request_not_blocked_by_long(self, setup):
        """Head-of-line: a 2-token request next to a 16-token request
        finishes first on the engine clock — the static engine's batch
        barrier would hold it until the long tail completes."""
        cfg, params = setup
        clock = VirtualClock()
        eng = ContinuousServeEngine(
            params, cfg, max_len=48, batch_slots=2, clock=clock,
            batch_cost_fn=modeled_batch_cost(1e-3))
        rng = np.random.default_rng(3)
        long = Request(prompt=rng.integers(0, cfg.vocab_size, size=(6,))
                       .astype(np.int32), max_new_tokens=16)
        short = Request(prompt=rng.integers(0, cfg.vocab_size, size=(6,))
                        .astype(np.int32), max_new_tokens=2)
        r_long, r_short = eng.run([long, short])
        assert r_short.latency_s < r_long.latency_s
        assert len(r_short.tokens) == 2 and len(r_long.tokens) == 16

    def test_arrivals_respect_virtual_time(self, setup):
        cfg, params = setup
        clock = VirtualClock()
        eng = ContinuousServeEngine(
            params, cfg, max_len=32, batch_slots=2, clock=clock,
            batch_cost_fn=modeled_batch_cost(1e-3))
        [req] = reqs_for(cfg, (4,), max_new=2)
        [res] = eng.run([Arrival(t=5.0, request=req)])
        # the engine fast-forwarded to the arrival; latency excludes the
        # idle wait before t=5
        assert clock() >= 5.0
        assert res.latency_s < 5.0

    def test_oversized_request_fails_not_hangs(self, setup):
        cfg, params = setup
        eng = ContinuousServeEngine(params, cfg, max_len=16, batch_slots=2)
        big = reqs_for(cfg, (14,), max_new=8)[0]     # 14 + 8 > 16
        ok = reqs_for(cfg, (4,), max_new=2, seed=5)[0]
        r_big, r_ok = eng.run([big, ok])
        assert r_big.failed and not r_ok.failed
        led = eng.ledger()
        assert led.complete and led.failed == 1 and led.finished == 1

    def test_watchdog_sheds_mid_decode(self, setup):
        """Deadline enforcement *during* decode: a request whose budget
        expires mid-stream is shed with its partial tokens."""
        cfg, params = setup
        clock = VirtualClock()
        eng = ContinuousServeEngine(
            params, cfg, max_len=48, batch_slots=2, clock=clock,
            batch_cost_fn=modeled_batch_cost(0.01))
        doomed = reqs_for(cfg, (6,), max_new=16, deadline_s=0.25)[0]
        fine = reqs_for(cfg, (6,), max_new=16, seed=7)[0]
        r_doomed, r_fine = eng.run([doomed, fine])
        assert r_doomed.shed and r_doomed.deadline_missed
        assert 0 < len(r_doomed.tokens) < 16      # partial, not dropped
        assert not r_fine.shed and len(r_fine.tokens) == 16
        assert eng.ledger().complete

    def test_admission_sheds_on_queue_cap(self, setup):
        cfg, params = setup
        clock = VirtualClock()
        eng = ContinuousServeEngine(
            params, cfg, max_len=32, batch_slots=2, clock=clock,
            admission=AdmissionControl(max_queue_batches=1),
            batch_cost_fn=modeled_batch_cost(1e-3))
        results = eng.run(reqs_for(cfg, (4,) * 12, max_new=8))
        led = eng.ledger()
        assert led.complete
        assert led.shed > 0 and led.finished > 0
        assert led.shed == sum(r.shed for r in results)

    def test_drain_ledger_is_complete(self, setup):
        """drain(): queue shed, in-flight finished, nothing unaccounted,
        and post-drain submissions are refused (shed)."""
        cfg, params = setup
        clock = VirtualClock()
        eng = ContinuousServeEngine(
            params, cfg, max_len=32, batch_slots=2, clock=clock,
            batch_cost_fn=modeled_batch_cost(1e-3))
        for r in reqs_for(cfg, (4,) * 6, max_new=8):
            eng.submit(r)
        eng.step()                 # some joined, some still queued
        led = eng.drain()
        assert led.complete and led.submitted == 6
        assert led.shed == 4       # 2 slots in flight, 4 queued -> shed
        assert led.finished == 2
        rid = eng.submit(reqs_for(cfg, (4,), seed=9)[0])
        assert eng.result(rid).shed
        assert eng.ledger().complete


# ---------------------------------------------------------------------------
# boundary transactions + recovery
# ---------------------------------------------------------------------------
def make_serving_stack(cfg, params, *, sites=("mlp",), deltas=(0.8, 0.6),
                       tokens=96):
    templates, modules = serving_templates(cfg, HW, tokens=tokens,
                                           sites=sites)
    planner = ServingWidthPlanner(HW, templates, modules=modules)
    traffic = [TrafficClass("burst", tokens)]
    planner.plan(traffic)
    ladder = DegradationLadder.build(planner, traffic, deltas=deltas)
    return planner, ladder


class _ScriptedSelector:
    """Deterministic stand-in for a DegradationController: returns the
    scripted plans in order, then holds the last one."""

    def __init__(self, plans):
        self.plans = list(plans)

    def select(self, tokens):
        plan = self.plans[0]
        if len(self.plans) > 1:
            self.plans.pop(0)
        return plan

    def observe(self, signal):
        return 0


@pytest.mark.slow
@pytest.mark.chaos
class TestBoundaryRecovery:
    def _narrow_and_full(self, cfg, planner, *, sites):
        narrow = planner.select(96)
        assert narrow.widths, "planner produced no narrowed plan"
        full = WidthPlan(traffic=narrow.traffic, widths={}, latency_s=0.0,
                         baseline_latency_s=0.0, satisfied=True,
                         modules=planner.modules)
        return narrow, full

    def test_reshape_fault_requeues_without_loss(self, setup):
        """A KV-reshape fault mid-boundary aborts the transaction: the
        canonical tree is restored, every in-flight request is requeued
        with its tokens intact, and the run finishes with zero lost."""
        cfg, params = setup
        planner, _ = make_serving_stack(cfg, params)
        narrow, _ = self._narrow_and_full(cfg, planner, sites=("mlp",))
        inj = ReshapeFailureInjector(1.0, seed=0)        # first boundary dies
        swapper = WidthSwapper(params, cfg, reshape_fault_hook=inj)
        clock = VirtualClock()
        eng = ContinuousServeEngine(
            params, cfg, max_len=48, batch_slots=2, clock=clock,
            planner=planner, swapper=swapper,
            batch_cost_fn=modeled_batch_cost(1e-3),
            max_retries=3, boundary_every=2, boundary_cooldown=1000)
        eng.planner = None
        eng.degrader = _ScriptedSelector([narrow])
        eng.admission = AdmissionControl(max_queue_batches=100)
        results = eng.run(reqs_for(cfg, (6, 6), max_new=8))
        assert inj.injected == 1
        [ev] = [b for b in eng.boundary_log if b.outcome == "reshape_failed"]
        assert ev.requeued == 2 and "InjectedFault" in ev.error
        # canonical-tree consistency after the abort: the cooldown keeps
        # the engine on the rolled-back state for the rest of the run
        assert eng.params_active is swapper.full_params
        led = eng.ledger()
        assert led.complete and led.finished == 2 and led.failed == 0
        for r in results:
            assert r.recovered and r.retries == 1
            assert len(r.tokens) == 8                    # nothing lost

    def test_swap_rollback_requeues_without_loss(self, setup):
        cfg, params = setup
        planner, _ = make_serving_stack(cfg, params)
        narrow, _ = self._narrow_and_full(cfg, planner, sites=("mlp",))
        inj = SwapFailureInjector(1.0, seed=0, steps=("materialize",))
        swapper = WidthSwapper(params, cfg, fault_hook=inj)
        clock = VirtualClock()
        eng = ContinuousServeEngine(
            params, cfg, max_len=48, batch_slots=2, clock=clock,
            planner=planner, swapper=swapper,
            batch_cost_fn=modeled_batch_cost(1e-3),
            max_retries=3, boundary_every=2, boundary_cooldown=1000)
        eng.planner = None
        eng.degrader = _ScriptedSelector([narrow])
        eng.admission = AdmissionControl(max_queue_batches=100)
        results = eng.run(reqs_for(cfg, (6, 6), max_new=8))
        assert eng.swap_log[0].outcome == "rolled_back"
        [ev] = [b for b in eng.boundary_log
                if b.outcome == "swap_rolled_back"]
        assert ev.requeued == 2
        assert eng.params_active is swapper.full_params
        assert eng.ledger().complete
        assert all(r.recovered and len(r.tokens) == 8 for r in results)

    def test_retry_budget_exhaustion_fails_loudly(self, setup):
        """Every boundary attempt fails and retries run out: requests end
        *failed*, in the ledger — never silently dropped."""
        cfg, params = setup
        planner, _ = make_serving_stack(cfg, params)
        narrow, _ = self._narrow_and_full(cfg, planner, sites=("mlp",))
        inj = ReshapeFailureInjector(1.0, seed=0)
        swapper = WidthSwapper(params, cfg, reshape_fault_hook=inj)
        eng = ContinuousServeEngine(
            params, cfg, max_len=48, batch_slots=2, clock=VirtualClock(),
            planner=planner, swapper=swapper,
            batch_cost_fn=modeled_batch_cost(1e-3),
            max_retries=1, boundary_every=2, boundary_cooldown=0)
        eng.planner = None
        eng.degrader = _ScriptedSelector([narrow])
        eng.admission = AdmissionControl(max_queue_batches=100)
        results = eng.run(reqs_for(cfg, (6, 6), max_new=8))
        led = eng.ledger()
        assert led.complete
        assert led.failed == 2 and led.finished == 0
        assert all(r.failed and r.retries == 2 for r in results)

    def _narrow_attn(self, cfg, planner):
        """A hand-built half-heads plan: the tiny reduced config is too
        small for Algorithm 2 to *choose* to narrow attention, but the
        boundary mechanics are what's under test."""
        base = planner.select(96)
        g = cfg.n_heads // max(cfg.n_kv_heads, 1)
        w = max(cfg.n_heads // 2, g) * cfg.head_dim
        return dataclasses.replace(
            base, widths={n: w for n in planner.modules})

    def test_shrink_boundary_carries_live_kv(self, setup):
        """An attention-narrowing boundary reshapes the live cache and
        decoding continues — no requeue, tokens keep flowing."""
        cfg, params = setup
        planner, _ = make_serving_stack(cfg, params, sites=("attn",))
        narrow = self._narrow_attn(cfg, planner)
        swapper = WidthSwapper(params, cfg)
        eng = ContinuousServeEngine(
            params, cfg, max_len=64, batch_slots=2, clock=VirtualClock(),
            planner=planner, swapper=swapper,
            batch_cost_fn=modeled_batch_cost(1e-3),
            boundary_every=3)
        eng.planner = None
        eng.degrader = _ScriptedSelector([narrow])
        eng.admission = AdmissionControl(max_queue_batches=100)
        results = eng.run(reqs_for(cfg, (6, 6), max_new=12))
        oks = [b for b in eng.boundary_log if b.outcome == "ok"]
        assert oks and all(b.requeued == 0 for b in oks)
        assert eng.ledger().complete
        assert all(not r.retries and len(r.tokens) == 12 for r in results)

    def test_grow_boundary_requeues_instead_of_zero_history(self, setup):
        """Shrink then grow with requests in flight: the grow crossing
        must requeue (re-prefill at the new width), never decode against
        zero-history head slots."""
        cfg, params = setup
        planner, _ = make_serving_stack(cfg, params, sites=("attn",))
        narrow = self._narrow_attn(cfg, planner)
        full = dataclasses.replace(narrow, widths={})
        swapper = WidthSwapper(params, cfg)
        eng = ContinuousServeEngine(
            params, cfg, max_len=64, batch_slots=2, clock=VirtualClock(),
            planner=planner, swapper=swapper,
            batch_cost_fn=modeled_batch_cost(1e-3),
            boundary_every=3)
        eng.planner = None
        eng.degrader = _ScriptedSelector([narrow, narrow, full])
        eng.admission = AdmissionControl(max_queue_batches=100)
        results = eng.run(reqs_for(cfg, (6, 6), max_new=16))
        grows = [b for b in eng.boundary_log if b.outcome == "requeued_grow"]
        assert grows and grows[0].requeued > 0
        led = eng.ledger()
        assert led.complete and led.failed == 0
        assert all(len(r.tokens) == 16 for r in results)
        assert any(r.recovered for r in results)


# ---------------------------------------------------------------------------
# load generation + tail reports (no model)
# ---------------------------------------------------------------------------
class TestOpenLoopLoad:
    LOADS = [TrafficLoad("steady", rate_rps=50.0, duration_s=2.0),
             TrafficLoad("spike", rate_rps=0.0, duration_s=2.0,
                         burst_at=0.5, burst_n=32)]

    def test_arrivals_are_seed_deterministic(self):
        a = open_loop_arrivals(self.LOADS, 256, seed=3)
        b = open_loop_arrivals(self.LOADS, 256, seed=3)
        assert [x.t for x in a] == [x.t for x in b]
        assert all(np.array_equal(x.request.prompt, y.request.prompt)
                   for x, y in zip(a, b))
        c = open_loop_arrivals(self.LOADS, 256, seed=4)
        assert [x.t for x in a] != [x.t for x in c]

    def test_arrivals_sorted_and_classed(self):
        arrivals = open_loop_arrivals(self.LOADS, 256, seed=0)
        ts = [a.t for a in arrivals]
        assert ts == sorted(ts)
        assert sum(a.klass == "spike" for a in arrivals) == 32
        assert all(a.t == 0.5 for a in arrivals if a.klass == "spike")
        assert all(0 < a.t < 2.0 for a in arrivals)

    def test_burst_outside_window_rejected(self):
        """A burst past its load's duration silently extended the run —
        now a loud schedule error."""
        bad = [TrafficLoad("late", rate_rps=1.0, duration_s=1.0,
                           burst_at=1.5, burst_n=4)]
        with pytest.raises(ValueError, match="outside its"):
            open_loop_arrivals(bad, 256, seed=0)

    def test_overlapping_spike_schedules_rejected(self):
        """Two classes spiking at the same instant interleave by list
        order, not by seed — refused so determinism can't silently
        depend on load declaration order."""
        bad = [TrafficLoad("a", rate_rps=0.0, duration_s=2.0,
                           burst_at=0.5, burst_n=8),
               TrafficLoad("b", rate_rps=0.0, duration_s=2.0,
                           burst_at=0.5, burst_n=8)]
        with pytest.raises(ValueError, match="overlapping spike"):
            open_loop_arrivals(bad, 256, seed=0)

    def test_tail_report_percentiles(self):
        from repro.serving import Result

        results = [Result(tokens=np.zeros(1, np.int32), steps=1,
                          latency_s=float(i)) for i in range(1, 1001)]
        results.append(Result(tokens=np.zeros(0, np.int32), steps=0,
                              shed=True))
        results.append(Result(tokens=np.zeros(0, np.int32), steps=0,
                              failed=True))
        rep = TailReport.build("t", results)
        assert rep.completed == 1000 and rep.shed == 1 and rep.failed == 1
        assert rep.p50_s == pytest.approx(500.5)
        assert rep.p99_s == pytest.approx(990.01)
        assert rep.p999_s == pytest.approx(999.001)
        empty = TailReport.build("e", [])
        assert np.isnan(empty.p50_s)

    def test_reshape_injector_seeded(self):
        def trace(seed):
            inj = ReshapeFailureInjector(0.4, seed=seed)
            out = []
            for _ in range(64):
                try:
                    inj()
                    out.append(False)
                except InjectedFault:
                    out.append(True)
            return out

        assert trace(2) == trace(2)
        assert trace(2) != trace(3)
        never = ReshapeFailureInjector(0.0)
        for _ in range(16):
            never()
        assert never.injected == 0 and never.calls == 16


# ---------------------------------------------------------------------------
# acceptance: 4x burst + both injectors, exact ledger, run-twice identical
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.chaos
class TestContinuousChaosScenario:
    @pytest.fixture(scope="class")
    def stack(self, setup):
        cfg, params = setup
        planner, ladder = make_serving_stack(cfg, params)
        return cfg, params, planner, ladder

    LOADS = [TrafficLoad("steady", rate_rps=40.0, duration_s=1.0,
                         prompt_len=8, max_new_tokens=8, deadline_s=2.0),
             TrafficLoad("spike", rate_rps=0.0, duration_s=1.0,
                         prompt_len=8, max_new_tokens=8, deadline_s=2.0,
                         burst_at=0.3, burst_n=48)]   # ~4x the steady rate

    def _run(self, stack):
        cfg, params, planner, ladder = stack
        swap_inj = SwapFailureInjector(0.3, seed=1, steps=("begin",))
        resh_inj = ReshapeFailureInjector(0.3, seed=2)
        swapper = WidthSwapper(params, cfg, fault_hook=swap_inj,
                               reshape_fault_hook=resh_inj)
        admission = AdmissionControl(max_queue_batches=3,
                                     target_batch_s=0.25,
                                     ewma_alpha=0.5, headroom=2.0)
        degrader = DegradationController(
            ladder, down_threshold=1.0, up_threshold=0.5,
            down_patience=4, up_patience=8, observe_every=4)
        eng = ContinuousServeEngine(
            params, cfg, max_len=48, batch_slots=4, planner=planner,
            swapper=swapper, admission=admission, degrader=degrader,
            clock=VirtualClock(),
            batch_cost_fn=modeled_batch_cost(1e-3, overhead_s=0.002),
            max_retries=3, boundary_every=4, boundary_cooldown=8)
        arrivals = open_loop_arrivals(self.LOADS, cfg.vocab_size, seed=5)
        results = eng.run(arrivals)
        ledger = eng.drain()
        return eng, swap_inj, resh_inj, arrivals, results, ledger

    def test_faults_fire_and_nothing_is_lost(self, stack):
        eng, swap_inj, resh_inj, arrivals, results, ledger = self._run(stack)
        assert swap_inj.injected >= 1 and resh_inj.injected >= 1
        aborted = [b for b in eng.boundary_log
                   if b.outcome in ("swap_rolled_back", "reshape_failed")]
        assert aborted and any(b.requeued > 0 for b in aborted)
        # the resilience claim: ledger sums exactly, zero silently lost
        assert ledger.complete
        assert ledger.submitted == len(arrivals)
        assert ledger.failed == 0
        assert sum(r.recovered for r in results) > 0
        # recovered requests still produced their full token budget
        for r in results:
            if r.recovered:
                assert len(r.tokens) == 8

    def test_degradation_engages_under_burst(self, stack):
        eng, *_ = self._run(stack)
        downs = [s for s in eng.degrader.shift_log
                 if s.direction == "down"]
        assert downs, "controller never downshifted under a 4x burst"
        assert any(b.outcome == "ok" for b in eng.boundary_log)

    def test_scenario_run_twice_is_identical(self, stack):
        def signature():
            eng, swap_inj, resh_inj, arrivals, results, ledger = \
                self._run(stack)
            reports = class_tail_reports(arrivals, results)
            return (
                [(r.shed, r.failed, r.retries, r.latency_s,
                  r.tokens.tolist()) for r in results],
                [b.outcome for b in eng.boundary_log],
                [s.direction for s in eng.degrader.shift_log],
                ledger,
                {k: dataclasses.astuple(v) for k, v in reports.items()},
            )

        assert signature() == signature()


# ---------------------------------------------------------------------------
# AOT compile cache in the serving hot path
# ---------------------------------------------------------------------------
def make_cached_engine(cfg, params, plan, *, cache=None, lens=(6, 6),
                       max_new=8):
    """Continuous engine + compile cache + scripted narrow plan — the
    shared rig for the AOT-serving scenarios."""
    cache = cache if cache is not None else WidthVariantCompileCache(cfg)
    swapper = WidthSwapper(params, cfg)
    eng = ContinuousServeEngine(
        params, cfg, max_len=48, batch_slots=2, clock=VirtualClock(),
        swapper=swapper, compile_cache=cache,
        batch_cost_fn=modeled_batch_cost(1e-3),
        max_retries=3, boundary_every=2, boundary_cooldown=1000)
    eng.planner = None
    eng.degrader = _ScriptedSelector([plan])
    eng.admission = AdmissionControl(max_queue_batches=100)
    return eng, cache, swapper


@pytest.mark.slow
@pytest.mark.chaos
class TestCompileCacheServing:
    def _narrow(self, cfg, params, *, sliced):
        """A planner-produced mlp-narrowing plan with its economics
        pinned: ``sliced=True`` makes the modeled saving dwarf one AOT
        compile (own executable), ``False`` makes it negligible (the
        zero-mask crossover)."""
        planner, _ = make_serving_stack(cfg, params)
        narrow = planner.select(96)
        assert narrow.widths
        if sliced:
            return dataclasses.replace(narrow, latency_s=0.5,
                                       baseline_latency_s=1.0)
        return dataclasses.replace(narrow, latency_s=0.999,
                                   baseline_latency_s=1.0)

    def test_warm_boundary_crossing_traces_nothing(self, setup):
        """The acceptance contract: after warm_compile, a serve run that
        crosses a width boundary performs zero jit traces — every
        prefill/decode is an AOT executable hit."""
        cfg, params = setup
        narrow = self._narrow(cfg, params, sliced=True)
        eng, cache, _ = make_cached_engine(cfg, params, narrow)
        warmed = eng.warm_compile([narrow], prefill_lengths=(6,))
        assert warmed > 0
        traced_at_warm = cache.tracer.count
        results = eng.run(reqs_for(cfg, (6, 6), max_new=8))
        assert cache.tracer.count == traced_at_warm   # ZERO new traces
        assert cache.stats["hits"] > 0
        assert any(b.outcome == "ok" for b in eng.boundary_log)
        assert eng.ledger().complete
        assert all(len(r.tokens) == 8 for r in results)

    def test_masked_crossover_runs_on_full_width_executable(self, setup):
        """An uneconomic plan realizes as zero-masked full-shape params:
        the boundary commits, but the cache stays addressed at the
        full-width key — no narrow executable is ever built."""
        cfg, params = setup
        narrow = self._narrow(cfg, params, sliced=False)
        eng, cache, _ = make_cached_engine(cfg, params, narrow)
        assert cache.decide(narrow) == "masked"
        eng.warm_compile([narrow], prefill_lengths=(6,))
        traced_at_warm = cache.tracer.count
        results = eng.run(reqs_for(cfg, (6, 6), max_new=8))
        assert cache.tracer.count == traced_at_warm
        assert any(b.outcome == "ok" for b in eng.boundary_log)
        assert eng._masked_active
        assert cache.active_key == cache.full_key
        # full-shape params throughout: the masked tree mirrors canonical
        canon = {tuple(x.shape)
                 for x in jax.tree_util.tree_leaves(params)}
        active = {tuple(x.shape)
                  for x in jax.tree_util.tree_leaves(eng.params_active)}
        assert active == canon
        assert eng.ledger().complete
        assert all(len(r.tokens) == 8 for r in results)

    def test_lookup_fault_serves_traced_with_zero_lost(self, setup):
        """Chaos: every serve-time executable fetch faults.  The engine
        must fall back to the traced path and finish every request with
        its full token budget — an AOT fault is never a lost request."""
        cfg, params = setup
        narrow = self._narrow(cfg, params, sliced=True)
        inj = CompileFailureInjector(1.0, steps=("lookup",))
        cache = WidthVariantCompileCache(cfg, fault_hook=inj)
        eng, cache, _ = make_cached_engine(cfg, params, narrow,
                                           cache=cache)
        eng.warm_compile([narrow], prefill_lengths=(6,))
        results = eng.run(reqs_for(cfg, (6, 6), max_new=8))
        assert inj.injected >= 1
        assert cache.stats["fallbacks"] >= 1
        assert cache.stats["hits"] == 0       # warm entries unreachable
        led = eng.ledger()
        assert led.complete and led.failed == 0
        assert all(len(r.tokens) == 8 for r in results)

    def test_compile_fault_serves_traced_with_zero_lost(self, setup):
        """Chaos: plan-time AOT compilation faults, so nothing is ever
        warm — the run degrades to the historical traced behavior."""
        cfg, params = setup
        narrow = self._narrow(cfg, params, sliced=True)
        inj = CompileFailureInjector(1.0, steps=("compile",))
        cache = WidthVariantCompileCache(cfg, fault_hook=inj)
        eng, cache, _ = make_cached_engine(cfg, params, narrow,
                                           cache=cache)
        assert eng.warm_compile([narrow], prefill_lengths=(6,)) == 0
        assert inj.injected >= 1 and len(cache) == 0
        results = eng.run(reqs_for(cfg, (6, 6), max_new=8))
        assert cache.stats["fallbacks"] >= 1
        led = eng.ledger()
        assert led.complete and led.failed == 0
        assert all(len(r.tokens) == 8 for r in results)


# ---------------------------------------------------------------------------
# pow2 prefill buckets: bounded trace count, unchanged tokens
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestPrefillBucketing:
    LENS = (3, 5, 6, 7, 9, 12)

    def _run(self, cfg, params, *, cache):
        eng = ContinuousServeEngine(
            params, cfg, max_len=48, batch_slots=2, clock=VirtualClock(),
            compile_cache=cache,
            batch_cost_fn=modeled_batch_cost(1e-3))
        results = eng.run(reqs_for(cfg, self.LENS, max_new=6))
        assert eng.ledger().complete
        return eng, [r.tokens.tolist() for r in results]

    def test_buckets_bound_traces(self, setup):
        """Six distinct prompt lengths land in two pow2 buckets {8, 16}:
        exactly 2 prefill traces + 1 decode trace, instead of one trace
        per distinct length — the grow-boundary retrace fix, pinned."""
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg)
        eng, _ = self._run(cfg, params, cache=cache)
        assert eng.prefill_bucketing          # default ON with a cache
        assert {eng._prefill_len(l) for l in self.LENS} == {8, 16}
        assert cache.tracer.count == 3        # 2 buckets + 1 decode shape

    def test_bucketed_tokens_match_unbucketed(self, setup):
        """Right-padded pow2 prefill is exact for global causal
        attention: the generated tokens are identical to the unbucketed
        engine's."""
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg)
        _, bucketed = self._run(cfg, params, cache=cache)
        _, plain = self._run(cfg, params, cache=None)
        assert bucketed == plain

    def test_explicit_bucketing_on_ineligible_config_raises(self, setup):
        cfg, params = setup
        local_cfg = dataclasses.replace(cfg, block_pattern=("local",),
                                        window=8)
        local_params = init_params(jax.random.PRNGKey(0), local_cfg)
        with pytest.raises(ValueError, match="prefill_bucketing"):
            ContinuousServeEngine(local_params, local_cfg, max_len=48,
                                  prefill_bucketing=True)


# ---------------------------------------------------------------------------
# chunked prefill: interleaving, checkpoints, recovery
# ---------------------------------------------------------------------------
class _OneShotChunkFault:
    """Raise InjectedFault on exactly the n-th chunk execution."""

    def __init__(self, at):
        self.at = int(at)
        self.calls = 0
        self.injected = 0

    def __call__(self):
        self.calls += 1
        if self.calls == self.at:
            self.injected += 1
            raise InjectedFault(f"injected chunk fault at call {self.at}")


@pytest.mark.slow
class TestChunkedPrefill:
    LENS = (5, 13, 27, 3, 21)

    def _run(self, cfg, params, *, chunk, budget=None, hook=None,
             max_retries=2):
        eng = ContinuousServeEngine(
            params, cfg, max_len=64, batch_slots=2, prefill_chunk=chunk,
            step_token_budget=budget, chunk_fault_hook=hook,
            max_retries=max_retries)
        results = eng.run(reqs_for(cfg, self.LENS, max_new=8))
        return eng, results

    def test_chunked_tokens_match_whole_prefill(self, setup):
        """Chunk-at-a-time prefill against the growing cache is exact
        for greedy decoding: every request's tokens match the
        whole-prompt prefill engine's, and the chunk count is exactly
        sum(ceil(plen / chunk))."""
        cfg, params = setup
        _, plain = self._run(cfg, params, chunk=None)
        eng, chunked = self._run(cfg, params, chunk=4, budget=8)
        for a, b in zip(plain, chunked):
            assert np.array_equal(a.tokens, b.tokens)
        assert eng.chunk_steps == sum(-(-l // 4) for l in self.LENS)
        assert eng.ledger().complete

    def test_chunk_fault_resumes_from_checkpoint(self, setup):
        """A fault mid-prefill requeues at the last committed chunk, not
        token zero: the total successful chunk count stays exactly
        sum(ceil(plen / chunk)) — no chunk re-executed — and the request
        finishes with identical tokens, marked recovered."""
        cfg, params = setup
        _, plain = self._run(cfg, params, chunk=None)
        hook = _OneShotChunkFault(4)      # mid-prefill of an early prompt
        eng, results = self._run(cfg, params, chunk=4, hook=hook)
        assert hook.injected == 1
        assert eng.chunk_log and eng.chunk_log[0].committed > 0
        assert eng.chunk_steps == sum(-(-l // 4) for l in self.LENS)
        for a, b in zip(plain, results):
            assert np.array_equal(a.tokens, b.tokens)
        assert sum(r.recovered for r in results) == 1
        assert eng.ledger().complete and eng.ledger().failed == 0

    def test_chunk_retry_budget_exhaustion_fails_loudly(self, setup):
        """Every chunk faulting forever: the request fails terminally
        after max_retries, accounted in the ledger — never a hang."""
        cfg, params = setup

        def always():
            raise InjectedFault("permanent chunk fault")

        eng = ContinuousServeEngine(
            params, cfg, max_len=64, batch_slots=2, prefill_chunk=4,
            chunk_fault_hook=always, max_retries=1)
        results = eng.run(reqs_for(cfg, (9,), max_new=4))
        assert results[0].failed and results[0].retries == 2
        led = eng.ledger()
        assert led.complete and led.failed == 1

    def test_chunk_on_ineligible_config_raises(self, setup):
        cfg, params = setup
        local_cfg = dataclasses.replace(cfg, block_pattern=("local",),
                                        window=8)
        local_params = init_params(jax.random.PRNGKey(0), local_cfg)
        with pytest.raises(ValueError, match="chunked prefill"):
            ContinuousServeEngine(local_params, local_cfg, max_len=48,
                                  prefill_chunk=4)

    def test_chunk_shapes_are_bounded_with_cache(self, setup):
        """With a compile cache the chunk executable shape set is the
        chunk plus pow2 tail buckets — bounded, AOT-warmable."""
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg)
        eng = ContinuousServeEngine(
            params, cfg, max_len=64, batch_slots=2, prefill_chunk=8,
            compile_cache=cache)
        eng.warm_compile([], prefill_lengths=self.LENS)
        traced_before = cache.tracer.count
        results = eng.run(reqs_for(cfg, self.LENS, max_new=4))
        assert eng.ledger().complete
        # decode is the only trace the serve loop should add on top of
        # the warmed chunk executables
        assert cache.tracer.count - traced_before <= 1
        assert all(len(r.tokens) == 4 for r in results)


class TestDrainFastPath:
    def test_drain_on_zero_submitted_engine(self, setup):
        """drain() before any submission returns the empty-but-complete
        ledger without stepping the engine at all — pinned (the guard
        keeps the zero-work drain from ever touching the model)."""
        cfg, params = setup
        eng = ContinuousServeEngine(params, cfg, max_len=32)
        led = eng.drain()
        assert led == Ledger(submitted=0, finished=0, shed=0, failed=0,
                             in_flight=0, queued=0, evicted=0)
        assert led.complete and eng.steps == 0

    def test_drain_after_completion_is_also_stepless(self, setup):
        cfg, params = setup
        eng = ContinuousServeEngine(params, cfg, max_len=32)
        eng.run(reqs_for(cfg, (4,), max_new=2))
        steps = eng.steps
        led = eng.drain()
        assert led.complete and led.finished == 1
        assert eng.steps == steps
