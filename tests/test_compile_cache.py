"""Width-variant AOT compile cache: keys, crossover, trace accounting,
fault fallback, and the autotuned-tile numerics contract.

The model-backed scenarios reuse the reduced serving config; every
assertion is exact (trace counts, stats dicts, bitwise logits), not
statistical.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core import TPU_V5E as HW
from repro.core.plan_address import plan_key
from repro.kernels import ops
from repro.models import init_params
from repro.models import transformer as tfm
from repro.serving import (
    TraceCounter, TrafficClass, WidthPlan, WidthSwapper,
    WidthVariantCompileCache, pow2_bucket, realized_exec_key,
    serving_templates,
)
from repro.serving.chaos import CompileFailureInjector, InjectedFault
from repro.serving.compile_cache import decode_state_struct


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def make_plan(widths, modules, *, tokens=96, latency_s=1.0,
              baseline_latency_s=2.0, name="t"):
    return WidthPlan(traffic=TrafficClass(name, tokens), widths=widths,
                     latency_s=latency_s,
                     baseline_latency_s=baseline_latency_s,
                     satisfied=True, modules=modules)


# ---------------------------------------------------------------------------
# pure units: buckets, trace counting, keys, crossover
# ---------------------------------------------------------------------------
class TestUnits:
    def test_pow2_bucket(self):
        assert pow2_bucket(1) == 8          # lo floor
        assert pow2_bucket(8) == 8
        assert pow2_bucket(9) == 16
        assert pow2_bucket(16) == 16
        assert pow2_bucket(17) == 32
        assert pow2_bucket(3, lo=1) == 4
        assert pow2_bucket(1000) == 1024

    def test_trace_counter_counts_traces_not_calls(self):
        tracer = TraceCounter()
        f = jax.jit(tracer.wrap(lambda x: x * 2))
        f(jnp.zeros((3,)))
        f(jnp.ones((3,)))                   # jit-cache hit: no trace
        assert tracer.count == 1
        f(jnp.zeros((4,)))                  # new shape: one more trace
        assert tracer.count == 2

    def test_realized_exec_key_distinct(self, setup):
        cfg, _ = setup
        cache = WidthVariantCompileCache(cfg)
        full = realized_exec_key(
            np.full(cfg.n_layers, cfg.d_ff),
            np.full(cfg.n_layers, cfg.n_heads))
        assert full == cache.full_key
        narrow = realized_exec_key(
            np.full(cfg.n_layers, 256), np.full(cfg.n_layers, cfg.n_heads))
        assert narrow != full
        # set_active(None) resets to the canonical full key
        cache.set_active(narrow)
        assert cache.active_key == narrow
        cache.set_active(None)
        assert cache.active_key == cache.full_key

    def test_decide_crossover(self, setup):
        cfg, _ = setup
        cache = WidthVariantCompileCache(cfg, compile_cost_s=0.25,
                                         horizon_batches=32)
        # saving over the horizon dwarfs one compile -> own executable
        big = make_plan({"mlp0": 256}, {}, latency_s=1.0,
                        baseline_latency_s=2.0)
        assert cache.decide(big) == "sliced"
        # saving (1 ms * 32) < 0.25 s -> masked onto the warm full path
        small = make_plan({"mlp0": 256}, {}, latency_s=0.999,
                          baseline_latency_s=1.0)
        assert cache.decide(small) == "masked"
        # the full-width plan has nothing to mask
        full = make_plan({}, {})
        assert cache.decide(full) == "sliced"

    def test_warm_plan_registry(self, setup):
        cfg, _ = setup
        cache = WidthVariantCompileCache(cfg)
        p = make_plan({"mlp0": 256}, {})
        q = make_plan({"mlp0": 384}, {})
        assert not cache.plan_is_warm(p)
        cache.mark_plan_warm(p)
        assert cache.plan_is_warm(p)
        assert not cache.plan_is_warm(q)
        assert plan_key(p.widths) != plan_key(q.widths)


# ---------------------------------------------------------------------------
# AOT executables: zero-trace warm path, traced fallback, faults
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestExecutables:
    def test_warm_prefill_zero_traces_and_matches_traced(self, setup):
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg)
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(1, 8)).astype(np.int32))
        assert cache.precompile("prefill", cache.full_key, (1, 8),
                                (params, toks))
        assert cache.stats["aot_compiles"] == 1
        traced_after_warm = cache.tracer.count   # lower() traced once
        out = cache.prefill(params, toks)
        out2 = cache.prefill(params, toks)
        assert cache.tracer.count == traced_after_warm  # zero new traces
        assert cache.stats["hits"] == 2
        ref_logits, _, _ = tfm.forward(params, cfg, tokens=toks,
                                       mode="prefill")
        np.testing.assert_array_equal(
            np.asarray(out[0].astype(jnp.float32)),
            np.asarray(ref_logits.astype(jnp.float32)))
        del out2

    def test_cold_lookup_falls_back_to_traced(self, setup):
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg)
        toks = jnp.zeros((1, 8), jnp.int32)
        logits, _, _ = cache.prefill(params, toks)
        assert logits.shape[:2] == (1, 8)
        assert cache.stats["misses"] == 1
        assert cache.tracer.count == 1           # the fallback traced

    def test_warm_decode_zero_traces(self, setup):
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg)
        b, max_len = 2, 32
        struct = decode_state_struct(cfg, b, max_len)
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.zeros((), jnp.int32)
        assert cache.precompile("decode", cache.full_key, (b,),
                                (params, tok, pos, struct))
        traced = cache.tracer.count
        states = tfm.init_decode_state(cfg, b, max_len)
        logits, new_states = cache.decode(params, tok, pos, states)
        assert cache.tracer.count == traced
        assert cache.stats["hits"] == 1
        assert logits.shape[0] == b
        jax.tree_util.tree_map(lambda a, s: None, new_states, states)

    def test_compile_fault_absorbed_and_served_traced(self, setup):
        cfg, params = setup
        inj = CompileFailureInjector(1.0, steps=("compile",))
        cache = WidthVariantCompileCache(cfg, fault_hook=inj)
        toks = jnp.zeros((1, 8), jnp.int32)
        assert not cache.precompile("prefill", cache.full_key, (1, 8),
                                    (params, toks))
        assert inj.injected >= 1
        assert cache.stats["fallbacks"] == 1
        assert len(cache) == 0
        assert cache.events[-1].outcome == "fault"
        logits, _, _ = cache.prefill(params, toks)   # traced path serves
        assert np.isfinite(
            np.asarray(logits.astype(jnp.float32))).all()

    def test_lookup_fault_absorbed_and_served_traced(self, setup):
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg)
        toks = jnp.zeros((1, 8), jnp.int32)
        assert cache.precompile("prefill", cache.full_key, (1, 8),
                                (params, toks))
        cache.fault_hook = CompileFailureInjector(1.0, steps=("lookup",))
        logits, _, _ = cache.prefill(params, toks)
        assert logits.shape[:2] == (1, 8)
        assert cache.stats["fallbacks"] == 1
        assert cache.stats["hits"] == 0

    def test_lru_bounds_executables(self, setup):
        cfg, params = setup
        cache = WidthVariantCompileCache(cfg, max_entries=1)
        t8 = jnp.zeros((1, 8), jnp.int32)
        t16 = jnp.zeros((1, 16), jnp.int32)
        cache.precompile("prefill", cache.full_key, (1, 8), (params, t8))
        cache.precompile("prefill", cache.full_key, (1, 16), (params, t16))
        assert len(cache) == 1               # oldest evicted


# ---------------------------------------------------------------------------
# masked realization: full-shape zero-masked params, distinct cache key
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestMaskedRealization:
    def test_masked_apply_keeps_canonical_shapes(self, setup):
        cfg, params = setup
        _, modules = serving_templates(cfg, HW, tokens=96, sites=("mlp",))
        swapper = WidthSwapper(params, cfg)
        plan = make_plan({f"mlp{i}": 256 for i in range(cfg.n_layers)},
                         modules)
        sliced, ev_s = swapper.apply(plan)
        masked, ev_m = swapper.apply(plan, masked=True)
        assert not ev_s.masked and ev_m.masked
        s_shapes = {tuple(x.shape)
                    for x in jax.tree_util.tree_leaves(sliced)}
        m_shapes = [tuple(x.shape)
                    for x in jax.tree_util.tree_leaves(masked)]
        f_shapes = [tuple(x.shape)
                    for x in jax.tree_util.tree_leaves(params)]
        assert m_shapes == f_shapes          # canonical shapes throughout
        assert s_shapes != set(m_shapes)     # the sliced tree is smaller
        # dropped channels really are zero: a masked forward cannot read
        # them even through a stale optimizer state
        w_up = masked["decoder"]["stack"]["u0"]["mlp"]["w_up"]
        assert not np.asarray(w_up[..., 256:]).any()
        assert np.asarray(w_up[..., :256]).any()

    def test_masked_and_sliced_use_distinct_swap_cache_keys(self, setup):
        cfg, params = setup
        _, modules = serving_templates(cfg, HW, tokens=96, sites=("mlp",))
        swapper = WidthSwapper(params, cfg)
        plan = make_plan({f"mlp{i}": 256 for i in range(cfg.n_layers)},
                         modules)
        a, _ = swapper.apply(plan, masked=True)
        b, _ = swapper.apply(plan)
        c, _ = swapper.apply(plan, masked=True)
        assert a is c                        # masked entry cached
        assert a is not b                    # and distinct from sliced

    def test_full_width_plan_ignores_masked_flag(self, setup):
        cfg, params = setup
        _, modules = serving_templates(cfg, HW, tokens=96, sites=("mlp",))
        swapper = WidthSwapper(params, cfg)
        plan = make_plan({}, modules)
        p, ev = swapper.apply(plan, masked=True)
        assert not ev.masked                 # nothing to mask at full width
        assert p is swapper.full_params


# ---------------------------------------------------------------------------
# autotuned tiles: sliced forward bit-for-bit vs default-tile forward
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.kernels
class TestAutotunedTileNumerics:
    def test_sliced_forward_bitwise_default_vs_autotuned(self, setup):
        """The acceptance contract for threading ``ops.*(hw=...)`` tiles
        through the model: on shapes where the contraction blocking
        coincides (single k-step, single kv-chunk), the autotuned-tile
        forward must be bit-for-bit with the default-tile forward —
        tiling the independent output axes differently is free."""
        cfg, params = setup
        _, modules = serving_templates(cfg, HW, tokens=96, sites=("mlp",))
        swapper = WidthSwapper(params, cfg)
        plan = make_plan({f"mlp{i}": 128 for i in range(cfg.n_layers)},
                         modules)
        sliced, _ = swapper.apply(plan)
        toks = jnp.asarray(np.random.default_rng(3).integers(
            0, cfg.vocab_size, size=(2, 16)).astype(np.int32))
        with ops.kernel_context(force="pallas_interpret"):
            base, _, _ = tfm.forward(sliced, cfg, tokens=toks,
                                     mode="prefill")
        with ops.kernel_context(hw=HW, force="pallas_interpret"):
            tuned, _, _ = tfm.forward(sliced, cfg, tokens=toks,
                                      mode="prefill")
        np.testing.assert_array_equal(
            np.asarray(base.astype(jnp.float32)),
            np.asarray(tuned.astype(jnp.float32)))

    def test_kernel_context_inert_in_ref_mode(self, setup):
        """Without a force override off-TPU, the context must not change
        numerics: the routed path is only taken when a kernel mode is
        actually active."""
        cfg, params = setup
        toks = jnp.asarray(np.random.default_rng(4).integers(
            0, cfg.vocab_size, size=(1, 8)).astype(np.int32))
        with jax.disable_jit():
            plain, _, _ = tfm.forward(params, cfg, tokens=toks,
                                      mode="prefill")
            with ops.kernel_context(hw=HW, force="ref"):
                ctxd, _, _ = tfm.forward(params, cfg, tokens=toks,
                                         mode="prefill")
        np.testing.assert_array_equal(
            np.asarray(plain.astype(jnp.float32)),
            np.asarray(ctxd.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# chaos injector unit
# ---------------------------------------------------------------------------
class TestCompileFailureInjector:
    def test_rate_one_raises_on_matching_step(self):
        inj = CompileFailureInjector(1.0, steps=("lookup",))
        inj("compile")                       # non-matching step: no-op
        with pytest.raises(InjectedFault):
            inj("lookup")
        assert inj.calls == 1 and inj.injected == 1  # only matching steps

    def test_rate_zero_never_raises(self):
        inj = CompileFailureInjector(0.0)
        for _ in range(20):
            inj("lookup")
        assert inj.injected == 0

    def test_unknown_step_rejected(self):
        with pytest.raises(ValueError):
            CompileFailureInjector(1.0, steps=("frobnicate",))
