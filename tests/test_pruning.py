"""Pruning baselines (HRank / SOFT criteria) + tail-aware discretization."""

import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st


from repro.core import pruning


class TestCriteria:
    def test_rank_scores_detect_informative_channels(self):
        """Channels with full-rank maps must outrank constant channels."""
        b, h, w, c = 4, 16, 16, 8
        rng = jax.random.PRNGKey(0)
        acts = jax.random.normal(rng, (b, h, w, c))
        acts = acts.at[..., :3].set(1.0)    # rank-1 (constant) channels
        scores = pruning.feature_map_rank_scores(acts)
        assert scores[:3].max() < scores[3:].min()

    def test_l2_scores(self):
        k = jnp.zeros((3, 3, 4, 6)).at[..., 0].set(10.0)
        s = pruning.l2_filter_scores(k)
        assert s[0] > s[1:].max()

    @given(keep=st.integers(1, 16))
    @settings(max_examples=10, deadline=None)
    def test_keep_indices(self, keep):
        scores = np.random.default_rng(0).standard_normal(16)
        idx = pruning.keep_indices(scores, keep)
        assert len(idx) == keep
        assert (np.diff(idx) > 0).all()
        dropped = np.setdiff1d(np.arange(16), idx)
        if len(dropped):
            assert scores[idx].min() >= scores[dropped].max()

    def test_soft_mask(self):
        scores = np.arange(8.0)
        m = pruning.soft_prune_mask(scores, 3)
        assert m.sum() == 3
        assert (m[-3:] == 1).all()


class TestPlans:
    def test_uniform_plan(self):
        plan = pruning.uniform_flops_plan({"a": 512, "b": 256}, 0.5)
        assert plan == {"a": 256, "b": 128}

    def test_build_plan(self):
        scores = {"a": np.arange(8.0), "b": np.arange(4.0)}
        plan = pruning.build_plan(lambda n: scores[n], {"a": 3, "b": 2})
        assert plan.widths == {"a": 3, "b": 2}
        np.testing.assert_array_equal(plan.indices["a"], [5, 6, 7])
