"""RG-LRU / RWKV6: fast parallel forms vs sequential oracles + decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st


from repro.models import recurrent as rec

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32) * scale


class TestRGLRU:
    def setup_method(self, _):
        self.p = rec.init_rglru(jax.random.PRNGKey(0), 32)

    def test_scan_vs_ref(self):
        x = rand(1, (2, 64, 32))
        y1, h1 = rec.rglru_scan(self.p, x)
        y2, h2 = rec.rglru_ref(self.p, x)
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-4)

    @given(t=st.sampled_from([8, 16, 33, 64]))
    @settings(max_examples=8, deadline=None)
    def test_state_carry_chains(self, t):
        """scan(x[:t1]) then scan(x[t1:]) == scan(x) (prefill chunking)."""
        x = rand(2, (1, t, 32))
        t1 = t // 2
        _, h_full = rec.rglru_scan(self.p, x)
        _, h_a = rec.rglru_scan(self.p, x[:, :t1])
        _, h_b = rec.rglru_scan(self.p, x[:, t1:], h_a)
        np.testing.assert_allclose(h_full, h_b, rtol=1e-4, atol=1e-4)

    def test_block_decode_matches_parallel(self):
        x = rand(3, (2, 8, 32)).astype(jnp.bfloat16)
        st0 = rec.rglru_init_state(2, 32)
        par, _ = rec.apply_rglru_block(self.p, x, state=st0)
        st_d, outs = st0, []
        for t in range(8):
            o, st_d = rec.apply_rglru_block(self.p, x[:, t:t + 1],
                                            state=st_d, decode=True)
            outs.append(o)
        seq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(seq, np.float32),
                                   np.asarray(par, np.float32),
                                   rtol=4e-2, atol=4e-2)

    @given(scale=st.floats(0.1, 5.0))
    @settings(max_examples=10, deadline=None)
    def test_stability(self, scale):
        """|a| < 1 by construction: long inputs never blow up."""
        x = rand(4, (1, 256, 32), scale)
        y, h = rec.rglru_scan(self.p, x)
        assert np.isfinite(np.asarray(y, np.float32)).all()
        assert np.abs(np.asarray(h)).max() < 1e4


class TestRWKV6:
    def test_chunked_vs_ref_various_chunks(self):
        b, t, h, dh = 2, 96, 2, 16
        r, k, v = rand(1, (b, t, h, dh)), rand(2, (b, t, h, dh)), \
            rand(3, (b, t, h, dh))
        lw = -jnp.exp(jnp.clip(rand(4, (b, t, h, dh)), -8, 1))
        u = rand(5, (h, dh), 0.1)
        o_ref, s_ref = rec.rwkv_ref(r, k, v, lw, u)
        for chunk in (8, 16, 32, 48):
            o, s = rec.rwkv_chunked(r, k, v, lw, u, chunk=chunk)
            np.testing.assert_allclose(o, o_ref, rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(s, s_ref, rtol=2e-4, atol=2e-4)

    def test_extreme_decay_is_stable(self):
        """Tiny per-step decay (log_w ~ -e^4) must not produce inf/nan —
        the chunked form only ever exponentiates non-positive numbers."""
        b, t, h, dh = 1, 64, 1, 8
        r, k, v = rand(1, (b, t, h, dh)), rand(2, (b, t, h, dh)), \
            rand(3, (b, t, h, dh))
        lw = jnp.full((b, t, h, dh), -50.0)
        u = rand(5, (h, dh), 0.1)
        o, s = rec.rwkv_chunked(r, k, v, lw, u, chunk=16)
        assert np.isfinite(np.asarray(o)).all()
        assert np.isfinite(np.asarray(s)).all()

    def test_timemix_decode_matches_chunked(self):
        d, h, dh = 32, 2, 16
        p = rec.init_rwkv(jax.random.PRNGKey(0), d, h, dh, 3 * d)
        x = rand(6, (2, 8, d)).astype(jnp.bfloat16)
        st0 = rec.rwkv_init_state(2, d, h, dh)
        par, _ = rec.apply_rwkv_timemix(
            p["rwkv"], x, state={"shift": st0["shift"], "s": st0["s"]})
        cur = {"shift": st0["shift"], "s": st0["s"]}
        outs = []
        for t in range(8):
            o, cur = rec.apply_rwkv_timemix(p["rwkv"], x[:, t:t + 1],
                                            state=cur, decode=True)
            outs.append(o)
        seq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(seq, np.float32),
                                   np.asarray(par, np.float32),
                                   rtol=4e-2, atol=4e-2)
