"""Differential staircase suite: fused kernel == batched engine == scalar.

Three independent implementations of the Eq. 3 staircase are compared on
randomized layers and width grids:

  * the frozen seed scalar path (``core.scalar_ref.scalar_evaluate``) —
    the ground truth;
  * the batched NumPy engine (``StairTable.evaluate_batch``), bit-for-bit
    with the scalar path by construction;
  * the fused affine-in-waves path (``backend="fused"`` and the Pallas
    kernel behind ``backend="pallas_interpret"`` /
    ``ops.staircase_latency``).

The fused factoring reassociates the float math, so latencies agree to
fp64 tolerance (a few ulp) rather than bit-for-bit — but wave counts are
integer-exact, within-stair latencies remain exactly equal (same wave
count -> same value), and therefore the staircase *edges* (the
optimizer's decision points) are identical.  The Pallas kernel computes
fp32 (what the TPU VPU would produce) and is compared at fp32 tolerance,
waves still exact.  Everything runs in interpret mode — no accelerator.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    LayerShape, TPU_V5E, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates, staircase_edges,
)
from repro.core.scalar_ref import scalar_evaluate
from repro.kernels.staircase_fused import (
    fused_columns, fused_latency, fused_staircase_reference,
)

pytestmark = pytest.mark.kernels

HW = TPU_V5E


@st.composite
def layer_shapes(draw):
    return LayerShape(
        name="l",
        tokens=draw(st.integers(1, 10000)),
        d_in=draw(st.integers(1, 10000)),
        width=draw(st.integers(1, 50000)),
        shard_in=draw(st.sampled_from([1, 2, 4, 8, 16])),
        shard_out=draw(st.sampled_from([1, 2, 3, 4, 8, 16])),
        dtype_bits=draw(st.sampled_from([16, 32])),
        flop_multiplier=draw(st.sampled_from([1.0, 0.5, 3.0])),
    )


def random_widths(seed, n_max=300, w_max=50000):
    rng = np.random.default_rng(seed)
    return rng.integers(1, w_max, size=int(rng.integers(1, n_max)))


class TestFusedVsBatchedVsScalar:
    @given(layer=layer_shapes(), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_fused_backend_matches_batched_and_scalar(self, layer, seed):
        widths = random_widths(seed)
        ref = WaveQuantizationModel(HW).evaluate_batch(layer, widths)
        fused = WaveQuantizationModel(HW, backend="fused") \
            .evaluate_batch(layer, widths)
        # Integer staircase structure: exact.
        assert np.array_equal(ref.waves, fused.waves)
        # Float columns: fp64 tolerance (reassociated math).
        np.testing.assert_allclose(fused.latency_s, ref.latency_s,
                                   rtol=1e-12, atol=0)
        np.testing.assert_allclose(fused.utilization, ref.utilization,
                                   rtol=1e-12, atol=0)
        np.testing.assert_allclose(fused.throughput, ref.throughput,
                                   rtol=1e-12, atol=0)
        # And the batched engine is itself bit-for-bit vs the seed scalar
        # path on a spot-checked width (the full property is pinned in
        # test_batched_equivalence.py).
        if widths.size:
            p = scalar_evaluate(HW, layer.with_width(int(widths[0])))
            assert p.latency_s == ref.latency_s[0]

    @given(layer=layer_shapes(), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_within_stair_latencies_stay_exactly_equal(self, layer, seed):
        """Widths on the same stair (same wave count) must produce EXACTLY
        the same fused latency — the property staircase_edges and the
        optimizer's tie-breaks rely on."""
        widths = random_widths(seed, n_max=150)
        fused = WaveQuantizationModel(HW, backend="fused") \
            .evaluate_batch(layer, widths)
        by_wave = {}
        for w, lat in zip(fused.waves, fused.latency_s):
            by_wave.setdefault(int(w), set()).add(float(lat))
        assert all(len(v) == 1 for v in by_wave.values())

    @given(layer=layer_shapes(), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_staircase_edges_identical(self, layer, seed):
        rng = np.random.default_rng(seed)
        lo = int(rng.integers(1, 20000))
        widths = np.arange(lo, lo + int(rng.integers(100, 1500)))
        ref = WaveQuantizationModel(HW).evaluate_batch(layer, widths)
        fused = WaveQuantizationModel(HW, backend="fused") \
            .evaluate_batch(layer, widths)
        assert np.array_equal(
            staircase_edges(widths, ref.latency_s),
            staircase_edges(widths, fused.latency_s))

    def test_degenerate_inputs_fall_back_exactly(self):
        """Outside the fused domain (widths < 1, non-byte-aligned dtype)
        every backend must return the exact numpy result bit-for-bit."""
        ref = WaveQuantizationModel(HW)
        for backend in ("fused", "pallas", "pallas_interpret"):
            model = WaveQuantizationModel(HW, backend=backend)
            for layer, widths in [
                (LayerShape("a", tokens=64, d_in=256, width=1),
                 np.array([-3, 0, 5, 130])),
                (LayerShape("b", tokens=64, d_in=256, width=1,
                            dtype_bits=7),
                 np.array([1, 127, 128, 129])),
                (LayerShape("c", tokens=64, d_in=256, width=1),
                 np.array([], dtype=np.int64)),
            ]:
                a = ref.evaluate_batch(layer, widths)
                b = model.evaluate_batch(layer, widths)
                assert np.array_equal(a.latency_s, b.latency_s)
                assert np.array_equal(a.waves, b.waves)

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            WaveQuantizationModel(HW, backend="cuda")


class TestStackedFused:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_model_batch_matches_per_layer(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 30))
        layers = [
            LayerShape(f"l{i}", tokens=int(rng.integers(1, 9000)),
                       d_in=int(rng.integers(1, 9000)), width=1,
                       shard_in=int(rng.choice([1, 2, 4, 8])),
                       shard_out=int(rng.choice([1, 2, 3, 8])),
                       dtype_bits=int(rng.choice([16, 32])),
                       flop_multiplier=float(rng.choice([1.0, 0.5, 3.0])))
            for i in range(n)
        ]
        widths = [rng.integers(1, 50000, size=int(rng.integers(1, 120)))
                  for _ in layers]
        ref = WaveQuantizationModel(HW).evaluate_model_batch(layers, widths)
        fused_model = WaveQuantizationModel(HW, backend="fused")
        fused = fused_model.evaluate_model_batch(layers, widths)
        assert np.array_equal(ref.waves, fused.waves)
        np.testing.assert_allclose(fused.latency_s, ref.latency_s,
                                   rtol=1e-12, atol=0)
        # latency-only packed path agrees with the full table
        lat = fused_model.latency_model_batch(layers, widths)
        for i, row in enumerate(lat):
            assert np.array_equal(row, fused.layer_table(i).latency_s)

    def test_mixed_stack_with_degenerate_rows(self):
        """A stack whose width matrix contains a non-positive entry must
        fall back to the exact core for the affected chunk."""
        layers = [LayerShape(f"l{i}", tokens=128, d_in=512, width=1)
                  for i in range(3)]
        widths = [[1, 128, 129], [0, 5, 7], [256, 257, 300]]
        ref = WaveQuantizationModel(HW).latency_model_batch(layers, widths)
        fused = WaveQuantizationModel(HW, backend="fused") \
            .latency_model_batch(layers, widths)
        for a, b in zip(ref, fused):
            assert np.array_equal(a, b)   # exact: numpy fallback path


class TestFusedOptimizerParity:
    def _tunables(self, seed, n=6):
        rng = np.random.default_rng(seed)
        out = []
        for i in range(n):
            w = int(rng.integers(1024, 16384))
            layer = LayerShape(f"L{i}", tokens=4096, d_in=4096, width=w,
                               shard_out=int(rng.choice([1, 8, 16])))
            cands = analytic_candidates(HW, layer,
                                        max_width=int(w * 1.6))
            out.append(TunableLayer(layer=layer, candidates=cands,
                                    params_per_unit=4096))
        return out

    @pytest.mark.parametrize("seed", [0, 7, 123])
    def test_fused_backend_same_optimizer_decisions(self, seed):
        """Algorithm 2 over fused tables returns the same widths/moves as
        over exact tables: within-stair equality + identical edges mean
        every comparison the optimizer makes resolves the same way."""
        ref_opt = TailEffectOptimizer(WaveQuantizationModel(HW))
        fused_opt = TailEffectOptimizer(
            WaveQuantizationModel(HW, backend="fused"))
        layers = self._tunables(seed)
        tau = 0.02 * sum(tl.params(tl.layer.width) for tl in layers)
        a = ref_opt.optimize_latency(self._tunables(seed), tau, delta=0.95)
        b = fused_opt.optimize_latency(self._tunables(seed), tau,
                                       delta=0.95)
        assert a.new_widths == b.new_widths
        assert [(m.layer, m.old_width, m.new_width) for m in a.moves] == \
               [(m.layer, m.old_width, m.new_width) for m in b.moves]
        c = ref_opt.optimize_accuracy(self._tunables(seed),
                                      latency_slack=0.05)
        d = fused_opt.optimize_accuracy(self._tunables(seed),
                                        latency_slack=0.05)
        assert c.new_widths == d.new_widths


class TestPallasKernel:
    """The fused sweep as an actual Pallas kernel, interpret mode."""

    @pytest.mark.parametrize("shape", [(1, 1), (3, 5), (8, 128),
                                       (13, 200), (40, 257)])
    def test_kernel_matches_fp64_reference(self, shape):
        rng = np.random.default_rng(42)
        rows, cols = shape
        w = rng.integers(1, 50000, size=(rows, cols))
        so, ca, mb, mc = fused_columns(
            HW, [LayerShape(f"l{i}", tokens=int(rng.integers(1, 5000)),
                            d_in=int(rng.integers(1, 5000)), width=1,
                            shard_out=int(rng.choice([1, 2, 8])))
                 for i in range(rows)])
        lat64, waves64, occ64 = fused_staircase_reference(
            w, so, ca, mb, mc, lane=HW.lane)
        from repro.kernels import ops
        lat32, waves32, occ32 = ops.staircase_latency(
            w, so, ca, mb, mc, lane=HW.lane, force="pallas_interpret")
        assert lat32.dtype == np.float32
        assert np.array_equal(waves64, waves32)        # ints: exact
        np.testing.assert_allclose(lat32, lat64, rtol=1e-5)
        np.testing.assert_allclose(occ32, occ64, rtol=1e-5)
        assert np.all(occ64 > 0) and np.all(occ64 <= 1.0)

    def test_model_backend_pallas_interpret(self):
        layer = LayerShape("l", tokens=512, d_in=1024, width=1,
                           shard_out=8)
        widths = np.arange(1, 700, 3)
        ref = WaveQuantizationModel(HW).evaluate_batch(layer, widths)
        ktab = WaveQuantizationModel(HW, backend="pallas_interpret") \
            .evaluate_batch(layer, widths)
        assert np.array_equal(ref.waves, ktab.waves)
        np.testing.assert_allclose(ktab.latency_s, ref.latency_s,
                                   rtol=1e-5)

    def test_ops_ref_dispatch_is_fp64_reference(self):
        """Off-TPU, force=None routes to the fp64 fused reference —
        bit-identical to fused_staircase_reference."""
        from repro.kernels import ops
        rng = np.random.default_rng(3)
        w = rng.integers(1, 9999, size=(4, 37))
        so = np.array([[1], [2], [8], [3]])
        ca = rng.random((4, 1)); mb = rng.random((4, 1))
        mc = rng.random((4, 1))
        a = fused_staircase_reference(w, so, ca, mb, mc, lane=HW.lane)
        b = ops.staircase_latency(w, so, ca, mb, mc, lane=HW.lane)
        for x, y in zip(a, b):
            assert np.array_equal(x, y)

    def test_kernel_rejects_non_2d(self):
        from repro.kernels.staircase_fused import staircase_fused_pallas
        with pytest.raises(ValueError, match="2-D"):
            staircase_fused_pallas(np.arange(5), [[1]], [[1.0]], [[1.0]],
                                   [[0.0]], lane=128, interpret=True)


class TestFusedHelpers:
    def test_fused_latency_out_buffer(self):
        w = np.arange(400, dtype=np.int64).reshape(2, -1) * 17 % 9999 + 1
        out = np.empty(w.shape)
        lat, nw = fused_latency(w, np.array([[1], [4]]),
                                np.array([[2.0], [3.0]]),
                                np.array([[1.0], [0.5]]),
                                np.array([[0.1], [0.2]]), lane=128,
                                out=out)
        assert lat is out
        lat2, nw2 = fused_latency(w, np.array([[1], [4]]),
                                  np.array([[2.0], [3.0]]),
                                  np.array([[1.0], [0.5]]),
                                  np.array([[0.1], [0.2]]), lane=128)
        assert np.array_equal(lat, lat2)
        assert np.array_equal(nw, nw2)

    def test_non_pow2_lane(self):
        w = np.arange(1, 500)
        lat, nw = fused_latency(w, 1, 1.0, 1.0, 0.0, lane=96,
                                all_so1=True)
        assert np.array_equal(nw, -(-w // 96))
