"""End-to-end training behaviour: learning, microbatching, checkpoint
restart (fault tolerance), quantized/kahan optimizers."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import init_params
from repro.train import (
    AdamWConfig, DataConfig, SyntheticLM, TrainConfig, adamw_init,
    adamw_update, build_train_step, checkpoint, cosine_schedule,
)
from repro.train.optim import dequantize_q8, quantize_q8

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow


def small_setup(arch="qwen1.5-0.5b", steps_lr=100, **tc_kw):
    cfg = reduced_config(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tc = TrainConfig(moe_strategy="dense", **tc_kw)
    lr = cosine_schedule(3e-3, 5, steps_lr)
    step = jax.jit(build_train_step(cfg, tc, lr))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=4))
    opt = adamw_init(params, tc.adamw)
    return cfg, params, opt, step, data


def run_steps(params, opt, step, data, n, start=0):
    losses = []
    for s in range(start, start + n):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        params, opt, m = step(params, opt, batch, jnp.asarray(s))
        losses.append(float(m["loss"]))
    return params, opt, losses


class TestLearning:
    def test_loss_decreases(self):
        cfg, params, opt, step, data = small_setup()
        _, _, losses = run_steps(params, opt, step, data, 30)
        assert min(losses[-5:]) < losses[0] - 0.2, losses[:3] + losses[-3:]

    def test_microbatch_equivalence(self):
        """Grad accumulation must match the monolithic batch step."""
        cfg, params, opt, step1, data = small_setup(microbatches=1)
        tc4 = TrainConfig(moe_strategy="dense", microbatches=4)
        step4 = jax.jit(build_train_step(cfg, tc4,
                                         cosine_schedule(3e-3, 5, 100)))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        p1, _, m1 = step1(params, opt, batch, jnp.asarray(0))
        p4, _, m4 = step4(params, adamw_init(params), batch,
                          jnp.asarray(0))
        l1 = jax.tree.leaves(p1)
        l4 = jax.tree.leaves(p4)
        for a, b in zip(l1, l4):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-3)

    def test_remat_modes_equivalent(self):
        cfg, params, opt, _, data = small_setup()
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        outs = {}
        for remat in ("none", "full", "sqrt"):
            tc = TrainConfig(moe_strategy="dense", remat=remat)
            step = jax.jit(build_train_step(cfg, tc,
                                            cosine_schedule(3e-3, 5, 100)))
            p, _, m = step(params, adamw_init(params), batch,
                           jnp.asarray(0))
            outs[remat] = float(m["loss"])
        assert outs["none"] == pytest.approx(outs["full"], rel=1e-4)
        assert outs["none"] == pytest.approx(outs["sqrt"], rel=1e-4)


class TestCheckpointRestart:
    def test_kill_and_resume_is_exact(self, tmp_path):
        """Train 10 steps w/ checkpoint at 5; restart from 5 and re-run
        5 more; params must match the uninterrupted run bit-exactly —
        node-failure recovery changes nothing."""
        cfg, params, opt, step, data = small_setup()
        # uninterrupted
        p_full, o_full, _ = run_steps(params, opt, step, data, 10)
        # interrupted
        p5, o5, _ = run_steps(params, opt, step, data, 5)
        checkpoint.save(str(tmp_path), 5, (p5, o5))
        del p5, o5
        latest = checkpoint.latest_step(str(tmp_path))
        assert latest == 5
        p_r, o_r = checkpoint.restore(str(tmp_path), 5,
                                      (params, adamw_init(params)))
        p_res, _, _ = run_steps(p_r, o_r, step, data, 5, start=5)
        for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_partial_save_is_ignored(self, tmp_path):
        cfg, params, opt, step, data = small_setup()
        checkpoint.save(str(tmp_path), 3, (params, opt))
        # simulate a crash mid-save: a .tmp dir without manifest
        os.makedirs(tmp_path / "step_7.tmp")
        (tmp_path / "step_7.tmp" / "arr_0.npy").write_bytes(b"garbage")
        assert checkpoint.latest_step(str(tmp_path)) == 3

    def test_gc_keeps_latest(self, tmp_path):
        cfg, params, opt, step, data = small_setup()
        for s in (1, 2, 3, 4, 5):
            checkpoint.save(str(tmp_path), s, (params, opt), keep=2)
        assert checkpoint.list_steps(str(tmp_path)) == [4, 5]


class TestOptimizers:
    def test_q8_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 128)) * 0.1
        q = quantize_q8(x)
        err = np.abs(np.asarray(dequantize_q8(q) - x))
        rowmax = np.abs(np.asarray(x)).max(-1, keepdims=True)
        assert (err <= rowmax / 127.0 + 1e-8).all()

    def test_q8_adam_converges(self):
        cfg, params, opt, _, data = small_setup()
        tc = TrainConfig(moe_strategy="dense",
                         adamw=AdamWConfig(quantize_moments=True))
        step = jax.jit(build_train_step(cfg, tc,
                                        cosine_schedule(3e-3, 5, 100)))
        opt = adamw_init(params, tc.adamw)
        _, _, losses = run_steps(params, opt, step, data, 25)
        assert min(losses[-5:]) < losses[0] - 0.15

    def test_kahan_bf16_tracks_f32(self):
        """bf16+Kahan master must stay close to the fp32 trajectory."""
        key = jax.random.PRNGKey(0)
        w32 = {"w": jax.random.normal(key, (32, 64)) * 0.1}
        w16 = jax.tree.map(lambda x: x.astype(jnp.bfloat16), w32)
        cfg32 = AdamWConfig(weight_decay=0.0)
        cfg16 = AdamWConfig(weight_decay=0.0, master_dtype="bf16_kahan")
        s32, s16 = adamw_init(w32, cfg32), adamw_init(w16, cfg16)
        for i in range(50):
            g = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                        (32, 64)) * 0.01}
            w32, s32, _ = adamw_update(g, s32, w32, jnp.asarray(1e-3),
                                       cfg32)
            w16, s16, _ = adamw_update(
                jax.tree.map(lambda x: x.astype(jnp.bfloat16), g),
                s16, w16, jnp.asarray(1e-3), cfg16)
        drift = np.abs(np.asarray(w16["w"], np.float32)
                       - np.asarray(w32["w"])).max()
        scale = np.abs(np.asarray(w32["w"])).max()
        assert drift < 0.05 * scale, (drift, scale)
        # without kahan, plain bf16 drifts measurably more
        w16n = jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                            {"w": jax.random.normal(key, (32, 64)) * 0.1})
        s16n = adamw_init(w16n, AdamWConfig(weight_decay=0.0))
        for i in range(50):
            g = {"w": jax.random.normal(jax.random.fold_in(key, i),
                                        (32, 64)) * 0.01}
            w16n, s16n, _ = adamw_update(
                jax.tree.map(lambda x: x.astype(jnp.bfloat16), g),
                s16n, w16n, jnp.asarray(1e-3),
                AdamWConfig(weight_decay=0.0))
        drift_nk = np.abs(np.asarray(w16n["w"], np.float32)
                          - np.asarray(w32["w"])).max()
        assert drift <= drift_nk + 1e-6
