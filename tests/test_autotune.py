"""Tile autotuner goldens: deterministic, wave-boundary-seeking, never
worse than the fixed defaults on the bench shapes, persisted via
ProfileTableCache."""

import dataclasses

import numpy as np
import pytest

from repro.core.hardware import TPU_LITE, TPU_V4, TPU_V5E
from repro.core.table_cache import ProfileTableCache
from repro.kernels import autotune
from repro.kernels.autotune import (
    TileConfig, autotune_flash_attention, autotune_matmul,
    autotune_moe_gmm, clear_memo,
)

pytestmark = pytest.mark.kernels

# Shapes the benchmarks/serving paths actually run (matmul M/N/K, flash
# (b, sq, skv, h, kv, dh), moe (e, c, d, f)).
BENCH_MATMUL = [(1024, 1024, 1024), (8192, 4096, 4096),
                (256, 8192, 2048), (4096, 11008, 4096)]
BENCH_FLASH = [(2, 1024, 1024, 8, 2, 128), (1, 4096, 4096, 16, 16, 64),
               (4, 512, 512, 8, 8, 128)]
BENCH_MOE = [(8, 256, 512, 1024), (16, 512, 1024, 2048)]


@pytest.fixture(autouse=True)
def _fresh_memo():
    clear_memo()
    yield
    clear_memo()


def _matmul_default_config(hw, m, n, k):
    """Score the historical fixed (256, 256, 512) default through the
    same cost model the autotuner uses."""
    from repro.kernels.autotune import _force_config, _matmul_config
    return _force_config(_matmul_config, hw, (m, n, k),
                         (min(256, m), min(256, n), min(512, k)), 16)


class TestDeterminism:
    @pytest.mark.parametrize("hw", [TPU_V5E, TPU_V4, TPU_LITE])
    def test_same_spec_same_tiles(self, hw):
        for shape in BENCH_MATMUL:
            a = autotune_matmul(hw, *shape)
            clear_memo()
            b = autotune_matmul(hw, *shape)
            assert a == b

    def test_golden_tiles_tpu_v5e(self):
        """Pin the selected tiles on the primary benchmark hardware: a
        change here means the cost model changed and must be deliberate
        (bump CACHE_VERSION if persisted tiles should invalidate)."""
        got = {shape: autotune_matmul(TPU_V5E, *shape).blocks
               for shape in BENCH_MATMUL}
        for shape, blocks in got.items():
            m, n, k = shape
            assert m % blocks[0] == 0 and n % blocks[1] == 0 \
                and k % blocks[2] == 0, (shape, blocks)
        # identical across repeated full enumerations too
        clear_memo()
        assert got == {shape: autotune_matmul(TPU_V5E, *shape).blocks
                       for shape in BENCH_MATMUL}

    def test_distinct_specs_may_differ_but_are_each_stable(self):
        a = autotune_matmul(TPU_V5E, 8192, 4096, 4096)
        b = autotune_matmul(TPU_LITE, 8192, 4096, 4096)
        # TPU_LITE's smaller VMEM must be respected by its choice.
        assert b.vmem_bytes <= TPU_LITE.vmem_bytes
        assert a.vmem_bytes <= TPU_V5E.vmem_bytes


class TestWaveBoundaries:
    def test_tail_free_chosen_when_one_exists(self):
        """Divisible bench shapes admit tail-free tilings within VMEM, and
        the autotuner must land on one: grid_blocks a multiple of the
        core count, no padded lanes."""
        for hw in (TPU_V5E, TPU_V4, TPU_LITE):
            for shape in BENCH_MATMUL:
                cfg = autotune_matmul(hw, *shape)
                assert cfg.tail_free, (hw, shape, cfg)
                assert cfg.grid_blocks % hw.cores_per_chip == 0
            for shape in BENCH_FLASH:
                cfg = autotune_flash_attention(hw, *shape)
                assert cfg.tail_free, (hw, shape, cfg)
            for shape in BENCH_MOE:
                cfg = autotune_moe_gmm(hw, *shape)
                assert cfg.tail_free, (hw, shape, cfg)

    def test_multi_core_spec_lands_full_waves(self):
        """With cores_per_chip > 1 the Eq. 3 wave boundary is non-trivial:
        the chosen grid must still fill whole waves when possible."""
        hw = dataclasses.replace(TPU_V5E, cores_per_chip=2)
        for shape in BENCH_MATMUL:
            cfg = autotune_matmul(hw, *shape)
            assert cfg.tail_free
            assert cfg.grid_blocks % 2 == 0
            assert cfg.waves == cfg.grid_blocks // 2

    def test_eq3_wave_accounting(self):
        cfg = autotune_matmul(TPU_V5E, 1024, 1024, 1024)
        assert cfg.grid_blocks == int(np.prod(cfg.grid))
        assert cfg.waves == -(-cfg.grid_blocks // TPU_V5E.cores_per_chip)

    def test_odd_shape_still_returns_valid_config(self):
        cfg = autotune_matmul(TPU_V5E, 100, 130, 70)
        assert not cfg.tail_free   # no divisor tiling exists in the space
        assert cfg.vmem_bytes <= TPU_V5E.vmem_bytes
        gm, gn, gk = cfg.grid
        bm, bn, bk = cfg.blocks
        assert gm * bm >= 100 and gn * bn >= 130 and gk * bk >= 70


class TestNeverRegress:
    def test_matmul_never_worse_than_fixed_defaults(self):
        for hw in (TPU_V5E, TPU_V4, TPU_LITE):
            for shape in BENCH_MATMUL:
                chosen = autotune_matmul(hw, *shape)
                default = _matmul_default_config(hw, *shape)
                assert chosen.latency_s <= default.latency_s + 1e-18, \
                    (hw, shape, chosen, default)

    def test_vmem_budget_respected(self):
        tiny = dataclasses.replace(TPU_V5E, vmem_bytes=1 << 20)
        for shape in BENCH_MATMUL:
            cfg = autotune_matmul(tiny, *shape)
            assert cfg.vmem_bytes <= tiny.vmem_bytes, (shape, cfg)


class TestPersistence:
    def test_tiles_roundtrip_through_cache(self, tmp_path):
        cache = ProfileTableCache(tmp_path)
        a = autotune_matmul(TPU_V5E, 8192, 4096, 4096, cache=cache)
        assert cache.stats.writes == 1
        clear_memo()
        b = autotune_matmul(TPU_V5E, 8192, 4096, 4096, cache=cache)
        assert b.blocks == a.blocks
        assert cache.stats.hits == 1
        assert cache.stats.writes == 1   # hit did not rewrite

    def test_cache_keys_distinguish_hw_kernel_shape(self, tmp_path):
        cache = ProfileTableCache(tmp_path)
        autotune_matmul(TPU_V5E, 1024, 1024, 1024, cache=cache)
        clear_memo()
        # Different hardware / shape / kernel: all misses, fresh writes.
        autotune_matmul(TPU_LITE, 1024, 1024, 1024, cache=cache)
        autotune_moe_gmm(TPU_V5E, 8, 256, 512, 1024, cache=cache)
        autotune_flash_attention(TPU_V5E, 2, 1024, 1024, 8, 2, 128,
                                 cache=cache)
        assert cache.stats.writes == 4

    def test_corrupt_tiles_entry_quarantined(self, tmp_path):
        cache = ProfileTableCache(tmp_path)
        autotune_matmul(TPU_V5E, 1024, 1024, 1024, cache=cache)
        clear_memo()
        (entry,) = list(tmp_path.glob("??/*.npz"))
        entry.write_bytes(b"garbage")
        cfg = autotune_matmul(TPU_V5E, 1024, 1024, 1024, cache=cache)
        assert isinstance(cfg, TileConfig)   # re-enumerated cleanly
        assert cache.stats.corrupted == 1
        assert cache.quarantined()


class TestOpsIntegration:
    """hw= on the ops wrappers resolves blocks through the autotuner and
    still produces correct outputs (interpret mode)."""

    def test_matmul_hw_dispatch(self):
        from repro.kernels import ops
        import jax.numpy as jnp
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((100, 130)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((130, 70)), jnp.float32)
        out = ops.matmul(x, w, hw=TPU_V5E, force="pallas_interpret")
        np.testing.assert_allclose(np.asarray(out), np.asarray(x) @
                                   np.asarray(w), rtol=2e-4, atol=2e-4)

    def test_moe_hw_dispatch(self):
        from repro.kernels import ops
        import jax.numpy as jnp
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((2, 24, 40)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((2, 40, 56)), jnp.float32)
        out = ops.moe_gmm(x, w, hw=TPU_V5E, force="pallas_interpret")
        ref = np.einsum("ecd,edf->ecf", np.asarray(x), np.asarray(w))
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4,
                                   atol=2e-4)
