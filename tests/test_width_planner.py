"""Serving width planner: per-traffic-class Algorithm 2 on the stacked
table engine, persistent across restarts via the profile-table cache."""

import numpy as np

from repro.core import (
    LayerShape, ProfileTableCache, TPU_V5E, TunableLayer,
    analytic_candidates,
)
from repro.serving import ServingWidthPlanner, TrafficClass

HW = TPU_V5E


def make_templates(n=6):
    """FFN stack templates at a reference token count, sharing one
    candidate grid (the vectorized-prep fast path)."""
    ref = LayerShape("ref", tokens=4096, d_in=4096, width=26000,
                     shard_out=16)
    cands = analytic_candidates(HW, ref, max_width=26000)
    out = []
    for i in range(n):
        shape = LayerShape(f"ffn{i}", tokens=4096, d_in=4096,
                           width=2048 * (i % 3 + 2) + 256, shard_out=16)
        out.append(TunableLayer(layer=shape, candidates=cands,
                                params_per_unit=4096))
    return out


class TestPlanner:
    TRAFFIC = [TrafficClass("decode", 256),
               TrafficClass("mixed", 4096),
               TrafficClass("prefill", 65536)]

    def test_plans_every_class(self):
        planner = ServingWidthPlanner(HW, make_templates())
        plans = planner.plan(self.TRAFFIC)
        assert set(plans) == {"decode", "mixed", "prefill"}
        for plan in plans.values():
            assert plan.latency_s <= plan.baseline_latency_s + 1e-15
            assert set(plan.widths) == {f"ffn{i}" for i in range(6)}

    def test_classes_get_distinct_plans(self):
        """The paper's core observation (Tables 4/5): no one-fit-all
        config — different token volumes move the compute/memory
        crossover, so at least one layer width should differ between the
        extreme classes."""
        planner = ServingWidthPlanner(HW, make_templates())
        plans = planner.plan(self.TRAFFIC)
        assert plans["decode"].widths != plans["prefill"].widths \
            or plans["decode"].latency_s != plans["prefill"].latency_s

    def test_select_nearest_class(self):
        planner = ServingWidthPlanner(HW, make_templates())
        planner.plan(self.TRAFFIC)
        assert planner.select(200).traffic.name == "decode"
        assert planner.select(5000).traffic.name == "mixed"
        assert planner.select(10**6).traffic.name == "prefill"

    def test_select_before_plan_raises(self):
        planner = ServingWidthPlanner(HW, make_templates())
        try:
            planner.select(100)
        except ValueError:
            pass
        else:
            raise AssertionError("expected ValueError")

    def test_retokened_classes_drop_measured_profiles(self):
        """A measured profile is only valid at its profiled token count:
        re-tokened traffic classes must fall back to the analytic model
        instead of silently reusing stale latencies (a same-tokens class
        may keep the profile)."""
        from repro.core import WaveQuantizationModel, tunable_from_profile
        from repro.core.profiler import analytic_profile

        shape = LayerShape("ffn", tokens=4096, d_in=4096, width=11008,
                           shard_out=16)
        q = 16 * HW.lane
        widths = np.unique(np.append(np.arange(q, 16385, q), shape.width))
        prof = analytic_profile(HW, shape, widths)
        tl = tunable_from_profile(shape, prof, params_per_unit=4096)
        planner = ServingWidthPlanner(HW, [tl])
        retok = planner._retokened(8192)
        assert retok[0].measured is None
        assert retok[0].layer.tokens == 8192
        same = planner._retokened(4096)
        assert same[0].measured is prof
        # end-to-end: a re-tokened class plans via the model, not the
        # stale profile
        plans = planner.plan([TrafficClass("prefill", 8192)])
        assert planner.model.eval_calls > 0
        assert plans["prefill"].baseline_latency_s > 0

    def test_warm_restart_skips_sweeps(self, tmp_path):
        """A restarted planner with the same cache performs zero model
        sweeps and reproduces the same plans (the cross-process
        profile-table reuse the cache exists for)."""
        cold = ServingWidthPlanner(HW, make_templates(),
                                   cache=ProfileTableCache(tmp_path))
        cold_plans = cold.plan(self.TRAFFIC)
        assert cold.model.eval_calls > 0

        warm = ServingWidthPlanner(HW, make_templates(),
                                   cache=ProfileTableCache(tmp_path))
        warm_plans = warm.plan(self.TRAFFIC)
        assert warm.model.eval_calls == 0
        assert {k: p.widths for k, p in warm_plans.items()} \
            == {k: p.widths for k, p in cold_plans.items()}
