"""Serving width planner: per-traffic-class Algorithm 2 on the stacked
table engine, persistent across restarts via the profile-table cache."""

import numpy as np
import pytest

from repro.core import (
    LayerShape, ModuleRef, ProfileTableCache, TPU_V5E, TunableLayer,
    analytic_candidates,
)
from repro.serving import (
    ServingWidthPlanner, TrafficClass, WidthPlan,
)

HW = TPU_V5E


def make_templates(n=6):
    """FFN stack templates at a reference token count, sharing one
    candidate grid (the vectorized-prep fast path)."""
    ref = LayerShape("ref", tokens=4096, d_in=4096, width=26000,
                     shard_out=16)
    cands = analytic_candidates(HW, ref, max_width=26000)
    out = []
    for i in range(n):
        shape = LayerShape(f"ffn{i}", tokens=4096, d_in=4096,
                           width=2048 * (i % 3 + 2) + 256, shard_out=16)
        out.append(TunableLayer(layer=shape, candidates=cands,
                                params_per_unit=4096))
    return out


class TestPlanner:
    TRAFFIC = [TrafficClass("decode", 256),
               TrafficClass("mixed", 4096),
               TrafficClass("prefill", 65536)]

    def test_plans_every_class(self):
        planner = ServingWidthPlanner(HW, make_templates())
        plans = planner.plan(self.TRAFFIC)
        assert set(plans) == {"decode", "mixed", "prefill"}
        for plan in plans.values():
            assert plan.latency_s <= plan.baseline_latency_s + 1e-15
            assert set(plan.widths) == {f"ffn{i}" for i in range(6)}

    def test_classes_get_distinct_plans(self):
        """The paper's core observation (Tables 4/5): no one-fit-all
        config — different token volumes move the compute/memory
        crossover, so at least one layer width should differ between the
        extreme classes."""
        planner = ServingWidthPlanner(HW, make_templates())
        plans = planner.plan(self.TRAFFIC)
        assert plans["decode"].widths != plans["prefill"].widths \
            or plans["decode"].latency_s != plans["prefill"].latency_s

    def test_select_nearest_class(self):
        planner = ServingWidthPlanner(HW, make_templates())
        planner.plan(self.TRAFFIC)
        assert planner.select(200).traffic.name == "decode"
        assert planner.select(5000).traffic.name == "mixed"
        assert planner.select(10**6).traffic.name == "prefill"

    def test_select_before_plan_raises(self):
        planner = ServingWidthPlanner(HW, make_templates())
        with pytest.raises(ValueError, match="no plans"):
            planner.select(100)

    def test_retokened_classes_drop_measured_profiles(self):
        """A measured profile is only valid at its profiled token count:
        re-tokened traffic classes must fall back to the analytic model
        instead of silently reusing stale latencies (a same-tokens class
        may keep the profile)."""
        from repro.core import WaveQuantizationModel, tunable_from_profile
        from repro.core.profiler import analytic_profile

        shape = LayerShape("ffn", tokens=4096, d_in=4096, width=11008,
                           shard_out=16)
        q = 16 * HW.lane
        widths = np.unique(np.append(np.arange(q, 16385, q), shape.width))
        prof = analytic_profile(HW, shape, widths)
        tl = tunable_from_profile(shape, prof, params_per_unit=4096)
        planner = ServingWidthPlanner(HW, [tl])
        retok = planner._retokened(8192)
        assert retok[0].measured is None
        assert retok[0].layer.tokens == 8192
        same = planner._retokened(4096)
        assert same[0].measured is prof
        # end-to-end: a re-tokened class plans via the model, not the
        # stale profile
        plans = planner.plan([TrafficClass("prefill", 8192)])
        assert planner.model.eval_calls > 0
        assert plans["prefill"].baseline_latency_s > 0

    def test_warm_restart_skips_sweeps(self, tmp_path):
        """A restarted planner with the same cache performs zero model
        sweeps and reproduces the same plans (the cross-process
        profile-table reuse the cache exists for)."""
        cold = ServingWidthPlanner(HW, make_templates(),
                                   cache=ProfileTableCache(tmp_path))
        cold_plans = cold.plan(self.TRAFFIC)
        assert cold.model.eval_calls > 0

        warm = ServingWidthPlanner(HW, make_templates(),
                                   cache=ProfileTableCache(tmp_path))
        warm_plans = warm.plan(self.TRAFFIC)
        assert warm.model.eval_calls == 0
        assert {k: p.widths for k, p in warm_plans.items()} \
            == {k: p.widths for k, p in cold_plans.items()}


def _dummy_plan(name, tokens, modules=None):
    return WidthPlan(traffic=TrafficClass(name, tokens), widths={},
                     latency_s=1.0, baseline_latency_s=1.0,
                     satisfied=True, modules=modules)


class TestSelectEdgeCases:
    """Boundary-time lookup corner cases: the engine calls select() on
    every batch, so its behavior at the edges must be pinned."""

    def _planner_with(self, plans):
        planner = ServingWidthPlanner(HW, [])
        for p in plans:
            planner.plans[p.traffic.name] = p
        return planner

    def test_tokens_zero_selects_smallest_class(self):
        """An empty/degenerate batch clamps to 1 token and lands on the
        smallest planned class instead of raising on log(0)."""
        planner = self._planner_with([_dummy_plan("small", 64),
                                      _dummy_plan("large", 65536)])
        assert planner.select(0).traffic.name == "small"
        assert planner.select(-3).traffic.name == "small"

    def test_log_scale_tie_resolves_to_first_planned(self):
        """Two classes at the same token volume are an exact
        log-distance tie; min() is stable, so the first-planned class
        wins deterministically (insertion order, not name order)."""
        planner = self._planner_with([_dummy_plan("b", 512),
                                      _dummy_plan("a", 512)])
        assert planner.select(512).traffic.name == "b"
        planner2 = self._planner_with([_dummy_plan("a", 512),
                                       _dummy_plan("b", 512)])
        assert planner2.select(512).traffic.name == "a"

    def test_zero_token_class_is_clamped(self):
        """A (degenerate) tokens=0 traffic class is clamped the same way
        as the query, not a log(0) crash."""
        planner = self._planner_with([_dummy_plan("zero", 0),
                                      _dummy_plan("big", 4096)])
        assert planner.select(1).traffic.name == "zero"

    def test_plan_stamps_modules_mapping(self):
        """Plans carry the planner's name->ModuleRef mapping so a
        WidthSwapper can materialize them."""
        modules = {"ffn0": ModuleRef(0, "mlp")}
        planner = ServingWidthPlanner(HW, make_templates(1),
                                      modules=modules)
        plans = planner.plan([TrafficClass("decode", 256)])
        assert plans["decode"].modules is modules


class TestTelemetry:
    """Observed-latency telemetry: the measurement half of a future
    closed re-planning loop, so its edge cases matter."""

    def test_unobserved_class_is_none(self):
        planner = ServingWidthPlanner(HW, [])
        assert planner.observed_percentile("ghost", 99) is None
        planner.record("real", 0.1)
        assert planner.observed_percentile("ghost", 99) is None

    def test_single_sample_is_every_percentile(self):
        planner = ServingWidthPlanner(HW, [])
        planner.record("decode", 0.25)
        for q in (0, 50, 99, 100):
            assert planner.observed_percentile("decode", q) \
                == pytest.approx(0.25)

    def test_percentile_q_is_clamped(self):
        """p99.9-style callers arrive via floats; q outside [0, 100]
        clamps to the extremes instead of raising."""
        planner = ServingWidthPlanner(HW, [])
        for v in (0.1, 0.2, 0.3):
            planner.record("decode", v)
        assert planner.observed_percentile("decode", 100.0001) \
            == pytest.approx(0.3)
        assert planner.observed_percentile("decode", -5) \
            == pytest.approx(0.1)
        assert planner.observed_percentile("decode", 99.9) \
            == pytest.approx(planner.observed_percentile("decode", 99.9))

    def test_record_memory_is_bounded(self):
        """A serving process records one sample per request forever; the
        window must cap per-class memory and keep the *latest* samples."""
        planner = ServingWidthPlanner(HW, [])
        planner.telemetry_window = 64
        for i in range(1000):
            planner.record("decode", float(i))
        assert len(planner.telemetry["decode"]) == 64
        assert planner.telemetry["decode"][0] == 936.0   # oldest kept
        assert planner.observed_percentile("decode", 0) == 936.0
        assert planner.observed_percentile("decode", 100) == 999.0
        # other classes are independent windows
        planner.record("prefill", 1.0)
        assert len(planner.telemetry["prefill"]) == 1


class _WarmStub:
    """compile-cache stand-in: just the warm-plan registry surface."""

    def __init__(self):
        from repro.core.plan_address import plan_key
        self._key = plan_key
        self._warm = set()

    def mark(self, plan):
        self._warm.add(self._key(plan.widths))

    def plan_is_warm(self, plan):
        return self._key(plan.widths) in self._warm


class TestTailAwareSelect:
    """Kernel-grid tie-breaks (goldens): with tile_hw, equal-latency
    widths are NOT equal — one wastes a partial wave (paper Eq. 3) or
    pays a trace at its first boundary.  Width 4096 on TPU v5e tiles
    tail-free at tokens=4096/d_in=4096; 4104 = 8*513 shares no lane-edge
    divisor, so every tiling leaves a remainder wave."""

    TAIL_FREE_W, TAIL_HEAVY_W = 4096, 4104

    def _plan(self, name, width):
        return WidthPlan(traffic=TrafficClass(name, 4096),
                         widths={"ffn0": width}, latency_s=1.0,
                         baseline_latency_s=2.0, satisfied=True,
                         modules={})

    def _planner(self, plans, **kw):
        planner = ServingWidthPlanner(HW, make_templates(1), **kw)
        for p in plans:
            planner.plans[p.traffic.name] = p
        return planner

    def test_tail_free_width_wins_tie_either_order(self):
        free = self._plan("free", self.TAIL_FREE_W)
        heavy = self._plan("heavy", self.TAIL_HEAVY_W)
        for order in ([heavy, free], [free, heavy]):
            planner = self._planner(order, tile_hw=HW)
            assert not planner.plan_tail_free(heavy)
            assert planner.plan_tail_free(free)
            assert planner.select(4096).traffic.name == "free"

    def test_without_tile_hw_historical_order_preserved(self):
        """tile_hw=None is the seed behavior, bit-for-bit: an exact tie
        resolves to the first-planned class no matter its grid."""
        free = self._plan("free", self.TAIL_FREE_W)
        heavy = self._plan("heavy", self.TAIL_HEAVY_W)
        planner = self._planner([heavy, free])
        assert planner.select(4096).traffic.name == "heavy"
        assert planner.plan_tail_free(heavy)      # trivially True: no hw

    def test_warm_executable_breaks_remaining_tie(self):
        """Both grids tail-free, one already AOT-warm: the warm plan
        wins — equal-latency widths differ by a first-boundary trace."""
        a = self._plan("cold", self.TAIL_FREE_W)
        b = self._plan("warm", 5120)              # also tail-free on v5e
        stub = _WarmStub()
        stub.mark(b)
        planner = self._planner([a, b], tile_hw=HW, compile_cache=stub)
        assert planner.plan_tail_free(a) and planner.plan_tail_free(b)
        assert planner.select(4096).traffic.name == "warm"

    def test_unknown_layer_names_are_skipped(self):
        """A hand-injected plan naming layers outside the template set
        can't be scored — it is treated as tail-free, not a KeyError."""
        planner = self._planner([], tile_hw=HW)
        ghost = WidthPlan(traffic=TrafficClass("g", 4096),
                          widths={"nope": 123}, latency_s=1.0,
                          baseline_latency_s=2.0, satisfied=True,
                          modules={})
        assert planner.plan_tail_free(ghost)

    def test_ladder_orders_equal_reduction_rungs_tail_first(self):
        """DegradationLadder.build(tile_hw=...) ranks equal-reduction
        rungs tail-free grids first and leaves the planner's own tile_hw
        untouched afterwards."""
        from repro.serving import DegradationLadder
        planner = ServingWidthPlanner(HW, make_templates())
        traffic = [TrafficClass("burst", 4096)]
        planner.plan(traffic)
        ladder = DegradationLadder.build(planner, traffic,
                                         deltas=(0.85, 0.7), tile_hw=HW)
        assert planner.tile_hw is None            # restored
        assert len(ladder) == 3
        reds = [r.reduction for r in ladder.rungs]
        assert reds == sorted(reds)
