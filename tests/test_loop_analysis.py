"""Loop-aware HLO cost analysis vs unrolled ground truth."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_loop_analysis import analyze, computation_multipliers


def compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


M = 128
FLOPS_ONE = 2.0 * M * M * M


class TestTripCounts:
    def test_scan_matmul(self):
        def f(x, ws):
            return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
        x = jax.ShapeDtypeStruct((M, M), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, M, M), jnp.float32)
        txt = compile_text(f, x, ws)
        cost = analyze(txt)
        assert cost.flops == pytest.approx(8 * FLOPS_ONE, rel=0.01)
        assert cost.flops_uncorrected == pytest.approx(FLOPS_ONE, rel=0.01)

    def test_nested_scan(self):
        def inner(c, w):
            return c @ w, None

        def outer(c, ws):
            c2, _ = jax.lax.scan(inner, c, ws)
            return c2, None

        def f(x, ws):
            # 3 outer x 4 inner = 12 matmuls
            return jax.lax.scan(outer, x, ws)[0]

        x = jax.ShapeDtypeStruct((M, M), jnp.float32)
        ws = jax.ShapeDtypeStruct((3, 4, M, M), jnp.float32)
        txt = compile_text(f, x, ws)
        cost = analyze(txt)
        assert cost.flops == pytest.approx(12 * FLOPS_ONE, rel=0.01)

    def test_unrolled_matches(self):
        def f(x, ws):
            for i in range(5):
                x = x @ ws[i]
            return x
        x = jax.ShapeDtypeStruct((M, M), jnp.float32)
        ws = jax.ShapeDtypeStruct((5, M, M), jnp.float32)
        txt = compile_text(f, x, ws)
        cost = analyze(txt)
        assert cost.flops == pytest.approx(5 * FLOPS_ONE, rel=0.01)
        assert cost.flops == pytest.approx(cost.flops_uncorrected, rel=0.01)

    def test_multipliers_fixpoint(self):
        def f(x, ws):
            return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
        x = jax.ShapeDtypeStruct((M, M), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, M, M), jnp.float32)
        txt = compile_text(f, x, ws)
        mult, comps = computation_multipliers(txt)
        assert max(mult.values()) >= 8


class TestAgainstCostAnalysis:
    def test_uncorrected_matches_xla(self):
        """Our once-counted FLOPs should track XLA's cost_analysis."""
        def f(x, ws):
            return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
        x = jax.ShapeDtypeStruct((M, M), jnp.float32)
        ws = jax.ShapeDtypeStruct((8, M, M), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = analyze(compiled.as_text())
        assert cost.flops_uncorrected == pytest.approx(
            float(ca["flops"]), rel=0.05)
