"""Chaos tier: seeded fault injection against the resilience layer.

Everything here is deterministic — injectors draw from generators seeded
at construction and scenario time runs on a virtual clock advanced by
modeled batch costs — so the assertions are exact (who was shed, which
swaps rolled back, the p99 to the float) rather than statistical.
Run alone with ``-m chaos``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    LayerShape, ProfileTableCache, TPU_V5E, TailEffectOptimizer,
    TunableLayer, WaveQuantizationModel, analytic_candidates,
)
from repro.serving import (
    AdmissionControl, DegradationController, DegradationLadder, Request,
    SWAP_STEPS, ServingWidthPlanner, TrafficClass, WidthSwapper,
    serving_templates,
)
from repro.serving.chaos import (
    CacheCorruptor, InjectedFault, LoadReport, SlowBatchInjector,
    SwapFailureInjector, VirtualClock, burst_requests, modeled_batch_cost,
)

pytestmark = pytest.mark.chaos

HW = TPU_V5E


# ---------------------------------------------------------------------------
# injectors: seeded determinism
# ---------------------------------------------------------------------------
class TestInjectors:
    def test_swap_injector_is_seed_deterministic(self):
        def trace(seed):
            inj = SwapFailureInjector(0.3, seed=seed, steps=("begin",))
            out = []
            for _ in range(64):
                try:
                    inj("begin")
                    out.append(False)
                except InjectedFault:
                    out.append(True)
            return out

        assert trace(7) == trace(7)
        assert trace(7) != trace(8)      # different seed, different faults

    def test_swap_injector_rates(self):
        always = SwapFailureInjector(1.0, steps=("materialize",))
        with pytest.raises(InjectedFault):
            always("materialize")
        never = SwapFailureInjector(0.0, steps=("materialize",))
        for _ in range(32):
            never("materialize")
        assert never.injected == 0
        # non-matching steps are free passes and don't consume draws
        always("begin")
        assert always.calls == 1

    def test_swap_injector_rejects_unknown_step(self):
        with pytest.raises(ValueError, match="unknown swap step"):
            SwapFailureInjector(1.0, steps=("explode",))

    def test_slow_batch_injector(self):
        slow = SlowBatchInjector(1.0, 0.25, seed=0)
        assert slow(0.1) == pytest.approx(0.35)
        none = SlowBatchInjector(0.0, 0.25, seed=0)
        assert none(0.1) == pytest.approx(0.1)
        a = SlowBatchInjector(0.5, 1.0, seed=3)
        b = SlowBatchInjector(0.5, 1.0, seed=3)
        assert [a(0.0) for _ in range(32)] == [b(0.0) for _ in range(32)]

    def test_virtual_clock(self):
        clk = VirtualClock(10.0)
        assert clk() == 10.0
        clk.advance(0.5)
        assert clk() == 10.5

    def test_virtual_clock_rejects_negative_dt(self):
        """A monotonic clock running backwards corrupts every latency
        downstream — advance() fails loudly instead."""
        clk = VirtualClock(1.0)
        with pytest.raises(ValueError, match="negative dt"):
            clk.advance(-0.1)
        assert clk() == 1.0               # untouched by the failed call

    def test_replica_stall_injector(self):
        from repro.serving.chaos import ReplicaStallInjector

        stall = ReplicaStallInjector(4.0, start_step=2, n_steps=2)
        assert stall(0.1) == pytest.approx(0.1)       # step 0: outside
        assert stall(0.1) == pytest.approx(0.1)       # step 1: outside
        assert stall(0.1) == pytest.approx(0.4)       # steps 2-3: stalled
        assert stall(0.1) == pytest.approx(0.4)
        assert stall(0.1) == pytest.approx(0.1)       # window closed
        assert stall.injected == 2
        with pytest.raises(ValueError):
            ReplicaStallInjector(0.5)                 # speedup, not stall

    def test_replica_crash_injector(self):
        from repro.serving.chaos import InjectedFault, ReplicaCrashInjector

        crash = ReplicaCrashInjector(at_step=2)
        assert crash(0.1) == pytest.approx(0.1)       # costed step 0
        assert crash(0.1) == pytest.approx(0.1)       # costed step 1
        with pytest.raises(InjectedFault, match="replica crash"):
            crash(0.1)                                # costed step 2
        assert crash.injected == 1
        a = ReplicaCrashInjector(rate=0.3, seed=7)
        b = ReplicaCrashInjector(rate=0.3, seed=7)

        def trace(inj):
            out = []
            for _ in range(32):
                try:
                    inj(0.1)
                    out.append(0)
                except InjectedFault:
                    out.append(1)
            return out

        ta, tb = trace(a), trace(b)
        assert ta == tb and sum(ta) > 0

    def test_chunk_fault_injector_seeded(self):
        from repro.serving.chaos import ChunkFaultInjector, InjectedFault

        def trace(seed):
            inj = ChunkFaultInjector(0.25, seed=seed)
            out = []
            for _ in range(64):
                try:
                    inj()
                    out.append(0)
                except InjectedFault:
                    out.append(1)
            return out

        assert trace(3) == trace(3)
        assert sum(trace(3)) > 0
        assert trace(3) != trace(4)

    def test_modeled_batch_cost_uses_plan_ratio(self):
        from repro.serving import WidthPlan

        cost = modeled_batch_cost(1e-3, overhead_s=0.01)
        assert cost(None, 100) == pytest.approx(0.11)
        plan = WidthPlan(traffic=TrafficClass("t", 100), widths={},
                         latency_s=0.5, baseline_latency_s=1.0,
                         satisfied=True)
        assert cost(plan, 100) == pytest.approx(0.01 + 0.1 * 0.5)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
class TestAdmissionControl:
    def test_ewma_tracks_batches(self):
        ac = AdmissionControl(ewma_alpha=0.5)
        ac.observe(0.1)
        assert ac.batch_ewma == pytest.approx(0.1)
        ac.observe(0.3)
        assert ac.batch_ewma == pytest.approx(0.2)

    def test_cold_start_admits_deadline_requests(self):
        ac = AdmissionControl(max_queue_batches=2)
        r = Request(prompt=np.zeros(4, np.int32), deadline_s=0.01)
        assert ac.admit(r, now=0.0, arrival=0.0, backlog_batches=0)

    def test_deadline_projection_sheds(self):
        ac = AdmissionControl(headroom=2.0, ewma_alpha=1.0)
        ac.observe(0.1)
        r = Request(prompt=np.zeros(4, np.int32), deadline_s=0.5)
        # elapsed 0.2 + 2*0.1 projected = 0.4 <= 0.5: admit
        assert ac.admit(r, now=0.2, arrival=0.0, backlog_batches=0)
        # elapsed 0.4 + 0.2 projected = 0.6 > 0.5: shed
        assert not ac.admit(r, now=0.4, arrival=0.0, backlog_batches=0)
        assert ac.admitted == 1 and ac.shed == 1

    def test_queue_cap_sheds_deadline_less(self):
        ac = AdmissionControl(max_queue_batches=2)
        r = Request(prompt=np.zeros(4, np.int32))
        assert ac.admit(r, now=0.0, arrival=0.0, backlog_batches=2)
        assert not ac.admit(r, now=0.0, arrival=0.0, backlog_batches=3)

    def test_signal_is_max_of_depth_and_latency(self):
        ac = AdmissionControl(max_queue_batches=4, target_batch_s=0.2)
        assert ac.signal(2) == pytest.approx(0.5)      # depth only (cold)
        ac.observe(0.3)                                 # ewma = 0.3
        assert ac.signal(2) == pytest.approx(1.5)      # latency dominates
        assert ac.signal(8) == pytest.approx(2.0)      # depth dominates


# ---------------------------------------------------------------------------
# degradation ladder + controller (planner on synthetic templates)
# ---------------------------------------------------------------------------
def make_planner(n=4):
    ref = LayerShape("ref", tokens=4096, d_in=4096, width=26000,
                     shard_out=16)
    cands = analytic_candidates(HW, ref, max_width=26000)
    layers = []
    for i in range(n):
        shape = LayerShape(f"ffn{i}", tokens=4096, d_in=4096,
                           width=2048 * (i % 3 + 2) + 256, shard_out=16)
        layers.append(TunableLayer(layer=shape, candidates=cands,
                                   params_per_unit=4096))
    return ServingWidthPlanner(HW, layers)


TRAFFIC = [TrafficClass("decode", 256), TrafficClass("prefill", 65536)]


class TestDegradationLadder:
    def test_rung0_is_full_width_and_rungs_ranked(self):
        ladder = DegradationLadder.build(make_planner(), TRAFFIC,
                                         deltas=(0.6, 0.9))
        assert len(ladder) == 3
        assert all(p.widths == {} for p in ladder.rung(0).plans.values())
        reds = [r.reduction for r in ladder.rungs]
        assert reds == sorted(reds)        # ranked by latency_reduction
        assert reds[0] == 0.0
        # every rung plans every traffic class
        for rung in ladder.rungs:
            assert set(rung.plans) == {"decode", "prefill"}

    def test_rung_clamps_and_class_lookup(self):
        ladder = DegradationLadder.build(make_planner(), TRAFFIC,
                                         deltas=(0.8,))
        assert ladder.rung(99) is ladder.rungs[-1]
        assert ladder.rung(-1) is ladder.rungs[0]
        assert ladder.rung(0).plan_for(100).traffic.name == "decode"
        assert ladder.rung(0).plan_for(10**6).traffic.name == "prefill"

    def test_empty_inputs_raise(self):
        with pytest.raises(ValueError, match="traffic"):
            DegradationLadder.build(make_planner(), [])
        with pytest.raises(ValueError, match="empty"):
            DegradationLadder([])


class TestDegradationController:
    def _controller(self, **kw):
        kw.setdefault("down_patience", 2)
        kw.setdefault("up_patience", 3)
        ladder = DegradationLadder.build(make_planner(), TRAFFIC,
                                         deltas=(0.8, 0.6))
        return DegradationController(ladder, **kw)

    def test_downshift_needs_sustained_overload(self):
        ctl = self._controller()
        assert ctl.observe(1.5) == 0       # one hot batch: no shift
        assert ctl.observe(1.5) == 1       # second: downshift
        assert ctl.shift_log[-1].direction == "down"

    def test_dead_band_resets_streaks(self):
        ctl = self._controller()
        ctl.observe(1.5)
        ctl.observe(0.7)                   # dead band: resets the streak
        assert ctl.observe(1.5) == 0       # needs two hot again
        assert ctl.observe(1.5) == 1

    def test_recovery_is_slower_than_degradation(self):
        ctl = self._controller()
        for _ in range(4):
            ctl.observe(2.0)
        assert ctl.level == 2              # floor of the ladder
        for _ in range(2):
            assert ctl.observe(0.1) == 2   # not yet: up_patience=3
        assert ctl.observe(0.1) == 1
        for _ in range(3):
            ctl.observe(0.1)
        assert ctl.level == 0
        dirs = [s.direction for s in ctl.shift_log]
        assert dirs == ["down", "down", "up", "up"]

    def test_select_follows_level(self):
        ctl = self._controller()
        full = ctl.select(256)
        assert full.widths == {}
        ctl.observe(2.0)
        ctl.observe(2.0)
        degraded = ctl.select(256)
        assert degraded.traffic.name == "decode"
        assert degraded.widths            # a real narrowed plan

    def test_threshold_validation(self):
        ladder = DegradationLadder.build(make_planner(), TRAFFIC,
                                         deltas=(0.8,))
        with pytest.raises(ValueError, match="hysteresis"):
            DegradationController(ladder, down_threshold=0.5,
                                  up_threshold=0.5)


# ---------------------------------------------------------------------------
# cache corruption -> quarantine -> recovery
# ---------------------------------------------------------------------------
def cache_layers(n=4):
    out = []
    for i in range(n):
        shape = LayerShape(f"l{i}", tokens=4096, d_in=4096,
                           width=2048 * (i % 4 + 2) + 256, shard_out=16)
        cands = analytic_candidates(HW, shape,
                                    max_width=int(shape.width * 1.6))
        out.append(TunableLayer(layer=shape, candidates=cands,
                                params_per_unit=4096))
    return out


class TestCacheCorruption:
    def test_corrupt_read_quarantines_and_recovers(self, tmp_path):
        layers = cache_layers()
        seed = TailEffectOptimizer(WaveQuantizationModel(HW),
                                   cache=ProfileTableCache(tmp_path))
        res_clean = seed.optimize_latency(layers, tau=1e9, delta=0.95)
        n_entries = len(list(ProfileTableCache(tmp_path)
                             .root.glob("??/*.npz")))
        assert n_entries == len(layers)

        corruptor = CacheCorruptor(ProfileTableCache(tmp_path), rate=1.0,
                                   seed=0)
        assert len(corruptor.strike()) == n_entries

        # the poisoned warm run: every read quarantines, the optimizer
        # falls back to a fresh sweep, and the answer is unchanged
        model = WaveQuantizationModel(HW)
        cache = ProfileTableCache(tmp_path)
        res = TailEffectOptimizer(model, cache=cache).optimize_latency(
            layers, tau=1e9, delta=0.95)
        assert res.new_widths == res_clean.new_widths
        assert model.eval_calls > 0                 # re-swept
        assert cache.stats.corrupted == n_entries   # visible, not silent
        assert cache.stats.hits == 0
        assert len(cache.quarantined()) == n_entries

        # the re-sweep rewrote fresh entries: next run is warm again
        model2 = WaveQuantizationModel(HW)
        cache2 = ProfileTableCache(tmp_path)
        TailEffectOptimizer(model2, cache=cache2).optimize_latency(
            layers, tau=1e9, delta=0.95)
        assert model2.eval_calls == 0
        assert cache2.stats.corrupted == 0

    def test_partial_corruption_spares_clean_entries(self, tmp_path):
        layers = cache_layers(6)
        TailEffectOptimizer(
            WaveQuantizationModel(HW),
            cache=ProfileTableCache(tmp_path)).optimize_latency(
                layers, tau=1e9, delta=0.95)
        hit = CacheCorruptor(ProfileTableCache(tmp_path), rate=0.5,
                             seed=1).strike()
        assert 0 < len(hit) < 6
        cache = ProfileTableCache(tmp_path)
        TailEffectOptimizer(WaveQuantizationModel(HW),
                            cache=cache).optimize_latency(
            layers, tau=1e9, delta=0.95)
        assert cache.stats.corrupted == len(hit)
        assert cache.stats.hits == 6 - len(hit)

    def test_quarantine_counts_once_then_plain_miss(self, tmp_path):
        layer = LayerShape("l", tokens=64, d_in=64, width=100)
        widths = np.array([128, 256], dtype=np.int64)
        cache = ProfileTableCache(tmp_path)
        cache.put(HW, layer, widths, {"latency_s": np.array([1.0, 2.0])})
        [path] = list(cache.root.glob("??/*.npz"))
        path.write_bytes(b"garbage")

        assert cache.get(HW, layer, widths) is None
        assert cache.stats.corrupted == 1
        assert not path.exists()                     # renamed to *.bad
        assert cache.quarantined()[0].name == path.name + ".bad"
        # second read: the key misses cleanly, no second quarantine
        assert cache.get(HW, layer, widths) is None
        assert cache.stats.corrupted == 1
        assert cache.purge_quarantined() == 1
        assert cache.quarantined() == []

    def test_clear_removes_quarantined(self, tmp_path):
        layer = LayerShape("l", tokens=64, d_in=64, width=100)
        widths = np.array([128], dtype=np.int64)
        cache = ProfileTableCache(tmp_path)
        cache.put(HW, layer, widths, {"latency_s": np.array([1.0])})
        [path] = list(cache.root.glob("??/*.npz"))
        path.write_bytes(b"junk")
        cache.get(HW, layer, widths)
        assert cache.quarantined()
        cache.clear()
        assert cache.quarantined() == []

    def test_corruptor_is_seed_deterministic(self, tmp_path):
        layers = cache_layers(6)
        TailEffectOptimizer(
            WaveQuantizationModel(HW),
            cache=ProfileTableCache(tmp_path)).optimize_latency(
                layers, tau=1e9, delta=0.95)
        a = CacheCorruptor(ProfileTableCache(tmp_path), rate=0.5, seed=9)
        b = CacheCorruptor(ProfileTableCache(tmp_path), rate=0.5, seed=9)
        # plan the strikes without executing twice: same seed, same draw
        # sequence over the same sorted file list
        assert [a.rng.random() for _ in range(8)] \
            == [b.rng.random() for _ in range(8)]


# ---------------------------------------------------------------------------
# end-to-end acceptance scenario: 4x burst + injected swap failures
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestBurstScenario:
    """The full resilience loop on a real (tiny) model.

    A 4x token-volume burst (12 batches against a 3-batch queue cap)
    with a 0.2 injected swap-failure rate, on a virtual clock advanced
    by modeled batch costs plus seeded straggler batches.  Everything
    asserted here is exact, not statistical.
    """

    SLOTS = 4
    CAP = 3
    BURST_N = 4 * 4 * 3          # 4x the sustainable queue, in requests

    @pytest.fixture(scope="class")
    def setup(self):
        import jax

        from repro.configs import get_config, reduced_config
        from repro.models import init_params

        cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                             n_layers=2, d_ff=576)
        params = init_params(jax.random.PRNGKey(0), cfg)
        templates, modules = serving_templates(cfg, HW, tokens=96,
                                               sites=("mlp",))
        planner = ServingWidthPlanner(HW, templates, modules=modules)
        traffic = [TrafficClass("burst", 96)]
        planner.plan(traffic)
        ladder = DegradationLadder.build(planner, traffic,
                                         deltas=(0.8, 0.6))
        return cfg, params, planner, ladder

    def _engine(self, setup, *, degrade: bool, fail_rate: float = 0.2):
        from repro.serving import ServeEngine

        cfg, params, planner, ladder = setup
        clock = VirtualClock()
        slow = SlowBatchInjector(0.25, 0.05, seed=11)
        injector = SwapFailureInjector(fail_rate, seed=1,
                                       steps=("begin",))
        admission = AdmissionControl(
            max_queue_batches=self.CAP, target_batch_s=0.25,
            ewma_alpha=0.5, headroom=2.0)
        degrader = swapper = eng_planner = None
        if degrade:
            eng_planner = planner
            swapper = WidthSwapper(params, cfg, fault_hook=injector)
            degrader = DegradationController(
                ladder, down_threshold=1.0, up_threshold=0.5,
                down_patience=1, up_patience=2)
        eng = ServeEngine(
            params, cfg, max_len=48, batch_slots=self.SLOTS,
            planner=eng_planner, swapper=swapper, admission=admission,
            degrader=degrader, clock=clock,
            batch_cost_fn=modeled_batch_cost(1e-3, overhead_s=0.01,
                                             slow=slow))
        return eng, injector

    def _burst(self, cfg, deadline_s):
        return burst_requests(cfg.vocab_size, n=self.BURST_N,
                              prompt_len=16, max_new_tokens=8,
                              deadline_s=deadline_s, seed=3)

    def _tight_run(self, setup):
        cfg = setup[0]
        eng, injector = self._engine(setup, degrade=True)
        results = eng.generate(self._burst(cfg, deadline_s=0.6))
        # trailing light traffic: the burst has passed, the controller
        # should walk back up to full width
        light = burst_requests(cfg.vocab_size, n=2, prompt_len=16,
                               max_new_tokens=8, seed=4)
        for _ in range(6):
            eng.generate(light)
        return eng, injector, results

    def test_tight_deadlines_shed_but_never_miss(self, setup):
        eng, injector, results = self._tight_run(setup)
        report = LoadReport.from_results(results)
        # overloaded: a real fraction of the burst was shed at admission
        assert report.shed > 0
        assert report.completed + report.shed == self.BURST_N
        # the resilience property: every request we accepted, we served
        # within its budget
        assert report.deadline_missed == 0
        assert all(not r.deadline_missed for r in results if not r.shed)
        assert eng.admission.shed == report.shed

    def test_engine_downshifts_and_recovers(self, setup):
        eng, injector, _ = self._tight_run(setup)
        full_w = setup[0].d_ff
        # downshift happened and reached the params: at least one swap
        # materialized a narrowed width during the burst
        downs = [s for s in eng.degrader.shift_log if s.direction == "down"]
        assert downs, "controller never downshifted under a 4x burst"
        narrowed = [ev for ev in eng.swap_log
                    if ev.outcome == "ok" and ev.realized
                    and min(w for _, w in ev.realized) < full_w]
        assert narrowed, "no batch was served at a reduced width"
        # burst passed: recovered to full width
        assert eng.degrader.level == 0
        assert eng.batch_log[-1].level == 0
        ups = [s for s in eng.degrader.shift_log if s.direction == "up"]
        assert len(ups) == len(downs)

    def test_injected_swap_failures_roll_back(self, setup):
        eng, injector, results = self._tight_run(setup)
        assert injector.injected >= 1          # 0.2 rate actually fired
        rolled = [ev for ev in eng.swap_log if ev.outcome == "rolled_back"]
        assert len(rolled) == injector.injected
        for ev in rolled:
            assert "InjectedFault" in ev.error
        # rolled-back batches still served (full width), nobody crashed
        assert all(len(r.tokens) == 8 for r in results if not r.shed)

    def test_scenario_is_deterministic(self, setup):
        runs = []
        for _ in range(2):
            eng, injector, results = self._tight_run(setup)
            runs.append((
                [r.shed for r in results],
                [ev.outcome for ev in eng.swap_log],
                [s.direction for s in eng.degrader.shift_log],
                LoadReport.from_results(results),
            ))
        assert runs[0] == runs[1]

    def test_degraded_p99_beats_full_width_under_burst(self, setup):
        cfg = setup[0]
        # relaxed deadlines: nothing sheds, so both runs complete the
        # identical 12-batch burst and the p99 gap is pure width policy
        relaxed = self._burst(cfg, deadline_s=100.0)
        eng_full, _ = self._engine(setup, degrade=False)
        full = LoadReport.from_results(eng_full.generate(relaxed))
        eng_deg, _ = self._engine(setup, degrade=True)
        deg = LoadReport.from_results(eng_deg.generate(relaxed))
        assert full.shed == deg.shed == 0
        assert full.completed == deg.completed == self.BURST_N
        assert deg.p99_s < full.p99_s
        assert deg.p50_s <= full.p50_s
