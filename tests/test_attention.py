"""Model-level attention: chunked-flash vs exact, decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: deterministic in-repo fallback
    from _hypothesis_fallback import given, settings, st


from repro.kernels import ref
from repro.models.attention import (
    chunked_attention, decode_attention, local_attention_prefill,
)

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestChunked:
    @pytest.mark.parametrize("mask,window", [("causal", 0), ("none", 0),
                                             ("local", 48)])
    def test_vs_exact(self, mask, window):
        b, s, h, kv, dh = 2, 256, 8, 2, 32
        q, k, v = rand(1, (b, s, h, dh)), rand(2, (b, s, kv, dh)), \
            rand(3, (b, s, kv, dh))
        out = chunked_attention(q, k, v, mask_kind=mask, window=window,
                                q_chunk=64, kv_chunk=64)
        expect = ref.attention_ref(q, k, v, mask_kind=mask, window=window)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   expect.astype(jnp.float32),
                                   rtol=2e-2, atol=2e-2)

    @given(qc=st.sampled_from([32, 64, 128, 256]),
           kc=st.sampled_from([32, 64, 128, 256]))
    @settings(max_examples=8, deadline=None)
    def test_chunk_size_invariance(self, qc, kc):
        """Output must not depend on the chunking (pure perf knob)."""
        b, s, h, kv, dh = 1, 256, 4, 4, 32
        q, k, v = rand(1, (b, s, h, dh)), rand(2, (b, s, kv, dh)), \
            rand(3, (b, s, kv, dh))
        base = chunked_attention(q, k, v, q_chunk=256, kv_chunk=256)
        out = chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   base.astype(jnp.float32),
                                   rtol=1e-2, atol=1e-2)

    def test_local_strip_equals_masked(self):
        b, s, h, kv, dh = 2, 512, 4, 1, 32
        q, k, v = rand(1, (b, s, h, dh)), rand(2, (b, s, kv, dh)), \
            rand(3, (b, s, kv, dh))
        full = chunked_attention(q, k, v, mask_kind="local", window=64,
                                 q_chunk=128, kv_chunk=128)
        strip = local_attention_prefill(q, k, v, window=64, q_chunk=128)
        np.testing.assert_allclose(strip.astype(jnp.float32),
                                   full.astype(jnp.float32),
                                   rtol=2e-2, atol=2e-2)


class TestDecode:
    def test_decode_equals_row_of_full(self):
        """decode at position p == row p of full causal attention."""
        b, s, h, kv, dh = 2, 64, 4, 2, 16
        q, k, v = rand(1, (b, s, h, dh)), rand(2, (b, s, kv, dh)), \
            rand(3, (b, s, kv, dh))
        full = ref.attention_ref(q, k, v, mask_kind="causal")
        for p in (0, 13, 63):
            dec = decode_attention(q[:, p], k, v, jnp.asarray(p + 1))
            np.testing.assert_allclose(
                dec.astype(jnp.float32), full[:, p].astype(jnp.float32),
                rtol=2e-2, atol=2e-2)

    def test_cache_len_masks_garbage(self):
        """Positions >= cache_len must not affect the result."""
        b, s, h, kv, dh = 1, 32, 2, 2, 16
        q = rand(1, (b, h, dh))
        k, v = rand(2, (b, s, kv, dh)), rand(3, (b, s, kv, dh))
        k2 = k.at[:, 20:].set(1e4)   # garbage beyond cache_len
        v2 = v.at[:, 20:].set(-1e4)
        a = decode_attention(q, k, v, jnp.asarray(20))
        b_ = decode_attention(q, k2, v2, jnp.asarray(20))
        np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-5)
