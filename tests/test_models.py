"""Per-arch smoke tests: reduced configs of all 10 assigned architectures —
one forward/train step on CPU asserting shapes + no NaNs, plus decode, and
the analytic parameter count against the real initialized tree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced_config
from repro.models import (
    count_params_analytic, decode_step, init_decode_state, init_params,
    layer_plan, train_loss,
)
from repro.models.transformer import forward, padded_vocab

# full XLA compiles: quick tier skips with -m "not slow"
pytestmark = pytest.mark.slow

ARCHS = list_archs()
B, S = 2, 32


def make_batch(cfg, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["src_embeds"] = jax.random.normal(k, (B, S, cfg.d_model))
    if cfg.rope_kind == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3))
    return batch


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = reduced_config(get_config(arch))
        out[arch] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(models, arch):
    cfg, params = models[arch]
    loss, metrics = train_loss(params, make_batch(cfg), cfg,
                               moe_strategy="dense")
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    assert np.isfinite(float(metrics["logz_mean"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(models, arch):
    cfg, params = models[arch]
    b = make_batch(cfg)
    logits, _, _ = forward(params, cfg, tokens=b["tokens"],
                           src_embeds=b.get("src_embeds"),
                           positions=b.get("positions"),
                           moe_strategy="dense")
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(models, arch):
    cfg, params = models[arch]
    enc_len = S if cfg.is_encdec else 0
    st = init_decode_state(cfg, B, 64, enc_len=enc_len)
    pos3 = (jnp.zeros((B, 1, 3), jnp.int32)
            if cfg.rope_kind == "mrope" else None)
    tok = jnp.zeros((B,), jnp.int32)
    logits, st2 = decode_step(params, cfg, tok, jnp.asarray(0), st,
                              positions=pos3)
    assert logits.shape == (B, padded_vocab(cfg))
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    # states preserved structure
    assert jax.tree.structure(st) == jax.tree.structure(st2)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_analytic(models, arch):
    """count_params_analytic must equal the initialized tree exactly,
    modulo vocab padding (the deliberate tail-elimination pad)."""
    cfg, params = models[arch]
    actual = sum(x.size for x in jax.tree.leaves(params))
    expected = count_params_analytic(cfg)
    pad_rows = padded_vocab(cfg) - cfg.vocab_size
    pad = pad_rows * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    assert actual == expected + pad, (arch, actual, expected, pad)


@pytest.mark.parametrize("arch", ARCHS)
def test_layer_plan_covers_depth(arch):
    cfg = get_config(arch)
    plan = layer_plan(cfg)
    assert len(plan) == cfg.n_layers
    kinds = {k for k, _ in plan}
    if cfg.family == "hybrid":
        assert kinds == {"rglru", "local"}
    if cfg.family == "ssm":
        assert kinds == {"rwkv"}
    if cfg.moe:
        assert any(m == "moe" for _, m in plan)


def test_prefill_decode_consistency():
    """Greedy decode continuing a prefix == teacher-forced forward."""
    cfg = reduced_config(get_config("qwen1.5-0.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)
    full_logits, _, _ = forward(params, cfg, tokens=toks)
    # decode token-by-token with a cache
    st = init_decode_state(cfg, 1, 16)
    outs = []
    for t in range(16):
        lg, st = decode_step(params, cfg, toks[:, t], jnp.asarray(t), st)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=3e-2, atol=3e-2)


def test_recurrent_prefill_decode_consistency():
    cfg = reduced_config(get_config("recurrentgemma-2b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                              cfg.vocab_size)
    full_logits, _, _ = forward(params, cfg, tokens=toks)
    st = init_decode_state(cfg, 1, 12)
    outs = []
    for t in range(12):
        lg, st = decode_step(params, cfg, toks[:, t], jnp.asarray(t), st)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=5e-2, atol=5e-2)
