"""Collective parsing + roofline arithmetic (the §Roofline machinery)."""

import numpy as np
import pytest

from repro.core import TPU_V5E, build_report, parse_collectives
from repro.core.hlo_analysis import CollectiveSummary, count_ops

SAMPLE = """
  %ag = f32[1024,64]{1,0} all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = bf16[256,4096]{1,0} all-reduce(%b), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%c), replica_groups=[2,8]<=[16], dimensions={0}
  %a2a = bf16[128,64]{1,0} all-to-all(%d), replica_groups=[32,16]<=[512]
  %cps = bf16[4,4]{1,0} collective-permute-start(%e), channel_id=9
  %cpd = bf16[4,4]{1,0} collective-permute-done(%cps)
  %dot = f32[8,8]{1,0} dot(%x, %y)
"""


class TestParser:
    def test_kinds_and_counts(self):
        s = parse_collectives(SAMPLE)
        kinds = sorted(o.kind for o in s.ops)
        assert kinds == ["all-gather", "all-reduce", "all-to-all",
                         "collective-permute", "reduce-scatter"]

    def test_group_sizes(self):
        s = parse_collectives(SAMPLE)
        by = {o.kind: o for o in s.ops}
        assert by["all-gather"].group_size == 16
        assert by["all-reduce"].group_size == 4
        assert by["reduce-scatter"].group_size == 8
        assert by["all-to-all"].group_size == 16

    def test_operand_derivation(self):
        s = parse_collectives(SAMPLE)
        by = {o.kind: o for o in s.ops}
        # all-gather result 1024*64*4 bytes over 16 shards
        assert by["all-gather"].operand_bytes == 1024 * 64 * 4 // 16
        assert by["all-reduce"].operand_bytes == 256 * 4096 * 2
        assert by["reduce-scatter"].operand_bytes == 64 * 32 * 4 * 8

    def test_ring_traffic(self):
        s = parse_collectives(SAMPLE)
        by = {o.kind: o for o in s.ops}
        r = 1024 * 64 * 4
        assert by["all-gather"].ring_traffic_bytes == pytest.approx(
            r * 15 / 16)
        ar = 256 * 4096 * 2
        assert by["all-reduce"].ring_traffic_bytes == pytest.approx(
            2 * ar * 3 / 4)
        assert by["collective-permute"].ring_traffic_bytes == 4 * 4 * 2

    def test_done_not_double_counted(self):
        s = parse_collectives(SAMPLE)
        assert sum(o.kind == "collective-permute" for o in s.ops) == 1

    def test_count_ops(self):
        c = count_ops(SAMPLE, ["dot", "all-gather"])
        assert c["dot"] == 1


class TestRoofline:
    def test_terms(self):
        s = parse_collectives(SAMPLE)
        rep = build_report(
            arch="x", shape="train_4k", mesh="single", chips=256,
            cost={"flops": 1.97e14, "bytes_accessed": 8.19e11},
            collectives=s, model_flops_total=1.97e14 * 256 * 0.5,
            hw=TPU_V5E)
        assert rep.compute_s == pytest.approx(1.0)
        assert rep.memory_s == pytest.approx(1.0)
        assert rep.dominant in ("compute", "memory")
        assert rep.useful_flops_fraction == pytest.approx(0.5)
        # roofline fraction: useful flops at the bound vs peak
        assert 0 < rep.roofline_fraction <= 1.0

    def test_dominant_collective(self):
        s = CollectiveSummary(ops=[])
        rep = build_report(
            arch="x", shape="s", mesh="single", chips=2,
            cost={"flops": 1.0, "bytes_accessed": 1.0},
            collectives=s, model_flops_total=1.0, hw=TPU_V5E)
        assert rep.collective_s == 0.0
        assert rep.dominant in ("compute", "memory")
