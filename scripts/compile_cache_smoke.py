"""CI smoke: warm AOT boundary crossings perform zero jit traces.

A tiny serving stack (reduced config, virtual clock, scripted narrow
plan) is AOT-warmed via ``warm_compile`` and then run through a width
boundary.  The trace-counting hook on the compile cache must not move —
every prefill/decode in the run is an executable table hit.  Runs in the
quick CI tier (scripts/ci.sh); seconds, not minutes.

    PYTHONPATH=src python scripts/compile_cache_smoke.py
"""

import dataclasses

import numpy as np

import jax

from repro.configs import get_config, reduced_config
from repro.core import TPU_V5E as HW
from repro.kernels.autotune import memo_stats
from repro.models import init_params
from repro.serving import (
    AdmissionControl, ContinuousServeEngine, Request, ServingWidthPlanner,
    TrafficClass, WidthSwapper, WidthVariantCompileCache,
    serving_templates,
)
from repro.serving.chaos import VirtualClock, modeled_batch_cost


class _Scripted:
    def __init__(self, plans):
        self.plans = list(plans)

    def select(self, tokens):
        plan = self.plans[0]
        if len(self.plans) > 1:
            self.plans.pop(0)
        return plan

    def observe(self, signal):
        return 0


def main() -> None:
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)
    templates, modules = serving_templates(cfg, HW, tokens=96,
                                           sites=("mlp",))
    planner = ServingWidthPlanner(HW, templates, modules=modules)
    planner.plan([TrafficClass("burst", 96)])
    narrow = planner.select(96)
    assert narrow.widths, "planner produced no narrowed plan"
    # pin the crossover economics so the plan realizes sliced
    narrow = dataclasses.replace(narrow, latency_s=0.5,
                                 baseline_latency_s=1.0)

    cache = WidthVariantCompileCache(cfg, hw=HW)
    eng = ContinuousServeEngine(
        params, cfg, max_len=48, batch_slots=2, clock=VirtualClock(),
        swapper=WidthSwapper(params, cfg), compile_cache=cache,
        batch_cost_fn=modeled_batch_cost(1e-3),
        boundary_every=2, boundary_cooldown=1000)
    eng.planner = None
    eng.degrader = _Scripted([narrow])
    eng.admission = AdmissionControl(max_queue_batches=100)

    rng = np.random.default_rng(0)
    requests = [Request(prompt=rng.integers(0, cfg.vocab_size, size=(pl,))
                        .astype(np.int32), max_new_tokens=6)
                for pl in (6, 6, 13)]

    warmed = eng.warm_compile([narrow], prefill_lengths=(6, 13))
    assert warmed > 0, "warm_compile built no executables"
    traced_at_warm = cache.tracer.count

    results = eng.run(requests)

    assert cache.tracer.count == traced_at_warm, (
        f"warm boundary crossing traced: {cache.tracer.count} != "
        f"{traced_at_warm}")
    assert cache.stats["hits"] > 0, "no AOT executable hits"
    assert any(b.outcome == "ok" for b in eng.boundary_log), \
        "no boundary crossed"
    led = eng.ledger()
    assert led.complete and led.failed == 0
    assert all(len(r.tokens) == 6 for r in results)

    print(f"compile_cache_smoke: ok  "
          f"(aot_compiles={cache.stats['aot_compiles']}, "
          f"hits={cache.stats['hits']}, traces={traced_at_warm}, "
          f"joins={eng.join_count}, "
          f"tile_memo={memo_stats()['entries']} entries)")


if __name__ == "__main__":
    main()
