#!/usr/bin/env bash
# CI entrypoint: quick tier, chaos tier, then the perf gate.
#
#   bash scripts/ci.sh
#
# Exits non-zero on the first failing stage, so the perf gate
# (benchmarks/run.py --check vs the committed BENCH_tail_optimizer.json)
# is no longer opt-in.  The compile-heavy slow tier is still covered by
# the tier-1 command in ROADMAP.md; this script is the fast always-on
# subset.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== quick tier =="
python -m pytest -q -m "not slow"

echo "== chaos tier =="
python -m pytest -q -m chaos

echo "== perf gate =="
python benchmarks/run.py --check

echo "ci: all stages passed"
