#!/usr/bin/env bash
# CI entrypoint: quick tier, chaos tier, then the perf gate.
#
#   bash scripts/ci.sh                 # all stages, in order
#   bash scripts/ci.sh --tier quick    # one stage (CI job sharding)
#   bash scripts/ci.sh --tier chaos
#   bash scripts/ci.sh --tier kernels
#   bash scripts/ci.sh --tier perf
#
# Exits non-zero on the first failing stage, so the perf gate
# (benchmarks/run.py --check vs the committed BENCH_tail_optimizer.json)
# is no longer opt-in.  The compile-heavy slow tier is still covered by
# the tier-1 command in ROADMAP.md; this script is the fast always-on
# subset.  --tier lets a CI matrix run the stages as parallel jobs with
# per-job timeouts instead of one serial wall.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier="all"
while [[ $# -gt 0 ]]; do
  case "$1" in
    --tier)
      [[ $# -ge 2 ]] || { echo "ci: --tier needs an argument" >&2; exit 2; }
      tier="$2"; shift 2 ;;
    *)
      echo "ci: unknown argument '$1' (usage: ci.sh [--tier quick|chaos|kernels|perf])" >&2
      exit 2 ;;
  esac
done

case "$tier" in
  all|quick|chaos|kernels|perf) ;;
  *)
    echo "ci: unknown tier '$tier' (expected quick, chaos, kernels, or perf)" >&2
    exit 2 ;;
esac

if [[ "$tier" == "all" || "$tier" == "quick" ]]; then
  echo "== quick tier =="
  python -m pytest -q -m "not slow"
  # AOT compile-cache smoke: a warmed serving run must cross a width
  # boundary with zero jit traces (trace-counting hook asserts inside).
  python scripts/compile_cache_smoke.py
fi

if [[ "$tier" == "all" || "$tier" == "chaos" ]]; then
  echo "== chaos tier =="
  # Boundary-recovery + compile-cache fault suites, and the hedged
  # multi-replica serving suites (width-variant hedging, health-aware
  # replica failover, chunked-prefill checkpoint recovery) in
  # tests/test_hedged_serving.py — all seeded, all exact-ledger.
  python -m pytest -q -m chaos
fi

if [[ "$tier" == "all" || "$tier" == "kernels" ]]; then
  echo "== kernels tier =="
  # Interpret-mode Pallas kernels + the fused-staircase differential
  # suite + tile autotuner goldens (no accelerator required).
  python -m pytest -q -m kernels
fi

if [[ "$tier" == "all" || "$tier" == "perf" ]]; then
  echo "== perf gate =="
  python benchmarks/run.py --check
fi

echo "ci: stage(s) passed (tier=$tier)"
