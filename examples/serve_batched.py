"""Batched serving with the decode engine (the paper's latency regime).

    PYTHONPATH=src python examples/serve_batched.py

Builds a reduced model, serves a mixed batch of requests (greedy +
temperature sampling, early EOS), and reports per-phase latency — prefill
vs decode — the split the tail-effect analysis targets.
"""

import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving import Request, ServeEngine  # noqa: E402


def main():
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=4)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, max_len=96, batch_slots=4)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        prompt = rng.integers(0, cfg.vocab_size, size=(16,)).astype(
            np.int32)
        reqs.append(Request(prompt=prompt, max_new_tokens=24,
                            temperature=0.0 if i % 2 == 0 else 0.8))

    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    print(f"served {len(reqs)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU)")
    for i, r in enumerate(results):
        kind = "greedy" if i % 2 == 0 else "t=0.8 "
        print(f"  req{i} [{kind}]: {r.tokens[:10].tolist()} ...")

    # greedy requests are deterministic
    again = engine.generate([reqs[0]])
    assert np.array_equal(again[0].tokens, results[0].tokens)
    print("OK: greedy decode deterministic")


if __name__ == "__main__":
    main()
