"""Batched serving with live width swapping (the paper's latency regime).

    PYTHONPATH=src python examples/serve_batched.py

Builds a reduced model whose FFN width (576) is deliberately misaligned
with the accelerator's wave quantum, plans per-traffic-class tail-free
widths with Algorithm 2, and serves a mixed batch of requests (greedy +
temperature sampling, early EOS) with the plans *applied* to the live
params at every batch boundary: the engine slices the real weight
pytree to the planned widths before prefilling, and repeat boundaries
hit the swapper's plan cache (zero new allocations).
"""

import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.core import TPU_V5E  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving import (  # noqa: E402
    Request, ServeEngine, ServingWidthPlanner, TrafficClass, WidthSwapper,
    serving_templates,
)


def main():
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=4, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)

    # Plan tail-free widths per traffic class and wire the plans to the
    # live params: templates + module addresses come as a matched pair.
    templates, modules = serving_templates(cfg, TPU_V5E, tokens=96,
                                           sites=("mlp",))
    planner = ServingWidthPlanner(TPU_V5E, templates, modules=modules)
    plans = planner.plan([TrafficClass("decode", 96),
                          TrafficClass("prefill", 4096)])
    for name, plan in plans.items():
        widths = sorted(set(plan.widths.values()))
        print(f"plan[{name}]: widths {widths} "
              f"(modeled latency -{plan.latency_reduction:.1%})")

    engine = ServeEngine(params, cfg, max_len=96, batch_slots=4,
                         planner=planner,
                         swapper=WidthSwapper(params, cfg))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        prompt = rng.integers(0, cfg.vocab_size, size=(16,)).astype(
            np.int32)
        reqs.append(Request(prompt=prompt, max_new_tokens=24,
                            temperature=0.0 if i % 2 == 0 else 0.8))

    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    print(f"served {len(reqs)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU)")
    for i, r in enumerate(results):
        kind = "greedy" if i % 2 == 0 else "t=0.8 "
        print(f"  req{i} [{kind}]: {r.tokens[:10].tolist()} ...")

    # every batch boundary applied its plan; repeats were cache hits
    assert len(engine.plan_log) == len(engine.swap_log) == 2
    for ev in engine.swap_log:
        state = "warm (cache hit, 0 allocs)" if ev.cache_hit else "cold"
        print(f"  swap -> plan[{ev.plan_name}] {state} "
              f"in {ev.swap_s*1e3:.2f}ms")
    assert engine.swap_log[1].cache_hit

    # greedy requests are deterministic (the re-run swaps to the same
    # cached plan, so the sliced params are identical objects)
    again = engine.generate([reqs[0]])
    assert np.array_equal(again[0].tokens, results[0].tokens)
    assert engine.swap_log[-1].cache_hit
    print("OK: greedy decode deterministic across warm swaps")


if __name__ == "__main__":
    main()
