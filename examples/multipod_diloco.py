"""Multi-pod training with DiLoCo outer sync + int8-EF compression.

    PYTHONPATH=src python examples/multipod_diloco.py

Simulates 2 pods on 8 fake host devices: each pod runs H=4 independent
inner AdamW steps (compiled with ZERO cross-pod collectives — asserted by
parsing the HLO), then pods synchronize once via the compressed outer
Nesterov step.  Cross-pod traffic: params x 1 byte / (H steps), vs
params x 4 bytes / step for naive DP — a ~16x DCI reduction.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.core.hlo_analysis import parse_collectives  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.parallel import diloco  # noqa: E402
from repro.parallel.compression import wire_bytes  # noqa: E402
from repro.train import (  # noqa: E402
    DataConfig, SyntheticLM, TrainConfig, adamw_init, build_train_step,
    cosine_schedule,
)


def main():
    n_pods, h, rounds = 2, 4, 6
    mesh = jax.make_mesh((n_pods, 2, 2), ("pod", "data", "model"))
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=64,
                         n_layers=2, vocab=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tc = TrainConfig(moe_strategy="dense")
    step = build_train_step(cfg, tc, cosine_schedule(3e-3, 4, 200))
    inner = jax.jit(diloco.build_inner_steps(step, h))

    pp = diloco.replicate_for_pods(params, n_pods)
    oo = diloco.replicate_for_pods(adamw_init(params), n_pods)
    shard = lambda t: jax.device_put(t, NamedSharding(mesh, P("pod")))
    pp, oo = jax.tree.map(shard, pp), jax.tree.map(shard, oo)
    outer = diloco.init_outer_state(params)
    dcfg = diloco.DilocoConfig(inner_steps=h, compress=True)

    # prove the inner loop never crosses pods
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=n_pods * h * 4))
    def pod_batches(r):
        b = data.batch(r)
        return jax.tree.map(
            lambda x: shard(jnp.asarray(x).reshape(n_pods, h, 4,
                                                   *x.shape[1:])), b)
    lowered = jax.jit(diloco.build_inner_steps(step, h)).lower(
        pp, oo, pod_batches(0), jnp.asarray(0))
    colls = parse_collectives(lowered.compile().as_text())
    max_group = max((o.group_size for o in colls.ops), default=1)
    assert max_group <= 4, "inner steps leaked cross-pod collectives!"
    print(f"inner-step collectives confined to pods "
          f"(max group {max_group} <= data*model=4)")

    naive = wire_bytes(params, "f32") * h
    ours = wire_bytes(params, "int8")
    print(f"cross-pod bytes per {h} steps: naive DP={naive/1e6:.2f}MB, "
          f"DiLoCo+int8EF={ours/1e6:.2f}MB ({naive/ours:.0f}x less)")

    for r in range(rounds):
        pp, oo, losses = inner(pp, oo, pod_batches(r), jnp.asarray(r * h))
        pp, outer = diloco.outer_step(pp, outer, dcfg, mesh)
        lm = np.asarray(losses).mean(axis=1)
        print(f"round {r}: per-pod inner-loss means "
              f"{np.round(lm, 3).tolist()}")
    print("OK: multi-pod DiLoCo training ran end-to-end")


if __name__ == "__main__":
    main()
