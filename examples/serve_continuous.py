"""Continuous batching with in-flight fault recovery.

    PYTHONPATH=src python examples/serve_continuous.py

The static engine (serve_resilient.py) forms lockstep batches: a short
request queued behind a long one pays the long one's decode tail, and
width swaps only ever happen between batches.  This example drives the
continuous engine through the full in-flight story on a virtual clock:

  * open-loop Poisson traffic plus a 4x spike — requests *join the
    running decode batch* as slots free up, no batch barrier;
  * the degradation controller downshifts at a width-plan boundary
    *while requests are decoding*: their KV caches are carried across
    the swap by ``reshape_states``;
  * an injected KV-reshape fault aborts a crossing mid-boundary: the
    canonical tree is restored and every in-flight request is requeued
    with its generated tokens intact (``Result.recovered``);
  * ``drain()`` closes the run with a ledger in which every admitted
    request is finished, shed, or failed — nothing silently dropped.

Every number printed here is deterministic: arrivals and injectors are
seeded and time only advances by modeled step costs.
"""

import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.core import TPU_V5E  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving import (  # noqa: E402
    AdmissionControl, ContinuousServeEngine, DegradationController,
    DegradationLadder, ServingWidthPlanner, TrafficClass, WidthSwapper,
    serving_templates,
)
from repro.serving.chaos import (  # noqa: E402
    ReshapeFailureInjector, SwapFailureInjector, TrafficLoad,
    VirtualClock, class_tail_reports, modeled_batch_cost,
    open_loop_arrivals,
)

SLOTS = 4


def main():
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)

    templates, modules = serving_templates(cfg, TPU_V5E, tokens=96,
                                           sites=("mlp",))
    planner = ServingWidthPlanner(TPU_V5E, templates, modules=modules)
    traffic = [TrafficClass("burst", 96)]
    planner.plan(traffic)
    ladder = DegradationLadder.build(planner, traffic, deltas=(0.8, 0.6))

    swap_inj = SwapFailureInjector(0.3, seed=1, steps=("begin",))
    resh_inj = ReshapeFailureInjector(0.3, seed=2)
    swapper = WidthSwapper(params, cfg, fault_hook=swap_inj,
                           reshape_fault_hook=resh_inj)
    eng = ContinuousServeEngine(
        params, cfg, max_len=48, batch_slots=SLOTS,
        planner=planner, swapper=swapper,
        admission=AdmissionControl(max_queue_batches=3,
                                   target_batch_s=0.25,
                                   ewma_alpha=0.5, headroom=2.0),
        degrader=DegradationController(
            ladder, down_threshold=1.0, up_threshold=0.5,
            down_patience=4, up_patience=8, observe_every=4),
        clock=VirtualClock(),
        batch_cost_fn=modeled_batch_cost(1e-3, overhead_s=0.002),
        max_retries=3, boundary_every=4, boundary_cooldown=8)

    # open-loop: steady Poisson traffic + a 4x spike dropped on top
    loads = [TrafficLoad("steady", rate_rps=40.0, duration_s=1.0,
                         prompt_len=8, max_new_tokens=8, deadline_s=2.0),
             TrafficLoad("spike", rate_rps=0.0, duration_s=1.0,
                         prompt_len=8, max_new_tokens=8, deadline_s=2.0,
                         burst_at=0.3, burst_n=48)]
    arrivals = open_loop_arrivals(loads, cfg.vocab_size, seed=5)
    print(f"open-loop workload: {len(arrivals)} requests over "
          f"{max(a.t for a in arrivals):.2f}s virtual, {SLOTS} slots")

    results = eng.run(arrivals)
    print(f"in-flight joins: {eng.join_count} "
          f"(> {len(arrivals)} means boundary-failure re-prefills)")

    for b in eng.boundary_log:
        if b.outcome == "ok":
            print(f"  step {b.step}: crossed to plan '{b.plan_name}' — "
                  f"live KV carried across the swap")
        elif b.outcome == "requeued_grow":
            print(f"  step {b.step}: grow boundary — {b.requeued} "
                  f"in-flight requeued to re-prefill at the new width")
        else:
            print(f"  step {b.step}: {b.outcome} ({b.error}) — "
                  f"{b.requeued} in-flight requeued, tokens intact")
    for s in eng.degrader.shift_log:
        print(f"  shift {s.direction} -> level {s.level} "
              f"(signal {s.signal:.2f})")

    recovered = sum(r.recovered for r in results)
    assert swap_inj.injected + resh_inj.injected >= 1
    assert recovered > 0
    print(f"injected faults: {swap_inj.injected} swap, "
          f"{resh_inj.injected} reshape; {recovered} requests recovered "
          f"with their tokens intact")

    ledger = eng.drain()
    assert ledger.complete and ledger.failed == 0
    print(f"drain ledger: {ledger.submitted} submitted = "
          f"{ledger.finished} finished + {ledger.shed} shed + "
          f"{ledger.failed} failed (complete={ledger.complete})")

    for name, rep in class_tail_reports(arrivals, results).items():
        print(f"  {name}: {rep.completed} done, p50 {rep.p50_s*1e3:.0f}ms "
              f"p99 {rep.p99_s*1e3:.0f}ms p99.9 {rep.p999_s*1e3:.0f}ms")
    print("OK: joined in flight, crossed boundaries, survived the "
          "faults, drained with a complete ledger")


if __name__ == "__main__":
    main()
