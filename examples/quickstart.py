"""Quickstart: see the latency staircase and eliminate the tail.

    PYTHONPATH=src python examples/quickstart.py

1. Model the staircase for deepseek-7b's d_ff=11008 on a 16-way TP slice
   of v5e (quantum = 16 shards x 128 lanes = 2048).
2. Eq. 4: identify the wave-aligned candidate widths.
3. Algorithm 2 both ways: cut latency (scale down) or grow capacity for
   free (scale up within the current wave).
"""

import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    LayerShape, TPU_V5E, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates,
)


def main():
    hw = TPU_V5E
    model = WaveQuantizationModel(hw)
    layer = LayerShape("deepseek_ffn", tokens=8192, d_in=4096,
                       width=11008, shard_out=16)

    print("== 1. the staircase (paper Fig. 1) ==")
    q = model.width_quantum(16)
    for w in range(8192, 12289, 512):
        pt = model.evaluate(layer.with_width(w))
        bar = "#" * int(pt.utilization * 40)
        print(f"  width {w:>6}  L={pt.latency_s*1e6:7.2f}us "
              f"waves={pt.waves}  util={pt.utilization:5.3f} {bar}")
    print(f"  quantum Q = 16 shards x {hw.lane} lanes = {q}")

    print("\n== 2. Eq. 4 candidates (argmax U x T = wave edges) ==")
    cands = analytic_candidates(hw, layer, max_width=16384)
    print(f"  {[int(c) for c in cands]}")

    print("\n== 3. Algorithm 2 ==")
    opt = TailEffectOptimizer(model)
    layers = [TunableLayer(
        layer=LayerShape(f"ffn_{i}", tokens=8192, d_in=4096,
                         width=11008, shard_out=16),
        candidates=cands, params_per_unit=3 * 4096)
        for i in range(4)]
    lat = opt.optimize_latency(layers,
                               tau=0.10 * sum(tl.params(11008)
                                              for tl in layers),
                               delta=0.9)
    print("  latency-oriented (Eq. 7):")
    print("   " + lat.summary().replace("\n", "\n   "))
    acc = opt.optimize_accuracy(layers)
    print("  accuracy-oriented (Eq. 6):")
    print("   " + acc.summary().replace("\n", "\n   "))


if __name__ == "__main__":
    main()
