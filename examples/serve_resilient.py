"""Fault-tolerant serving under a 4x burst (the resilience layer).

    PYTHONPATH=src python examples/serve_resilient.py

Builds the misaligned reduced model from serve_batched.py, then puts the
engine under deliberate abuse on a virtual clock: a 4x token-volume
burst of deadline-carrying requests, seeded straggler batches, and a
0.2 injected swap-failure rate.  Shows the whole loop:

  * admission control sheds the requests that would miss anyway
    (nobody admitted misses a deadline);
  * the degradation controller downshifts to narrower Algorithm 2
    widths under the overload signal and walks back to full width when
    the burst passes;
  * injected mid-swap failures roll back to the canonical tree
    (outcome recorded on the SwapEvent) instead of crashing a batch;
  * the same burst served at full width vs through the ladder shows
    the p99 win degradation buys.

Every number printed here is deterministic: injectors are seeded and
time only advances by modeled batch costs.
"""

import sys

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.core import TPU_V5E  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving import (  # noqa: E402
    AdmissionControl, DegradationController, DegradationLadder,
    ServeEngine, ServingWidthPlanner, TrafficClass, WidthSwapper,
    serving_templates,
)
from repro.serving.chaos import (  # noqa: E402
    LoadReport, SlowBatchInjector, SwapFailureInjector, VirtualClock,
    burst_requests, modeled_batch_cost,
)

SLOTS, CAP = 4, 3
BURST_N = 4 * SLOTS * CAP       # 4x the sustainable queue


def build_engine(cfg, params, planner, ladder, *, degrade):
    swapper = degrader = eng_planner = None
    injector = SwapFailureInjector(0.2, seed=1, steps=("begin",))
    if degrade:
        eng_planner = planner
        swapper = WidthSwapper(params, cfg, fault_hook=injector)
        degrader = DegradationController(
            ladder, down_threshold=1.0, up_threshold=0.5,
            down_patience=1, up_patience=2)
    eng = ServeEngine(
        params, cfg, max_len=48, batch_slots=SLOTS,
        planner=eng_planner, swapper=swapper,
        admission=AdmissionControl(max_queue_batches=CAP,
                                   target_batch_s=0.25,
                                   ewma_alpha=0.5, headroom=2.0),
        degrader=degrader, clock=VirtualClock(),
        batch_cost_fn=modeled_batch_cost(
            1e-3, overhead_s=0.01,
            slow=SlowBatchInjector(0.25, 0.05, seed=11)))
    return eng, injector


def main():
    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)

    templates, modules = serving_templates(cfg, TPU_V5E, tokens=96,
                                           sites=("mlp",))
    planner = ServingWidthPlanner(TPU_V5E, templates, modules=modules)
    traffic = [TrafficClass("burst", 96)]
    planner.plan(traffic)
    ladder = DegradationLadder.build(planner, traffic, deltas=(0.8, 0.6))
    for rung in ladder.rungs:
        widths = sorted({w for p in rung.plans.values()
                         for w in p.widths.values()}) or ["full"]
        print(f"ladder level {rung.level}: widths {widths} "
              f"(modeled -{rung.reduction:.1%})")

    # --- tight deadlines: shed the hopeless, serve the rest on time ---
    eng, injector = build_engine(cfg, params, planner, ladder,
                                 degrade=True)
    burst = burst_requests(cfg.vocab_size, n=BURST_N, prompt_len=16,
                           max_new_tokens=8, deadline_s=0.6, seed=3)
    report = LoadReport.from_results(eng.generate(burst))
    print(f"\n4x burst, 0.6s deadlines: {report.completed} served / "
          f"{report.shed} shed / {report.deadline_missed} missed "
          f"(p50 {report.p50_s*1e3:.0f}ms, p99 {report.p99_s*1e3:.0f}ms)")
    assert report.deadline_missed == 0

    for s in eng.degrader.shift_log:
        print(f"  shift {s.direction}: level {s.level} at batch "
              f"{s.batch_index} (signal {s.signal:.2f})")
    for ev in eng.swap_log:
        if ev.outcome == "rolled_back":
            print(f"  swap rolled back: {ev.error} — batch served "
                  f"full-width, nobody crashed")
    assert injector.injected >= 1

    # --- the burst passes: trailing light traffic walks back up -------
    light = burst_requests(cfg.vocab_size, n=2, prompt_len=16,
                           max_new_tokens=8, seed=4)
    for _ in range(6):
        eng.generate(light)
    print(f"recovered: degradation level {eng.degrader.level} "
          f"(full width) after the burst")
    assert eng.degrader.level == 0

    # --- same burst, full width vs the ladder (no shedding) -----------
    relaxed = burst_requests(cfg.vocab_size, n=BURST_N, prompt_len=16,
                             max_new_tokens=8, deadline_s=100.0, seed=3)
    eng_full, _ = build_engine(cfg, params, planner, ladder,
                               degrade=False)
    full = LoadReport.from_results(eng_full.generate(relaxed))
    eng_deg, _ = build_engine(cfg, params, planner, ladder, degrade=True)
    deg = LoadReport.from_results(eng_deg.generate(relaxed))
    print(f"same burst, no shedding: p99 full {full.p99_s*1e3:.0f}ms -> "
          f"degraded {deg.p99_s*1e3:.0f}ms "
          f"({full.p99_s/deg.p99_s:.2f}x)")
    assert deg.p99_s < full.p99_s
    print("OK: shed the hopeless, degrade the rest, recover after")


if __name__ == "__main__":
    main()
