"""End-to-end driver: train an LM with checkpointed restart.

    PYTHONPATH=src python examples/train_lm.py            # CPU-sized demo
    PYTHONPATH=src python examples/train_lm.py --full     # ~0.5B config

The demo trains a reduced qwen1.5 for a few hundred steps on the synthetic
stream, killing and resuming from the checkpoint halfway to demonstrate
fault tolerance.  ``--full`` uses the real qwen1.5-0.5b config (the ~100M+
regime) — the same driver, sized for real accelerators.
"""

import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch import train as train_cli  # noqa: E402


def main():
    full = "--full" in sys.argv
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        common = ["--arch", "qwen1.5-0.5b", "--seq", "128", "--batch", "8",
                  "--ckpt-dir", ckpt, "--ckpt-every", "50",
                  "--log-every", "25"]
        if not full:
            common += ["--reduced", "--d-model", "128", "--n-layers", "4"]
        print("=== phase 1: train 100 steps (checkpoint at 50, 100) ===")
        train_cli.main(common + ["--steps", "100"])
        print("\n=== phase 2: 'node failure' -> relaunch, resumes at 100, "
              "trains to 200 ===")
        losses = train_cli.main(common + ["--steps", "200"])
        assert losses[-1] < losses[0], "loss did not improve"
        print("\nOK: resumed training continued the run "
              f"(loss {losses[0]:.3f} -> {losses[-1]:.3f})")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
