"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no bias, cohere-style parallel blocks
[hf:CohereForAI/c4ai-command-r-v01]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=33792,
        vocab_size=256000,
        block_pattern=("attn",),
        qkv_bias=False,
        tie_embeddings=True,
        norm="layernorm",
        mlp_gated=True,
        parallel_block=True,
        rope_theta=75000000.0,
        sub_quadratic=False,
    )
