"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern.

26L d_model=2560 10H (MQA kv=1, head_dim=256) d_ff=7680 vocab=256000
[arXiv:2402.19427]
"""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        # Griffin: two recurrent blocks then one local-attention block.
        block_pattern=("rglru", "rglru", "local"),
        window=2048,
        norm="rmsnorm",
        mlp_gated=True,
        tie_embeddings=True,
        logit_softcap=30.0,
        rope_theta=10000.0,
        sub_quadratic=True,   # local attn + O(1) recurrent state -> long_500k
    )
