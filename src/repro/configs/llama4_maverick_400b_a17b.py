"""llama4-maverick-400b-a17b [moe] — MoE 128 experts top-1 + shared expert.

48L d_model=5120 40H (GQA kv=8) expert d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E lineage].  Early fusion: multimodal
tokens enter the same embedding stream (text-token dry-run shapes here).
"""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,                # shared-expert / dense-path hidden
        vocab_size=202048,
        block_pattern=("attn",),
        moe=True,
        n_experts=128,
        experts_per_token=1,
        moe_d_ff=8192,
        shared_expert=True,
        capacity_factor=1.25,
        moe_interleave=2,         # maverick alternates dense / MoE layers
        norm="rmsnorm",
        mlp_gated=True,
        rope_theta=500000.0,
        sub_quadratic=False,
    )
