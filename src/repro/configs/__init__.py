from repro.configs.base import (
    ModelConfig, ShapeConfig, SHAPES, shapes_for, all_cells, get_config,
    list_archs, reduced_config,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "shapes_for", "all_cells",
    "get_config", "list_archs", "reduced_config",
]
