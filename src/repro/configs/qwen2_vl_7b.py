"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. 28L d_model=3584 28H
(GQA kv=4) d_ff=18944 vocab=152064 [arXiv:2409.12191].

Backbone only; the vision frontend is a STUB — input_specs() provides
precomputed patch embeddings plus 3D (t, h, w) M-RoPE position ids.
"""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        block_pattern=("attn",),
        qkv_bias=True,
        norm="rmsnorm",
        mlp_gated=True,
        rope_kind="mrope",
        mrope_sections=(16, 24, 24),   # head_dim/2 = 64 split over t/h/w
        rope_theta=1000000.0,
        frontend="vision",
        sub_quadratic=False,
    )
