"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

12L (x2: encoder+decoder) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596].  The speech frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d_model) to the encoder.
"""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,              # decoder layers
        encoder_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        block_pattern=("attn",),
        norm="layernorm",
        mlp_gated=False,          # fairseq-style GeLU MLP
        qkv_bias=True,
        frontend="audio",
        sub_quadratic=False,      # full attention -> long_500k skipped
    )
