"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) expert d_ff=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base].
"""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        block_pattern=("attn",),
        moe=True,
        n_experts=32,
        experts_per_token=8,
        moe_d_ff=512,
        shared_expert=False,
        capacity_factor=1.25,
        norm="rmsnorm",
        mlp_gated=True,
        tie_embeddings=True,
        sub_quadratic=False,
    )
