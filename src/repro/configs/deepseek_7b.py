"""deepseek-7b [dense] — llama-arch MHA. 30L d_model=4096 32H (kv=32)
d_ff=11008 vocab=102400 [arXiv:2401.02954]."""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        n_layers=30,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=11008,
        vocab_size=102400,
        block_pattern=("attn",),
        norm="rmsnorm",
        mlp_gated=True,
        sub_quadratic=False,
    )
