"""rwkv6-1.6b (Finch) [ssm] — attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536, head_dim=64 (32 heads)
[arXiv:2404.05892].
"""

from repro.configs.base import ModelConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,               # d_model / rwkv_head_dim
        n_kv_heads=32,
        d_ff=7168,                # channel-mix hidden
        vocab_size=65536,
        block_pattern=("rwkv",),
        rwkv_head_dim=64,
        norm="layernorm",
        mlp_gated=False,          # RWKV channel-mix (squared ReLU)
        rope_kind="none",
        sub_quadratic=True,       # O(1) recurrent state -> long_500k runs
    )
