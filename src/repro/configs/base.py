"""Model / shape / run configuration dataclasses and the arch registry."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (one instance per assigned arch)."""

    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # Layer pattern, repeated (and truncated) over n_layers.
    # Kinds: attn | local | rglru | rwkv
    block_pattern: tuple = ("attn",)
    window: int = 0                  # sliding-window size for `local`

    # MoE
    moe: bool = False
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    moe_interleave: int = 1          # MoE on every k-th layer (llama4: 2)

    # Encoder-decoder (0 = decoder-only)
    encoder_layers: int = 0

    # Embedding / attention details
    rope_theta: float = 10000.0
    rope_kind: str = "standard"      # standard | mrope | none
    mrope_sections: tuple = (16, 24, 24)  # t/h/w split of head_dim/2
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp_gated: bool = True           # SwiGLU vs plain GeLU MLP
    parallel_block: bool = False     # cohere-style parallel attn+mlp
    logit_softcap: float = 0.0

    # RWKV
    rwkv_head_dim: int = 64

    # Modality frontend stub ('audio' | 'vision' | None): input_specs()
    # provides precomputed frame/patch embeddings for these.
    frontend: Optional[str] = None

    # Can this arch run the 524288-token decode shape?
    sub_quadratic: bool = False

    # Megatron-style sequence parallelism for activations: residual stream
    # and norms sharded over `model` along the sequence dim (perf variant).
    seq_parallel_acts: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived ----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def block_kind(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    def layer_kinds(self) -> list:
        return [self.block_kind(i) for i in range(self.n_layers)]

    # ---- parameter / FLOP accounting --------------------------------------
    def param_count(self, *, reduced: bool = False) -> int:
        """Exact parameter count of our implementation of this config."""
        from repro.models.transformer import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str    # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shapes_for(cfg: ModelConfig) -> list:
    """Valid (non-skipped) shape cells for an arch.

    long_500k needs sub-quadratic attention — skipped for pure
    full-attention archs (see DESIGN.md section 4).
    """
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out


def all_cells(cfg: ModelConfig) -> list:
    """All 4 assigned cells, with a skip marker where inapplicable."""
    valid = {s.name for s in shapes_for(cfg)}
    return [(SHAPES[n], n in valid) for n in
            ("train_4k", "prefill_32k", "decode_32k", "long_500k")]


# ---------------------------------------------------------------------------
_REGISTRY: dict = {}


def register(fn: Callable[[], ModelConfig]):
    cfg = fn()
    _REGISTRY[cfg.name] = cfg
    return fn


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: "
                       f"{sorted(_REGISTRY)}") from None


def list_archs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        recurrentgemma_2b, seamless_m4t_medium, qwen1_5_0_5b,
        command_r_plus_104b, yi_34b, deepseek_7b, qwen2_vl_7b,
        rwkv6_1_6b, llama4_maverick_400b_a17b, granite_moe_1b_a400m,
    )


def reduced_config(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 64,
                   n_heads: int = 4, d_ff: int = 128, vocab: int = 256,
                   n_experts: int = 4) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    kv = max(1, min(cfg.n_kv_heads, n_heads)) if cfg.n_kv_heads else 0
    if cfg.n_kv_heads and cfg.n_heads % cfg.n_kv_heads == 0:
        kv = max(1, n_heads // max(1, cfg.n_heads // cfg.n_kv_heads))
    pattern_span = len(cfg.block_pattern)
    layers = max(n_layers, pattern_span)
    half = (d_model // n_heads) // 2
    t_sec = half // 4
    h_sec = (half - t_sec) // 2
    sections = (t_sec, h_sec, half - t_sec - h_sec)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=layers,
        encoder_layers=min(cfg.encoder_layers, 2) if cfg.is_encdec else 0,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=d_model // n_heads,
        d_ff=d_ff,
        moe_d_ff=min(cfg.moe_d_ff, d_ff) if cfg.moe else 0,
        n_experts=min(cfg.n_experts, n_experts) if cfg.moe else 0,
        experts_per_token=(min(cfg.experts_per_token, n_experts)
                           if cfg.moe else 0),
        vocab_size=vocab,
        window=min(cfg.window, 64) if cfg.window else 0,
        rwkv_head_dim=16,
        mrope_sections=sections,
    )
