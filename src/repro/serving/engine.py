"""Batched serving engine: prefill + decode with greedy/temperature
sampling, continuous slot management and per-request stop handling.

The decode step is the exact function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells; on the production mesh the KV cache is
sequence-sharded over the model axis (flash-decode).

Width planning and live swapping
--------------------------------
``ServingWidthPlanner`` runs the paper's Algorithm 2 per *traffic class*
(token-volume bucket): the tail-free width config that is optimal for a
32-token decode batch is not optimal for an 8k-token prefill batch (the
staircase quantum is the same but the compute/memory crossover moves), so
the planner pre-computes one width plan per class on the stacked table
engine — all layers x all candidates in one NumPy sweep, with tables
persisted through ``repro.core.table_cache`` so a planner restart skips the
pre-analysis.

Resilience layer
----------------
A loaded server's p99 is set by its queue, not its model, so the engine
degrades instead of queueing without bound:

  * **Deadlines + admission control** — each :class:`Request` may carry
    a completion budget (``deadline_s``); an attached
    :class:`AdmissionControl` sheds requests whose projected completion
    (elapsed queue wait + a batch-latency EWMA with headroom) exceeds
    the budget, and deadline-less requests beyond a queue-depth cap.
    Shed requests return immediately (``Result.shed``) — wasting no
    compute on work that will miss anyway.
  * **Graceful degradation** — an attached
    ``degradation.DegradationController`` replaces ``planner.select`` at
    batch boundaries: under a sustained overload signal (queue depth +
    batch EWMA, from the admission controller) it downshifts to
    narrower/faster WidthPlans with hysteresis, and recovers to full
    width when the burst passes.
  * **Transactional swaps** — boundary swaps go through
    ``WidthSwapper.apply_guarded``: any mid-swap failure rolls back to
    the retained canonical full-width tree and is recorded on the
    ``SwapEvent`` (``outcome="rolled_back"``), so a failed swap costs
    one batch of speedup, never a crash.
  * **Deterministic time** — ``clock`` and ``batch_cost_fn`` let the
    chaos harness (``serving.chaos``) run the whole loop on a virtual
    clock advanced by *modeled* batch costs, making shed sets, deadline
    misses and tail percentiles exactly reproducible from a seed.

Plans are *applied*, not just recorded: at each request-batch boundary —
the swap point where a width change is representable without touching
in-flight state — the engine looks up the traffic class nearest the
batch's token volume (``plan_log``) and, when a
``width_swap.WidthSwapper`` is attached, materializes the plan onto the
live param pytree (sliced MLP hidden dims and attention heads, zero-padded
within stacked scan groups) before prefilling.  The prefill then builds
KV caches directly in the plan's shapes.  Each swap is recorded in
``swap_log`` (plan, wall time, cache hit); a warm swap to an
already-seen plan is served from the swapper's plan cache with zero new
array allocations.  Build the planner's templates with
``width_swap.serving_templates`` so every ``WidthPlan`` carries the
layer-name -> ``ModuleRef`` mapping (``modules``) the swapper needs to
address the pytree.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan_address import ModuleRef
from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1            # -1: never stop early
    temperature: float = 0.0    # 0 = greedy
    # Completion budget in seconds from submission; None = best-effort.
    # Admission control sheds the request when its projected completion
    # exceeds the budget (see AdmissionControl).
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Result:
    tokens: np.ndarray
    steps: int
    shed: bool = False              # rejected by admission control
    deadline_missed: bool = False   # completed, but past its budget
    latency_s: float = 0.0          # submission -> completion (engine clock)
    retries: int = 0                # boundary-failure requeues survived
    recovered: bool = False         # finished normally after >=1 requeue
    failed: bool = False            # terminal failure (retry budget spent)
    cancelled: bool = False         # cancelled in flight (hedge loser)
    hedged: bool = False            # served as a hedge pair (router-level)
    won_by: str = ""                # "primary" | "backup" when hedged
    migrations: int = 0             # replica failovers survived (router)


def _shed_result() -> "Result":
    return Result(tokens=np.zeros(0, np.int32), steps=0, shed=True)


class AdmissionControl:
    """Deadline-aware admission + load shedding on an overload signal.

    Two inputs form the overload signal (both normalized so 1.0 = at the
    configured limit):

      * **queue depth** — batches waiting, over ``max_queue_batches``;
      * **batch latency** — an EWMA of observed batch wall times
        (``observe`` is fed by the engine after every batch), over
        ``target_batch_s``.

    ``signal`` is the max of the two: queueing stacks latency near
    saturation, so depth alone predicts the tail even before the EWMA
    catches up, and a latency regression (slow batches at low depth)
    still registers.  Admission is per request at batch-formation time:
    a deadline-carrying request is shed when its elapsed wait plus
    ``headroom`` EWMA-predicted batch times exceeds the budget (it
    would miss anyway — serving it would only push every later request
    closer to missing too); a deadline-less request is shed only behind
    a queue deeper than ``max_queue_batches`` at its arrival.
    """

    def __init__(self, *, max_queue_batches: int = 8,
                 target_batch_s: Optional[float] = None,
                 ewma_alpha: float = 0.3, headroom: float = 1.5):
        self.max_queue_batches = max(int(max_queue_batches), 1)
        self.target_batch_s = target_batch_s
        self.ewma_alpha = float(ewma_alpha)
        self.headroom = float(headroom)
        self.batch_ewma: Optional[float] = None
        self.admitted = 0
        self.shed = 0

    def observe(self, batch_s: float) -> None:
        """Feed one completed batch's wall time into the EWMA."""
        if self.batch_ewma is None:
            self.batch_ewma = float(batch_s)
        else:
            self.batch_ewma = (self.ewma_alpha * float(batch_s)
                               + (1.0 - self.ewma_alpha) * self.batch_ewma)

    def signal(self, queue_batches: int) -> float:
        """Overload signal: max of queue-depth and batch-EWMA ratios."""
        depth = queue_batches / self.max_queue_batches
        lat = 0.0
        if self.batch_ewma is not None and self.target_batch_s:
            lat = self.batch_ewma / self.target_batch_s
        return max(depth, lat)

    def admit(self, request: Request, *, now: float, arrival: float,
              backlog_batches: int) -> bool:
        """Admit or shed one request at batch-formation time.

        ``backlog_batches`` is the queue depth (in batches) ahead of the
        request when it arrived — the arrival-time congestion a real
        admission gate would see."""
        if request.deadline_s is not None and self.batch_ewma is not None:
            projected = (now - arrival) + self.headroom * self.batch_ewma
            ok = projected <= request.deadline_s
        else:
            # no deadline to project against (or cold EWMA): hard cap
            ok = backlog_batches <= self.max_queue_batches
        if ok:
            self.admitted += 1
        else:
            self.shed += 1
        return ok


@dataclasses.dataclass(frozen=True)
class BatchStats:
    """Per-batch telemetry, appended to ``ServeEngine.batch_log``."""

    tokens: int         # token volume the batch was planned/costed at
    latency_s: float    # observed (or simulated) batch wall time
    plan_name: str      # traffic class served, "" without a planner
    level: int          # degradation level, -1 without a degrader
    signal: float       # overload signal after this batch


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One serving traffic bucket: a typical per-device token volume
    (batch x padded sequence) and a latency-reduction target."""

    name: str
    tokens: int
    delta: float = 0.95       # Algorithm 2 target: L_new <= delta * L_old


@dataclasses.dataclass
class WidthPlan:
    """Per-traffic-class output of Algorithm 2: the width config to swap
    in at a batch boundary, plus its modeled latency.

    ``modules`` maps each planned layer name to its
    :class:`repro.core.plan_address.ModuleRef` pytree address — the
    hook ``width_swap.WidthSwapper`` needs to materialize the plan onto
    real params.  Plans built from planner templates without a module
    mapping stay record-only (``None``)."""

    traffic: TrafficClass
    widths: dict[str, int]
    latency_s: float
    baseline_latency_s: float
    satisfied: bool
    modules: "dict[str, ModuleRef] | None" = None

    @property
    def latency_reduction(self) -> float:
        if self.baseline_latency_s == 0:
            return 0.0
        return 1.0 - self.latency_s / self.baseline_latency_s


class ServingWidthPlanner:
    """Plans tail-free width configs per traffic class on the stacked
    table engine (paper Algorithm 2, latency-oriented).

    ``layers`` are ``TunableLayer`` templates at a reference token count;
    each traffic class re-tokens the shapes and runs one optimize pass.
    All per-class table builds go through the same
    ``TailEffectOptimizer`` — one stacked sweep per class — and, when a
    ``table_cache.ProfileTableCache`` is supplied, tables persist across
    planner restarts (a warm planner performs zero model sweeps).
    """

    def __init__(self, hw, layers: Sequence, *, cache=None,
                 tau_frac: float = 0.02,
                 modules: "dict[str, ModuleRef] | None" = None,
                 tile_hw=None, compile_cache=None):
        from repro.core.tail_model import WaveQuantizationModel
        from repro.core.tail_optimizer import TailEffectOptimizer

        self.hw = hw
        self.layers = list(layers)
        self.model = WaveQuantizationModel(hw)
        self.opt = TailEffectOptimizer(self.model, cache=cache)
        self.tau_frac = tau_frac
        # Kernel-grid tail awareness (optional): with a tile_hw spec,
        # `select` breaks log-distance ties toward plans whose autotuned
        # matmul grids are tail-free (core.candidates.kernel_tail_free)
        # and — with a serving.compile_cache attached — whose
        # executables are already AOT-warm.  With tile_hw=None the
        # historical first-planned tie-break is bit-for-bit unchanged.
        self.tile_hw = tile_hw
        self.compile_cache = compile_cache
        self._layer_by_name = {tl.layer.name: tl.layer
                               for tl in self.layers}
        # name -> pytree address; stamped on every WidthPlan so a
        # WidthSwapper can materialize it (width_swap.serving_templates
        # builds layers and modules as a matched pair).
        self.modules = modules
        self.plans: dict[str, WidthPlan] = {}
        # Telemetry hook: observed per-class batch latencies, fed by the
        # engine after every batch (`record`).  This is the measurement
        # the plans were built to improve — keeping it on the planner is
        # what lets a future closed loop re-solve plans from measured
        # tail behavior instead of static traffic classes.
        self.telemetry: dict[str, List[float]] = {}
        # A serving process records one sample per request forever; an
        # unbounded list is a slow leak.  Keep a sliding window — recent
        # samples are also the ones a re-planning loop should trust.
        self.telemetry_window = 4096

    def record(self, class_name: str, latency_s: float) -> None:
        """Observe one served batch's latency for a traffic class.
        Memory is bounded: only the latest ``telemetry_window`` samples
        per class are retained."""
        lats = self.telemetry.setdefault(class_name, [])
        lats.append(float(latency_s))
        if len(lats) > self.telemetry_window:
            del lats[:-self.telemetry_window]

    def observed_percentile(self, class_name: str,
                            q: float) -> Optional[float]:
        """q-th percentile of observed batch latencies for a class, or
        None before any observation.  ``q`` is clamped to [0, 100] so
        p99.9-style callers can't trip numpy on a rounding excursion."""
        lats = self.telemetry.get(class_name)
        if not lats:
            return None
        q = min(max(float(q), 0.0), 100.0)
        return float(np.percentile(np.asarray(lats), q))

    def _retokened(self, tokens: int) -> list:
        out = []
        for tl in self.layers:
            if tl.layer.tokens == tokens:
                out.append(tl)
                continue
            layer = dataclasses.replace(tl.layer, tokens=tokens)
            # A measured profile is only valid at the token count it was
            # profiled with — re-tokened classes must fall back to the
            # analytic model rather than silently reuse stale latencies.
            out.append(dataclasses.replace(tl, layer=layer, measured=None))
        return out

    def plan(self, traffic: Sequence[TrafficClass]) -> dict[str, WidthPlan]:
        """One Algorithm 2 pass per traffic class; results are kept on the
        planner for ``select`` and returned keyed by class name."""
        total_p = sum(tl.params(tl.layer.width) for tl in self.layers)
        for tc in traffic:
            res = self.opt.optimize_latency(
                self._retokened(tc.tokens),
                tau=self.tau_frac * total_p,
                delta=tc.delta)
            self.plans[tc.name] = WidthPlan(
                traffic=tc,
                widths=res.new_widths,
                latency_s=res.latency_new_s,
                baseline_latency_s=res.latency_old_s,
                satisfied=res.satisfied,
                modules=self.modules)
        return self.plans

    def plan_tail_free(self, plan: WidthPlan) -> bool:
        """True when every planned width's autotuned matmul grid is
        tail-free on ``tile_hw`` (trivially True without one).  Widths
        naming layers outside the template set are skipped — a hand
        -injected plan can't be scored, only compared by distance."""
        if self.tile_hw is None:
            return True
        from repro.core.candidates import kernel_tail_free
        for name, w in plan.widths.items():
            layer = self._layer_by_name.get(name)
            if layer is None:
                continue
            if not kernel_tail_free(self.tile_hw, plan.traffic.tokens,
                                    layer.d_in, w):
                return False
        return True

    def plan_is_warm(self, plan: WidthPlan) -> bool:
        """True when a compile cache is attached and holds AOT
        executables for the plan's widths."""
        return self.compile_cache is not None \
            and self.compile_cache.plan_is_warm(plan)

    def select(self, tokens: int) -> WidthPlan:
        """The planned class nearest (log-scale) to a batch's token
        volume — the boundary-time lookup ``ServeEngine`` performs.

        ``tokens`` is clamped to >= 1 (an empty batch selects the
        smallest class).  Without ``tile_hw``, an exact log-distance tie
        resolves to the class planned first (``min`` is stable over
        insertion order) — the historical deterministic behavior.  With
        ``tile_hw``, ties instead prefer plans whose autotuned kernel
        grids are tail-free, then plans whose executables are already
        AOT-warm: equal-latency widths are not equal when one wastes a
        partial wave or pays a trace at its first boundary."""
        if not self.plans:
            raise ValueError("no plans yet: call plan() first")
        log_t = np.log(max(tokens, 1))
        if self.tile_hw is None:
            return min(
                self.plans.values(),
                key=lambda p: abs(log_t
                                  - np.log(max(p.traffic.tokens, 1))))
        return min(
            self.plans.values(),
            key=lambda p: (abs(log_t
                               - np.log(max(p.traffic.tokens, 1))),
                           not self.plan_tail_free(p),
                           not self.plan_is_warm(p)))


class ServeEngine:
    """Static-batch engine: pads requests to a slot batch, prefills, then
    decodes all slots in lockstep, releasing finished ones."""

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 512,
                 batch_slots: int = 4, rng_seed: int = 0,
                 planner: "ServingWidthPlanner | None" = None,
                 swapper=None, admission: "AdmissionControl | None" = None,
                 degrader=None,
                 clock: Callable[[], float] = time.monotonic,
                 batch_cost_fn=None, compile_cache=None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.slots = batch_slots
        self.rng = jax.random.PRNGKey(rng_seed)
        # Width planning: at each batch boundary the engine looks up the
        # traffic class nearest the batch's token volume (plan_log) and,
        # with a width_swap.WidthSwapper attached, swaps the plan onto
        # the live params before prefilling (swap_log).  Each distinct
        # plan's param shapes get their own jit specialization; the
        # swapper's plan cache makes repeat boundaries allocation-free.
        self.planner = planner
        self.swapper = swapper
        # Resilience: admission control (deadline shedding + the overload
        # signal), a degradation controller (width downshift under that
        # signal; needs the admission controller as its signal source),
        # and the deterministic-time hooks chaos runs use: `clock` is
        # any time.monotonic-like callable, and `batch_cost_fn(plan,
        # tokens)`, when set, replaces measured batch wall time with a
        # simulated cost (advancing a chaos.VirtualClock if the clock
        # exposes .advance).
        if degrader is not None and admission is None:
            raise ValueError(
                "a degradation controller needs an AdmissionControl as "
                "its overload-signal source; pass admission= too")
        self.admission = admission
        self.degrader = degrader
        self.clock = clock
        self.batch_cost_fn = batch_cost_fn
        self.plan_log: List[WidthPlan] = []
        self.swap_log: List = []
        self.batch_log: List[BatchStats] = []

        # AOT width-variant executables (serving/compile_cache.py): with
        # a cache attached every prefill/decode goes through its
        # lookup-or-traced-fallback entry points, the boundary swap sets
        # the active realized key, and plans whose modeled saving cannot
        # pay for a compile realize as zero-masked full-shape params on
        # the warm full-width executable (`decide`).
        self.compile_cache = compile_cache
        if compile_cache is not None:
            if compile_cache.cfg is not cfg and compile_cache.cfg != cfg:
                raise ValueError("compile_cache was built for a different "
                                 "ModelConfig than this engine")
            self._decode = compile_cache.decode
            self._prefill = compile_cache.prefill
        else:
            self._decode = jax.jit(
                lambda p, t, pos, st: tfm.decode_step(p, cfg, t, pos, st))
            self._prefill = jax.jit(
                lambda p, toks: tfm.forward(p, cfg, tokens=toks,
                                            mode="prefill"))

    def warm_compile(self, plans: Sequence[WidthPlan],
                     batch_shapes: Sequence[tuple]) -> int:
        """Plan-time AOT compilation: for every plan x (batch, prompt
        length) shape, compile the prefill and decode executables so the
        batch-boundary swap to that plan is a table lookup, never a
        trace.  Masked-crossover plans (``decide() == "masked"``) warm
        the full-width key instead.  Returns the number of executables
        compiled; a compile fault is absorbed (traced fallback)."""
        if self.compile_cache is None or self.swapper is None:
            return 0
        from repro.serving.compile_cache import (
            decode_state_struct, realized_exec_key)
        cache = self.compile_cache
        prev_key = cache.active_key
        n = 0
        todo = list(plans) + [None]     # None: the full-width baseline
        for plan in todo:
            if plan is None:
                key = cache.full_key
                params = self.swapper.full_params
                heads = None
            else:
                masked = bool(plan.widths) \
                    and cache.decide(plan) == "masked"
                params, event = self.swapper.apply_guarded(
                    plan, masked=masked)
                if event.outcome != "ok":
                    continue
                mlp_w, heads_to = self.swapper.realize_plan(plan)
                if masked:
                    key, heads = cache.full_key, None
                else:
                    key = realized_exec_key(mlp_w, heads_to)
                    heads = heads_to
            for (b, plen) in batch_shapes:
                b, plen = int(b), int(plen)
                cache.set_active(key)
                toks = jnp.zeros((b, plen), jnp.int32)
                n += cache.precompile("prefill", key, (b, plen),
                                      (params, toks))
                st = decode_state_struct(self.cfg, b, self.max_len,
                                         swapper=self.swapper,
                                         heads=heads)
                cur = jnp.zeros((b,), jnp.int32)
                pos = jnp.zeros((), jnp.int32)
                n += cache.precompile("decode", key, (b,),
                                      (params, cur, pos, st))
            if plan is not None:
                cache.mark_plan_warm(plan)
        cache.set_active(prev_key)
        return n

    def generate(self, requests: List[Request]) -> List[Result]:
        """Serve an open-loop burst: all requests arrive now; batches of
        ``batch_slots`` are formed in order, with admission control (when
        attached) shedding requests at batch-formation time."""
        results: List[Optional[Result]] = [None] * len(requests)
        arrival = self.clock()
        queue = deque(enumerate(requests))
        while queue:
            batch_idx: List[int] = []
            batch: List[Request] = []
            while queue and len(batch) < self.slots:
                i, r = queue.popleft()
                if self.admission is not None and not self.admission.admit(
                        r, now=self.clock(), arrival=arrival,
                        backlog_batches=i // self.slots):
                    results[i] = _shed_result()
                    continue
                batch_idx.append(i)
                batch.append(r)
            if not batch:
                continue
            t0 = self.clock()
            out, plan = self._generate_batch(batch)
            self._account_batch(plan, batch, t0, queue_len=len(queue))
            end = self.clock()
            for i, res in zip(batch_idx, out):
                res.latency_s = end - arrival
                d = requests[i].deadline_s
                res.deadline_missed = d is not None and res.latency_s > d
                results[i] = res
        return [r for r in results if r is not None]

    def _account_batch(self, plan, reqs: List[Request], t0: float,
                       *, queue_len: int) -> float:
        """Close out one batch: latency (measured, or simulated through
        ``batch_cost_fn`` + a virtual clock), EWMA/telemetry feeds, and
        the degradation controller's overload observation."""
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        tokens = b * (plen + max(r.max_new_tokens for r in reqs))
        if self.batch_cost_fn is not None:
            dt = self.batch_cost_fn(plan, tokens)
            advance = getattr(self.clock, "advance", None)
            if advance is not None:
                advance(dt)
        else:
            dt = self.clock() - t0
        if self.admission is not None:
            self.admission.observe(dt)
        sig = 0.0
        if self.admission is not None:
            qb = (queue_len + self.slots - 1) // self.slots
            sig = self.admission.signal(qb)
            if self.degrader is not None:
                self.degrader.observe(sig)
        if self.planner is not None and plan is not None:
            self.planner.record(plan.traffic.name, dt)
        self.batch_log.append(BatchStats(
            tokens=tokens, latency_s=dt,
            plan_name=plan.traffic.name if plan is not None else "",
            level=self.degrader.level if self.degrader is not None else -1,
            signal=sig))
        return dt

    def _generate_batch(self, reqs: List[Request]):
        cfg = self.cfg
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        params = self.params
        plan = None
        if self.degrader is not None:
            # degradation replaces the static class lookup: the active
            # ladder rung picks the plan for this token volume
            plan = self.degrader.select(b * plen)
        elif self.planner is not None:
            plan = self.planner.select(b * plen)
        if plan is not None:
            self.plan_log.append(plan)
            if self.swapper is not None:
                # The actual swap: materialize the plan onto the live
                # params (cached per realized width assignment).  The
                # prefill below then builds KV caches in the plan's
                # shapes, so no in-flight state is ever re-shaped.
                # Guarded: a mid-swap failure rolls back to the
                # canonical full-width tree (outcome on the SwapEvent)
                # instead of dropping the batch.  A plan without a
                # module mapping still raises (build templates via
                # width_swap.serving_templates) rather than silently
                # serving full-width weights.
                masked = (self.compile_cache is not None
                          and bool(plan.widths)
                          and self.compile_cache.decide(plan) == "masked")
                params, event = self.swapper.apply_guarded(
                    plan, masked=masked)
                self.swap_log.append(event)
                if self.compile_cache is not None:
                    if event.outcome == "ok" and not masked:
                        from repro.serving.compile_cache import \
                            realized_exec_key
                        mlp_w, heads = self.swapper.realize_plan(plan)
                        self.compile_cache.set_active(
                            realized_exec_key(mlp_w, heads))
                    else:
                        # masked or rolled back: canonical shapes run on
                        # the full-width executable
                        self.compile_cache.set_active(None)
        elif self.compile_cache is not None:
            self.compile_cache.set_active(None)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        toks_j = jnp.asarray(toks)

        logits, states, _ = self._prefill(params, toks_j)
        states = self._ensure_states(states, b, plen)

        max_new = max(r.max_new_tokens for r in reqs)
        last = logits[:, -1, :cfg.vocab_size]
        cur = jnp.argmax(last, axis=-1).astype(jnp.int32)
        generated = [cur]
        done = np.zeros(b, bool)
        steps = 0
        # Decode-loop invariants, hoisted: sampling config never changes
        # across steps, and the per-step host sync (np.asarray) is only
        # needed when some request can actually stop early on an eos.
        any_temp = any(r.temperature > 0 for r in reqs)
        if any_temp:
            temp = jnp.asarray([max(r.temperature, 1e-6)
                                for r in reqs])[:, None]
            use_t = jnp.asarray([r.temperature > 0 for r in reqs])
        track_eos = any(r.eos_id >= 0 for r in reqs)
        for t in range(max_new - 1):
            pos = jnp.asarray(plen + t, jnp.int32)
            logits, states = self._decode(params, cur, pos, states)
            logits = logits[:, :cfg.vocab_size]
            if any_temp:
                self.rng, sub = jax.random.split(self.rng)
                nxt = jax.random.categorical(sub, logits / temp, axis=-1)
                greedy = jnp.argmax(logits, axis=-1)
                cur = jnp.where(use_t, nxt, greedy).astype(jnp.int32)
            else:
                cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            generated.append(cur)
            steps += 1
            if track_eos:
                host = np.asarray(cur)
                for i, r in enumerate(reqs):
                    if r.eos_id >= 0 and host[i] == r.eos_id:
                        done[i] = True
                if done.all():
                    break

        gen = np.stack([np.asarray(g) for g in generated], axis=1)
        results = []
        for i, r in enumerate(reqs):
            row = gen[i][: r.max_new_tokens]
            if r.eos_id >= 0 and (row == r.eos_id).any():
                row = row[: int(np.argmax(row == r.eos_id)) + 1]
            results.append(Result(tokens=row, steps=steps + 1))
        return results, plan

    def _ensure_states(self, states, b: int, plen: int):
        """Grow prefill caches to max_len decode capacity."""
        cfg = self.cfg

        def pad_cache(x):
            # attention caches: (B, S, KV, dh) or stacked (L, B, S, KV, dh);
            # pad the sequence dim to max_len decode capacity.
            if x.ndim == 4 and x.shape[0] == b and x.shape[1] == plen:
                pad = self.max_len - plen
                if pad > 0:
                    return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            if x.ndim == 5 and x.shape[1] == b and x.shape[2] == plen:
                pad = self.max_len - plen
                if pad > 0:
                    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0),
                                       (0, 0)))
            return x

        return jax.tree.map(pad_cache, states)
