"""Batched serving engine: prefill + decode with greedy/temperature
sampling, continuous slot management and per-request stop handling.

The decode step is the exact function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells; on the production mesh the KV cache is
sequence-sharded over the model axis (flash-decode).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1            # -1: never stop early
    temperature: float = 0.0    # 0 = greedy


@dataclasses.dataclass
class Result:
    tokens: np.ndarray
    steps: int


class ServeEngine:
    """Static-batch engine: pads requests to a slot batch, prefills, then
    decodes all slots in lockstep, releasing finished ones."""

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 512,
                 batch_slots: int = 4, rng_seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.slots = batch_slots
        self.rng = jax.random.PRNGKey(rng_seed)

        self._decode = jax.jit(
            lambda p, t, pos, st: tfm.decode_step(p, cfg, t, pos, st))
        self._prefill = jax.jit(
            lambda p, toks: tfm.forward(p, cfg, tokens=toks,
                                        mode="prefill"))

    def generate(self, requests: List[Request]) -> List[Result]:
        out: List[Result] = []
        for i in range(0, len(requests), self.slots):
            out.extend(self._generate_batch(requests[i:i + self.slots]))
        return out

    def _generate_batch(self, reqs: List[Request]) -> List[Result]:
        cfg = self.cfg
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        toks_j = jnp.asarray(toks)

        logits, states, _ = self._prefill(self.params, toks_j)
        states = self._ensure_states(states, b, plen)

        max_new = max(r.max_new_tokens for r in reqs)
        last = logits[:, -1, :cfg.vocab_size]
        cur = jnp.argmax(last, axis=-1).astype(jnp.int32)
        generated = [cur]
        done = np.zeros(b, bool)
        steps = 0
        # Decode-loop invariants, hoisted: sampling config never changes
        # across steps, and the per-step host sync (np.asarray) is only
        # needed when some request can actually stop early on an eos.
        any_temp = any(r.temperature > 0 for r in reqs)
        if any_temp:
            temp = jnp.asarray([max(r.temperature, 1e-6)
                                for r in reqs])[:, None]
            use_t = jnp.asarray([r.temperature > 0 for r in reqs])
        track_eos = any(r.eos_id >= 0 for r in reqs)
        for t in range(max_new - 1):
            pos = jnp.asarray(plen + t, jnp.int32)
            logits, states = self._decode(self.params, cur, pos, states)
            logits = logits[:, :cfg.vocab_size]
            if any_temp:
                self.rng, sub = jax.random.split(self.rng)
                nxt = jax.random.categorical(sub, logits / temp, axis=-1)
                greedy = jnp.argmax(logits, axis=-1)
                cur = jnp.where(use_t, nxt, greedy).astype(jnp.int32)
            else:
                cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            generated.append(cur)
            steps += 1
            if track_eos:
                host = np.asarray(cur)
                for i, r in enumerate(reqs):
                    if r.eos_id >= 0 and host[i] == r.eos_id:
                        done[i] = True
                if done.all():
                    break

        gen = np.stack([np.asarray(g) for g in generated], axis=1)
        results = []
        for i, r in enumerate(reqs):
            row = gen[i][: r.max_new_tokens]
            if r.eos_id >= 0 and (row == r.eos_id).any():
                row = row[: int(np.argmax(row == r.eos_id)) + 1]
            results.append(Result(tokens=row, steps=steps + 1))
        return results

    def _ensure_states(self, states, b: int, plen: int):
        """Grow prefill caches to max_len decode capacity."""
        cfg = self.cfg

        def pad_cache(x):
            # attention caches: (B, S, KV, dh) or stacked (L, B, S, KV, dh);
            # pad the sequence dim to max_len decode capacity.
            if x.ndim == 4 and x.shape[0] == b and x.shape[1] == plen:
                pad = self.max_len - plen
                if pad > 0:
                    return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            if x.ndim == 5 and x.shape[1] == b and x.shape[2] == plen:
                pad = self.max_len - plen
                if pad > 0:
                    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0),
                                       (0, 0)))
            return x

        return jax.tree.map(pad_cache, states)
