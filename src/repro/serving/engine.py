"""Batched serving engine: prefill + decode with greedy/temperature
sampling, continuous slot management and per-request stop handling.

The decode step is the exact function the dry-run lowers for the
``decode_32k`` / ``long_500k`` cells; on the production mesh the KV cache is
sequence-sharded over the model axis (flash-decode).

Width planning and live swapping
--------------------------------
``ServingWidthPlanner`` runs the paper's Algorithm 2 per *traffic class*
(token-volume bucket): the tail-free width config that is optimal for a
32-token decode batch is not optimal for an 8k-token prefill batch (the
staircase quantum is the same but the compute/memory crossover moves), so
the planner pre-computes one width plan per class on the stacked table
engine — all layers x all candidates in one NumPy sweep, with tables
persisted through ``repro.core.table_cache`` so a planner restart skips the
pre-analysis.

Plans are *applied*, not just recorded: at each request-batch boundary —
the swap point where a width change is representable without touching
in-flight state — the engine looks up the traffic class nearest the
batch's token volume (``plan_log``) and, when a
``width_swap.WidthSwapper`` is attached, materializes the plan onto the
live param pytree (sliced MLP hidden dims and attention heads, zero-padded
within stacked scan groups) before prefilling.  The prefill then builds
KV caches directly in the plan's shapes.  Each swap is recorded in
``swap_log`` (plan, wall time, cache hit); a warm swap to an
already-seen plan is served from the swapper's plan cache with zero new
array allocations.  Build the planner's templates with
``width_swap.serving_templates`` so every ``WidthPlan`` carries the
layer-name -> ``ModuleRef`` mapping (``modules``) the swapper needs to
address the pytree.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan_address import ModuleRef
from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1            # -1: never stop early
    temperature: float = 0.0    # 0 = greedy


@dataclasses.dataclass
class Result:
    tokens: np.ndarray
    steps: int


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One serving traffic bucket: a typical per-device token volume
    (batch x padded sequence) and a latency-reduction target."""

    name: str
    tokens: int
    delta: float = 0.95       # Algorithm 2 target: L_new <= delta * L_old


@dataclasses.dataclass
class WidthPlan:
    """Per-traffic-class output of Algorithm 2: the width config to swap
    in at a batch boundary, plus its modeled latency.

    ``modules`` maps each planned layer name to its
    :class:`repro.core.plan_address.ModuleRef` pytree address — the
    hook ``width_swap.WidthSwapper`` needs to materialize the plan onto
    real params.  Plans built from planner templates without a module
    mapping stay record-only (``None``)."""

    traffic: TrafficClass
    widths: dict[str, int]
    latency_s: float
    baseline_latency_s: float
    satisfied: bool
    modules: "dict[str, ModuleRef] | None" = None

    @property
    def latency_reduction(self) -> float:
        if self.baseline_latency_s == 0:
            return 0.0
        return 1.0 - self.latency_s / self.baseline_latency_s


class ServingWidthPlanner:
    """Plans tail-free width configs per traffic class on the stacked
    table engine (paper Algorithm 2, latency-oriented).

    ``layers`` are ``TunableLayer`` templates at a reference token count;
    each traffic class re-tokens the shapes and runs one optimize pass.
    All per-class table builds go through the same
    ``TailEffectOptimizer`` — one stacked sweep per class — and, when a
    ``table_cache.ProfileTableCache`` is supplied, tables persist across
    planner restarts (a warm planner performs zero model sweeps).
    """

    def __init__(self, hw, layers: Sequence, *, cache=None,
                 tau_frac: float = 0.02,
                 modules: "dict[str, ModuleRef] | None" = None):
        from repro.core.tail_model import WaveQuantizationModel
        from repro.core.tail_optimizer import TailEffectOptimizer

        self.hw = hw
        self.layers = list(layers)
        self.model = WaveQuantizationModel(hw)
        self.opt = TailEffectOptimizer(self.model, cache=cache)
        self.tau_frac = tau_frac
        # name -> pytree address; stamped on every WidthPlan so a
        # WidthSwapper can materialize it (width_swap.serving_templates
        # builds layers and modules as a matched pair).
        self.modules = modules
        self.plans: dict[str, WidthPlan] = {}

    def _retokened(self, tokens: int) -> list:
        out = []
        for tl in self.layers:
            if tl.layer.tokens == tokens:
                out.append(tl)
                continue
            layer = dataclasses.replace(tl.layer, tokens=tokens)
            # A measured profile is only valid at the token count it was
            # profiled with — re-tokened classes must fall back to the
            # analytic model rather than silently reuse stale latencies.
            out.append(dataclasses.replace(tl, layer=layer, measured=None))
        return out

    def plan(self, traffic: Sequence[TrafficClass]) -> dict[str, WidthPlan]:
        """One Algorithm 2 pass per traffic class; results are kept on the
        planner for ``select`` and returned keyed by class name."""
        total_p = sum(tl.params(tl.layer.width) for tl in self.layers)
        for tc in traffic:
            res = self.opt.optimize_latency(
                self._retokened(tc.tokens),
                tau=self.tau_frac * total_p,
                delta=tc.delta)
            self.plans[tc.name] = WidthPlan(
                traffic=tc,
                widths=res.new_widths,
                latency_s=res.latency_new_s,
                baseline_latency_s=res.latency_old_s,
                satisfied=res.satisfied,
                modules=self.modules)
        return self.plans

    def select(self, tokens: int) -> WidthPlan:
        """The planned class nearest (log-scale) to a batch's token
        volume — the boundary-time lookup ``ServeEngine`` performs.

        ``tokens`` is clamped to >= 1 (an empty batch selects the
        smallest class); an exact log-distance tie resolves to the class
        planned first (``min`` is stable over insertion order), so the
        boundary lookup is deterministic."""
        if not self.plans:
            raise ValueError("no plans yet: call plan() first")
        best = min(
            self.plans.values(),
            key=lambda p: abs(np.log(max(tokens, 1))
                              - np.log(max(p.traffic.tokens, 1))))
        return best


class ServeEngine:
    """Static-batch engine: pads requests to a slot batch, prefills, then
    decodes all slots in lockstep, releasing finished ones."""

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 512,
                 batch_slots: int = 4, rng_seed: int = 0,
                 planner: "ServingWidthPlanner | None" = None,
                 swapper=None):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.slots = batch_slots
        self.rng = jax.random.PRNGKey(rng_seed)
        # Width planning: at each batch boundary the engine looks up the
        # traffic class nearest the batch's token volume (plan_log) and,
        # with a width_swap.WidthSwapper attached, swaps the plan onto
        # the live params before prefilling (swap_log).  Each distinct
        # plan's param shapes get their own jit specialization; the
        # swapper's plan cache makes repeat boundaries allocation-free.
        self.planner = planner
        self.swapper = swapper
        self.plan_log: List[WidthPlan] = []
        self.swap_log: List = []

        self._decode = jax.jit(
            lambda p, t, pos, st: tfm.decode_step(p, cfg, t, pos, st))
        self._prefill = jax.jit(
            lambda p, toks: tfm.forward(p, cfg, tokens=toks,
                                        mode="prefill"))

    def generate(self, requests: List[Request]) -> List[Result]:
        out: List[Result] = []
        for i in range(0, len(requests), self.slots):
            out.extend(self._generate_batch(requests[i:i + self.slots]))
        return out

    def _generate_batch(self, reqs: List[Request]) -> List[Result]:
        cfg = self.cfg
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        params = self.params
        if self.planner is not None:
            plan = self.planner.select(b * plen)
            self.plan_log.append(plan)
            if self.swapper is not None:
                # The actual swap: materialize the plan onto the live
                # params (cached per realized width assignment).  The
                # prefill below then builds KV caches in the plan's
                # shapes, so no in-flight state is ever re-shaped.
                # A plan without a module mapping raises here (build
                # templates via width_swap.serving_templates) rather
                # than silently serving full-width weights.
                params, event = self.swapper.apply(plan)
                self.swap_log.append(event)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        toks_j = jnp.asarray(toks)

        logits, states, _ = self._prefill(params, toks_j)
        states = self._ensure_states(states, b, plen)

        max_new = max(r.max_new_tokens for r in reqs)
        last = logits[:, -1, :cfg.vocab_size]
        cur = jnp.argmax(last, axis=-1).astype(jnp.int32)
        generated = [cur]
        done = np.zeros(b, bool)
        steps = 0
        # Decode-loop invariants, hoisted: sampling config never changes
        # across steps, and the per-step host sync (np.asarray) is only
        # needed when some request can actually stop early on an eos.
        any_temp = any(r.temperature > 0 for r in reqs)
        if any_temp:
            temp = jnp.asarray([max(r.temperature, 1e-6)
                                for r in reqs])[:, None]
            use_t = jnp.asarray([r.temperature > 0 for r in reqs])
        track_eos = any(r.eos_id >= 0 for r in reqs)
        for t in range(max_new - 1):
            pos = jnp.asarray(plen + t, jnp.int32)
            logits, states = self._decode(params, cur, pos, states)
            logits = logits[:, :cfg.vocab_size]
            if any_temp:
                self.rng, sub = jax.random.split(self.rng)
                nxt = jax.random.categorical(sub, logits / temp, axis=-1)
                greedy = jnp.argmax(logits, axis=-1)
                cur = jnp.where(use_t, nxt, greedy).astype(jnp.int32)
            else:
                cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            generated.append(cur)
            steps += 1
            if track_eos:
                host = np.asarray(cur)
                for i, r in enumerate(reqs):
                    if r.eos_id >= 0 and host[i] == r.eos_id:
                        done[i] = True
                if done.all():
                    break

        gen = np.stack([np.asarray(g) for g in generated], axis=1)
        results = []
        for i, r in enumerate(reqs):
            row = gen[i][: r.max_new_tokens]
            if r.eos_id >= 0 and (row == r.eos_id).any():
                row = row[: int(np.argmax(row == r.eos_id)) + 1]
            results.append(Result(tokens=row, steps=steps + 1))
        return results

    def _ensure_states(self, states, b: int, plen: int):
        """Grow prefill caches to max_len decode capacity."""
        cfg = self.cfg

        def pad_cache(x):
            # attention caches: (B, S, KV, dh) or stacked (L, B, S, KV, dh);
            # pad the sequence dim to max_len decode capacity.
            if x.ndim == 4 and x.shape[0] == b and x.shape[1] == plen:
                pad = self.max_len - plen
                if pad > 0:
                    return jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
            if x.ndim == 5 and x.shape[1] == b and x.shape[2] == plen:
                pad = self.max_len - plen
                if pad > 0:
                    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0),
                                       (0, 0)))
            return x

        return jax.tree.map(pad_cache, states)
