"""Seeded fault-injection harness for the serving stack.

Resilience claims that are only exercised by production incidents are
not claims, they are hopes.  This module makes the failure modes that
create serving tails *injectable, deterministic, and cheap*:

  * :class:`SwapFailureInjector` — installed as a
    ``WidthSwapper.fault_hook``; raises :class:`InjectedFault` at the
    named swap checkpoints (``width_swap.SWAP_STEPS``) at a seeded rate,
    proving ``apply_guarded`` rolls back to the canonical tree.
  * :class:`ReshapeFailureInjector` — installed as a
    ``WidthSwapper.reshape_fault_hook``; faults ``reshape_states``
    mid-boundary (params committed, KV caches mid-rewrite), the window
    where the continuous engine's transaction recovery is proven.
  * :class:`SlowBatchInjector` — wraps a batch-cost function; a seeded
    fraction of batches pay an extra latency (the "one straggler batch"
    tail generator from the long-tail playbook).
  * :class:`CacheCorruptor` — flips a seeded fraction of
    ``ProfileTableCache`` npz entries to garbage on disk, driving the
    cache's retry-then-quarantine path.
  * :class:`VirtualClock` + :func:`modeled_batch_cost` — a simulated
    time base: the engine's deadlines, EWMA and percentiles run on a
    clock that only advances by *modeled* batch costs (each plan's own
    predicted latency ratio), so a chaos scenario's shed set, deadline
    misses and p50/p99 are exactly reproducible from the seed — on any
    machine, under any load.
  * :func:`burst_requests` — an open-loop burst of deadline-carrying
    requests (open-loop because closed-loop load generators coordinate
    with the victim and hide the tail).
  * :class:`TrafficLoad` + :func:`open_loop_arrivals` — seeded Poisson
    arrival schedules per traffic class (with optional spikes) for the
    continuous engine, reported per class by :class:`TailReport`
    (p50/p99/p99.9) via :func:`class_tail_reports`.

Every injector draws from its own ``numpy`` Generator seeded at
construction: two harnesses built with the same seeds inject the same
faults at the same points, which is what lets the chaos tier assert
exact outcomes (who was shed, which swaps rolled back) rather than
statistical ones.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.serving.width_swap import SWAP_STEPS


class InjectedFault(RuntimeError):
    """A deliberately injected failure — never raised by real code."""


class VirtualClock:
    """Deterministic time base: callable like ``time.monotonic`` but
    only advances when told to (the engine advances it by each batch's
    simulated cost when a ``batch_cost_fn`` is attached)."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        dt = float(dt)
        if dt < 0:
            # A monotonic clock cannot run backwards.  A negative dt is
            # always a harness bug (a mis-ordered event or a bad cost
            # model) and used to corrupt every downstream latency and
            # deadline silently — fail loudly instead.
            raise ValueError(
                f"VirtualClock.advance(dt={dt}): negative dt would make "
                f"the monotonic clock run backwards")
        self.now += dt
        return self.now


class SwapFailureInjector:
    """Seeded ``fault_hook`` raising :class:`InjectedFault` mid-swap.

    ``rate`` is the per-swap failure probability; the Bernoulli draw
    happens once per matching step, so a rate of 1.0 fails every swap at
    the first matching step and 0.0 never fires.  ``steps`` defaults to
    the materialize checkpoint (the widest window in a real swap); pass
    any subset of ``width_swap.SWAP_STEPS`` to move the failure point.
    """

    def __init__(self, rate: float, *, seed: int = 0,
                 steps: Sequence[str] = ("materialize",)):
        for s in steps:
            if s not in SWAP_STEPS:
                raise ValueError(f"unknown swap step {s!r}; expected "
                                 f"a subset of {SWAP_STEPS}")
        self.rate = float(rate)
        self.steps = tuple(steps)
        self.rng = np.random.default_rng(seed)
        self.calls = 0          # matching-step evaluations
        self.injected = 0       # faults actually raised

    def __call__(self, step: str) -> None:
        if step not in self.steps:
            return
        self.calls += 1
        if self.rng.random() < self.rate:
            self.injected += 1
            raise InjectedFault(
                f"injected swap failure #{self.injected} at {step!r}")


class ReshapeFailureInjector:
    """Seeded ``WidthSwapper.reshape_fault_hook`` — faults the *state*
    half of a boundary crossing.

    ``SwapFailureInjector`` breaks the parameter swap, which
    ``apply_guarded`` rolls back before any live state is touched.  This
    injector fires inside ``reshape_states`` instead: the params have
    already committed, the KV caches are mid-rewrite — the exact window
    where a naive engine strands its in-flight requests.  The continuous
    engine treats it as a transaction abort (canonical tree restored,
    every in-flight request requeued with its tokens intact), which is
    what the chaos tier proves.
    """

    def __init__(self, rate: float, *, seed: int = 0):
        self.rate = float(rate)
        self.rng = np.random.default_rng(seed)
        self.calls = 0          # reshape attempts evaluated
        self.injected = 0       # faults actually raised

    def __call__(self) -> None:
        self.calls += 1
        if self.rng.random() < self.rate:
            self.injected += 1
            raise InjectedFault(
                f"injected KV-reshape failure #{self.injected}")


class CompileFailureInjector:
    """Seeded ``WidthVariantCompileCache.fault_hook`` — faults the AOT
    executable layer of a boundary crossing.

    ``steps`` selects which ``compile_cache.COMPILE_STEPS`` checkpoints
    can fire: ``"lower"``/``"compile"`` break plan-time AOT compilation
    (the cache entry is never built), ``"lookup"`` breaks the serve-time
    executable fetch (a warm entry becomes unreachable).  In every case
    the cache's contract is to fall back to the ordinary traced jit path
    — requests must finish with identical tokens and zero losses, which
    is what the chaos tier asserts.
    """

    def __init__(self, rate: float, *, seed: int = 0,
                 steps: Sequence[str] = ("lookup",)):
        from repro.serving.compile_cache import COMPILE_STEPS
        for s in steps:
            if s not in COMPILE_STEPS:
                raise ValueError(f"unknown compile step {s!r}; expected "
                                 f"a subset of {COMPILE_STEPS}")
        self.rate = float(rate)
        self.steps = tuple(steps)
        self.rng = np.random.default_rng(seed)
        self.calls = 0          # matching-step evaluations
        self.injected = 0       # faults actually raised

    def __call__(self, step: str) -> None:
        if step not in self.steps:
            return
        self.calls += 1
        if self.rng.random() < self.rate:
            self.injected += 1
            raise InjectedFault(
                f"injected compile-cache failure #{self.injected} "
                f"at {step!r}")


class SlowBatchInjector:
    """Seeded straggler batches: wraps a base batch cost, adding
    ``extra_s`` with probability ``rate`` per batch."""

    def __init__(self, rate: float, extra_s: float, *, seed: int = 0):
        self.rate = float(rate)
        self.extra_s = float(extra_s)
        self.rng = np.random.default_rng(seed)
        self.injected = 0

    def __call__(self, base_s: float) -> float:
        if self.rng.random() < self.rate:
            self.injected += 1
            return base_s + self.extra_s
        return base_s


def modeled_batch_cost(per_token_s: float, *, overhead_s: float = 0.0,
                       slow: "SlowBatchInjector | None" = None
                       ) -> Callable:
    """A ``ServeEngine.batch_cost_fn`` driven by the plan's own model.

    Cost = ``overhead_s + per_token_s * tokens * ratio`` where ``ratio``
    is the plan's modeled ``latency_s / baseline_latency_s`` (1.0 for
    full width / no plan).  This is exactly the counterfactual the
    paper's tables promise — a narrower plan speeds a batch by its
    predicted reduction — which makes the degraded-vs-full p99 gap in a
    chaos run a direct measurement of the ladder's modeled win, free of
    host noise.  An optional :class:`SlowBatchInjector` composes on top.
    """

    def cost(plan, tokens: int) -> float:
        ratio = 1.0
        if plan is not None and getattr(plan, "baseline_latency_s", 0.0):
            ratio = plan.latency_s / plan.baseline_latency_s
        base = overhead_s + per_token_s * float(tokens) * ratio
        return slow(base) if slow is not None else base

    return cost


class ReplicaStallInjector:
    """Gray-failure straggler replica: wraps one replica's base batch
    cost (compose via ``modeled_batch_cost(..., slow=...)``), multiplying
    every costed step inside a deterministic step window by ``factor``
    (optionally thinned by a seeded ``rate``).  Unlike
    :class:`SlowBatchInjector` — an occasional straggler *batch* — this
    models a *machine* going slow (thermal throttling, a noisy
    neighbor, a dying disk): every step of one replica pays, which is
    the failure mode replica routing + hedging exist to bound."""

    def __init__(self, factor: float, *, start_step: int = 0,
                 n_steps: int = 10 ** 9, rate: float = 1.0, seed: int = 0):
        if factor < 1.0:
            raise ValueError(f"stall factor must be >= 1 (got {factor})")
        self.factor = float(factor)
        self.start_step = max(int(start_step), 0)
        self.n_steps = max(int(n_steps), 0)
        self.rate = float(rate)
        self.rng = np.random.default_rng(seed)
        self.calls = 0          # costed steps evaluated
        self.injected = 0       # steps actually slowed

    def __call__(self, base_s: float) -> float:
        i = self.calls
        self.calls += 1
        if self.start_step <= i < self.start_step + self.n_steps \
                and self.rng.random() < self.rate:
            self.injected += 1
            return base_s * self.factor
        return base_s


class ReplicaCrashInjector:
    """Replica death: raises :class:`InjectedFault` out of the replica's
    batch-cost call — mid-step, after tokens were appended but before
    the clock advanced, the worst spot — on the ``at_step``-th costed
    step (and/or at a seeded ``rate``).  The router's contract is to
    mark the replica dead, evict its in-flight work and requeue it onto
    healthy replicas with generated tokens intact — zero lost requests.
    Compose via ``modeled_batch_cost(..., slow=...)``."""

    def __init__(self, *, at_step: Optional[int] = None, rate: float = 0.0,
                 seed: int = 0):
        self.at_step = None if at_step is None else int(at_step)
        self.rate = float(rate)
        self.rng = np.random.default_rng(seed)
        self.calls = 0          # costed steps evaluated
        self.injected = 0       # crashes raised

    def __call__(self, base_s: float) -> float:
        i = self.calls
        self.calls += 1
        if (self.at_step is not None and i == self.at_step) or (
                self.rate > 0 and self.rng.random() < self.rate):
            self.injected += 1
            raise InjectedFault(
                f"injected replica crash at costed step {i}")
        return base_s


class ChunkFaultInjector:
    """Seeded ``ContinuousServeEngine.chunk_fault_hook`` — faults a
    prefill *chunk* mid-prefill.  The engine's contract is that chunk
    boundaries are recovery checkpoints: the request requeues holding
    every committed chunk and resumes from the last one — never from
    token zero — within its retry budget."""

    def __init__(self, rate: float, *, seed: int = 0):
        self.rate = float(rate)
        self.rng = np.random.default_rng(seed)
        self.calls = 0          # chunk executions evaluated
        self.injected = 0       # faults actually raised

    def __call__(self) -> None:
        self.calls += 1
        if self.rng.random() < self.rate:
            self.injected += 1
            raise InjectedFault(
                f"injected prefill-chunk failure #{self.injected}")


class CacheCorruptor:
    """Seeded on-disk corruption of ``ProfileTableCache`` entries.

    ``strike()`` walks the live ``*.npz`` entries in sorted order (so
    the seed fully determines which files are hit) and, at ``rate``,
    overwrites each with garbage bytes — the torn-write/bit-rot case the
    cache's quarantine path exists for.  Returns the corrupted paths.
    """

    def __init__(self, cache, rate: float = 1.0, *, seed: int = 0):
        self.cache = cache
        self.rate = float(rate)
        self.rng = np.random.default_rng(seed)
        self.corrupted: List[Path] = []

    def strike(self) -> List[Path]:
        hit = []
        for path in sorted(self.cache.root.glob("??/*.npz")):
            if self.rng.random() >= self.rate:
                continue
            garbage = self.rng.integers(0, 256, size=64,
                                        dtype=np.uint8).tobytes()
            try:
                path.write_bytes(b"\x00CHAOS" + garbage)
            except OSError:
                continue
            hit.append(path)
        self.corrupted.extend(hit)
        return hit


def burst_requests(vocab_size: int, *, n: int, prompt_len: int = 8,
                   max_new_tokens: int = 4,
                   deadline_s: Optional[float] = None,
                   seed: int = 0) -> list:
    """An open-loop burst: ``n`` requests, all arriving at once (the
    engine stamps arrival at ``generate`` time), each carrying the same
    completion deadline.  Prompts are seeded random tokens."""
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    return [
        Request(prompt=rng.integers(0, vocab_size, size=(prompt_len,))
                .astype(np.int32),
                max_new_tokens=max_new_tokens, deadline_s=deadline_s)
        for _ in range(n)
    ]


@dataclasses.dataclass(frozen=True)
class TrafficLoad:
    """One traffic class of an open-loop workload: ``rate_rps`` Poisson
    arrivals per second for ``duration_s``, each request drawn with this
    class's shape.  ``burst_at``/``burst_n`` optionally drop an
    instantaneous burst on top (the 4x-spike scenario)."""

    name: str
    rate_rps: float
    duration_s: float
    prompt_len: int = 8
    max_new_tokens: int = 8
    deadline_s: Optional[float] = None
    burst_at: Optional[float] = None
    burst_n: int = 0


def open_loop_arrivals(loads: Sequence[TrafficLoad], vocab_size: int,
                       *, seed: int = 0) -> list:
    """Seeded open-loop arrival schedule across traffic classes.

    Per class, inter-arrival gaps are exponential at ``rate_rps``
    (Poisson process) over ``duration_s``; an optional burst adds
    ``burst_n`` simultaneous arrivals at ``burst_at``.  Classes are
    merged and sorted by time.  Open-loop: arrival times never depend on
    the server, so a saturated engine sees the queue it would see in
    production rather than a politely back-pressured one.  The schedule
    is a pure function of ``seed``.
    """
    from repro.serving.continuous import Arrival
    from repro.serving.engine import Request

    # Spike-schedule validation.  Both defects used to pass silently and
    # only surface downstream as inexplicable tails: a burst outside its
    # load's [0, duration_s] window extends the run past the schedule
    # the caller asked for, and two classes spiking at the *same
    # instant* interleave purely by list order — the per-class arrival
    # ordering (and therefore the whole deterministic run) silently
    # depends on how the loads were listed rather than on the seed.
    spikes: dict = {}
    for load in loads:
        if load.burst_at is None or load.burst_n <= 0:
            continue
        t = float(load.burst_at)
        if not 0.0 <= t <= load.duration_s:
            raise ValueError(
                f"load {load.name!r}: burst_at={t} outside its "
                f"[0, duration_s={load.duration_s}] window")
        if t in spikes:
            raise ValueError(
                f"overlapping spike schedules: loads {spikes[t]!r} and "
                f"{load.name!r} both burst at t={t}")
        spikes[t] = load.name

    out = []
    for k, load in enumerate(loads):
        rng = np.random.default_rng(seed + 7919 * k)

        def req():
            return Request(
                prompt=rng.integers(0, vocab_size,
                                    size=(load.prompt_len,))
                .astype(np.int32),
                max_new_tokens=load.max_new_tokens,
                deadline_s=load.deadline_s)

        t = 0.0
        if load.rate_rps > 0:
            while True:
                t += float(rng.exponential(1.0 / load.rate_rps))
                if t >= load.duration_s:
                    break
                out.append(Arrival(t=t, request=req(), klass=load.name))
        if load.burst_at is not None:
            for _ in range(load.burst_n):
                out.append(Arrival(t=float(load.burst_at), request=req(),
                                   klass=load.name))
    out.sort(key=lambda a: a.t)
    return out


@dataclasses.dataclass
class TailReport:
    """Latency tail for one traffic class of an open-loop run."""

    name: str
    completed: int
    shed: int
    failed: int
    recovered: int
    p50_s: float
    p99_s: float
    p999_s: float

    @classmethod
    def build(cls, name: str, results) -> "TailReport":
        done = [r for r in results if not r.shed and not r.failed]
        lats = np.array([r.latency_s for r in done])
        nan = float("nan")
        return cls(
            name=name, completed=len(done),
            shed=sum(r.shed for r in results),
            failed=sum(getattr(r, "failed", False) for r in results),
            recovered=sum(getattr(r, "recovered", False)
                          for r in results),
            p50_s=float(np.percentile(lats, 50)) if lats.size else nan,
            p99_s=float(np.percentile(lats, 99)) if lats.size else nan,
            p999_s=float(np.percentile(lats, 99.9)) if lats.size else nan,
        )


def class_tail_reports(arrivals, results) -> dict:
    """Per-class :class:`TailReport` for a run of ``open_loop_arrivals``
    output through ``ContinuousServeEngine.run`` (results align with
    arrivals by position)."""
    by_class: dict = {}
    for a, r in zip(arrivals, results):
        by_class.setdefault(a.klass, []).append(r)
    return {k: TailReport.build(k, rs) for k, rs in by_class.items()}


@dataclasses.dataclass
class LoadReport:
    """Tail summary of one open-loop run (non-shed request latencies)."""

    completed: int
    shed: int
    deadline_missed: int
    p50_s: float
    p99_s: float

    @classmethod
    def from_results(cls, results) -> "LoadReport":
        lats = np.array([r.latency_s for r in results if not r.shed])
        if lats.size == 0:
            return cls(0, len(results), 0, float("nan"), float("nan"))
        return cls(
            completed=int(lats.size),
            shed=sum(r.shed for r in results),
            deadline_missed=sum(r.deadline_missed for r in results),
            p50_s=float(np.percentile(lats, 50)),
            p99_s=float(np.percentile(lats, 99)),
        )
