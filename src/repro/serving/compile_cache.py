"""Width-variant executable cache: AOT-compiled prefill/decode per plan.

Every distinct realized ``WidthPlan`` changes the param (and KV) shapes
the serving engines feed ``models.transformer``, and a fresh shape costs
a full jit trace + XLA compile (~hundreds of ms) at its first boundary
crossing — exactly the latency spike a width *optimizer* exists to
remove.  This module makes the executable itself a planned, cached
artifact, the same way ``core.table_cache.ProfileTableCache`` makes the
staircase tables one:

  * :class:`WidthVariantCompileCache` AOT-compiles (``jax.jit(...)
    .lower(...).compile()``) the prefill and decode functions for every
    plan-realizable width at *plan time* (``ServeEngine.warm_compile`` /
    ``ContinuousServeEngine.warm_compile``), keyed on
    ``(hardware fingerprint, kind, realized plan key, shape bucket)``.
    A warm boundary crossing is then a dict lookup — never a trace.
  * Serve-time entry points (:meth:`prefill` / :meth:`decode`) fall back
    to an ordinary traced ``jax.jit`` path on any miss or fault, so a
    cold or broken cache degrades to today's behavior, never to a lost
    request.  ``serving.chaos.CompileFailureInjector`` exercises exactly
    this contract through ``fault_hook``.
  * :meth:`decide` is the **cost crossover**: when a plan's modeled
    saving over the engine's horizon is smaller than one AOT compile,
    the plan should be realized as *zero-masked full-shape params*
    (``WidthSwapper.apply(plan, masked=True)``) running on the already
    -warm full-width executable — trading the plan's FLOP saving for a
    guaranteed-warm boundary.
  * :class:`TraceCounter` is the observability hook the acceptance
    assertions hang off: it wraps the Python callables handed to
    ``jax.jit``, so ``tracer.count`` increments exactly when XLA
    (re-)traces — a warm crossing leaves it unchanged.

The model functions are traced inside ``kernels.ops.kernel_context``
(``hw=`` the cache's hardware spec), so on a Pallas backend every
compiled variant runs on the wave-aligned tiles ``kernels.autotune``
picks; off-TPU the context is inert and the reference path is used
unchanged.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan_address import plan_key
from repro.kernels import ops
from repro.models import transformer as tfm

# Fault-hook checkpoints, mirroring width_swap.SWAP_STEPS: "lower" and
# "compile" fire during plan-time AOT compilation, "lookup" on every
# serve-time executable fetch.  A hook raising at any of them must leave
# the engine on the traced fallback path with zero lost requests.
COMPILE_STEPS = ("lower", "compile", "lookup")


def pow2_bucket(n: int, lo: int = 8) -> int:
    """Smallest power of two >= n (and >= lo) — the prefill length
    bucket.  Bucketing bounds the number of distinct prefill shapes (and
    therefore traces/executables) at log2(max_len) instead of one per
    distinct prompt length."""
    n = max(int(n), 1)
    b = max(int(lo), 1)
    while b < n:
        b *= 2
    return b


class TraceCounter:
    """Counts jit traces by counting Python-body executions.

    ``jax.jit`` only runs the wrapped Python callable on a trace-cache
    miss, so incrementing inside the body counts traces exactly: AOT
    ``lower()`` calls count (they trace once, at plan time), warm
    executable calls and jit-cache hits do not."""

    def __init__(self) -> None:
        self.count = 0

    def wrap(self, fn: Callable) -> Callable:
        def counted(*args, **kwargs):
            self.count += 1
            return fn(*args, **kwargs)
        return counted


@dataclasses.dataclass(frozen=True)
class CompileEvent:
    """One cache interaction, appended to ``events``."""

    kind: str           # "prefill" | "decode" | "chunk"
    key: tuple          # full executable key (fingerprint/kind/plan/shape)
    outcome: str        # "compiled" | "hit" | "miss" | "fault"
    wall_s: float = 0.0
    error: str = ""


def realized_exec_key(mlp_w, heads) -> tuple:
    """Executable key for a realized width assignment: the per-layer
    (mlp widths, head counts) the param/KV *shapes* follow.  Masked
    realizations keep canonical shapes and therefore use the cache's
    ``full_key`` instead."""
    return (tuple(int(x) for x in np.asarray(mlp_w).ravel()),
            tuple(int(x) for x in np.asarray(heads).ravel()))


class WidthVariantCompileCache:
    """AOT executable table for one model config.

    One instance per engine (``cfg`` must match the engine's): the
    engines route every prefill/decode through :meth:`prefill` /
    :meth:`decode`, and call ``set_active`` with the realized executable
    key at each boundary so lookups address the right variant.
    """

    def __init__(self, cfg: ModelConfig, *, hw=None, tile_cache=None,
                 compile_cost_s: float = 0.25, horizon_batches: int = 32,
                 fault_hook: "Callable[[str], None] | None" = None,
                 max_entries: int = 64):
        self.cfg = cfg
        self.hw = hw
        self.tile_cache = tile_cache
        if hw is not None:
            from repro.core.table_cache import hardware_fingerprint
            self.fingerprint = hardware_fingerprint(hw)
        else:
            self.fingerprint = ""
        self.compile_cost_s = float(compile_cost_s)
        self.horizon_batches = max(int(horizon_batches), 1)
        self.fault_hook = fault_hook
        self.max_entries = max(int(max_entries), 1)
        self._exec: "OrderedDict[tuple, Any]" = OrderedDict()
        self._warm_plans: set = set()
        self.events: List[CompileEvent] = []
        self.stats = {"aot_compiles": 0, "hits": 0, "misses": 0,
                      "fallbacks": 0}
        self.tracer = TraceCounter()

        n_refs = len(tfm.decoder_layer_refs(cfg))
        # Canonical full-width executable key — what masked realizations
        # and the engine's initial (unswapped) state resolve to.
        self.full_key = ((cfg.d_ff,) * n_refs, (cfg.n_heads,) * n_refs)
        self._active_key: tuple = self.full_key

        # The single pair of jit wrappers used for BOTH plan-time AOT
        # lowering and the serve-time traced fallback; their bodies run
        # under the kernel context so Pallas backends get autotuned
        # tiles (inert in ref mode — numerics unchanged).
        def prefill_fn(p, toks):
            with ops.kernel_context(hw=self.hw, cache=self.tile_cache):
                return tfm.forward(p, cfg, tokens=toks, mode="prefill")

        def decode_fn(p, t, pos, st):
            with ops.kernel_context(hw=self.hw, cache=self.tile_cache):
                return tfm.decode_step(p, cfg, t, pos, st)

        def chunk_fn(p, toks, pos, st):
            with ops.kernel_context(hw=self.hw, cache=self.tile_cache):
                return tfm.prefill_chunk(p, cfg, toks, pos, st)

        self._jit = {
            "prefill": jax.jit(self.tracer.wrap(prefill_fn)),
            "decode": jax.jit(self.tracer.wrap(decode_fn)),
            "chunk": jax.jit(self.tracer.wrap(chunk_fn)),
        }

    # ------------------------------------------------------------------
    # keys
    # ------------------------------------------------------------------
    def set_active(self, key: "tuple | None") -> None:
        """Point serve-time lookups at a realized executable key (the
        boundary-time switch).  ``None`` resets to full width."""
        self._active_key = self.full_key if key is None else tuple(key)

    @property
    def active_key(self) -> tuple:
        return self._active_key

    def _entry_key(self, kind: str, key: tuple, shape_key: tuple) -> tuple:
        return (self.fingerprint, kind, key, tuple(shape_key))

    def __len__(self) -> int:
        return len(self._exec)

    # ------------------------------------------------------------------
    # warm-plan registry (planner preference signal)
    # ------------------------------------------------------------------
    def mark_plan_warm(self, plan) -> None:
        self._warm_plans.add(plan_key(plan.widths))

    def plan_is_warm(self, plan) -> bool:
        return plan_key(plan.widths) in self._warm_plans

    # ------------------------------------------------------------------
    # cost crossover
    # ------------------------------------------------------------------
    def decide(self, plan) -> str:
        """``"sliced"`` | ``"masked"``: realize the plan with genuinely
        smaller shapes (own executable) or as zero-masked full-shape
        params on the warm full-width executable.

        The crossover prices one AOT compile against the plan's modeled
        saving over ``horizon_batches`` served batches: recompilation
        that costs more wall time than the FLOPs it saves is realized as
        a mask instead."""
        widths = getattr(plan, "widths", None)
        if not widths:
            return "sliced"     # full width: nothing to mask
        saved_per_batch = max(
            float(plan.baseline_latency_s) - float(plan.latency_s), 0.0)
        saved = saved_per_batch * self.horizon_batches
        return "sliced" if saved >= self.compile_cost_s else "masked"

    # ------------------------------------------------------------------
    # plan-time AOT compilation
    # ------------------------------------------------------------------
    def _check(self, step: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook(step)

    def precompile(self, kind: str, key: tuple, shape_key: tuple,
                   example_args: tuple) -> bool:
        """AOT-compile one (kind, realized key, shape) executable from
        example args (arrays or ShapeDtypeStructs).  Returns True when
        the entry is warm afterwards; a compile fault is recorded and
        absorbed (the serve path falls back to the traced jit)."""
        if kind not in self._jit:
            raise ValueError(f"unknown kind {kind!r}")
        ek = self._entry_key(kind, key, shape_key)
        if ek in self._exec:
            return True
        t0 = time.perf_counter()
        try:
            self._check("lower")
            lowered = self._jit[kind].lower(*example_args)
            self._check("compile")
            compiled = lowered.compile()
        except Exception as e:  # noqa: BLE001 — fault => traced fallback
            self.stats["fallbacks"] += 1
            self.events.append(CompileEvent(
                kind=kind, key=ek, outcome="fault",
                wall_s=time.perf_counter() - t0,
                error=f"{type(e).__name__}: {e}"))
            return False
        self._exec[ek] = compiled
        while len(self._exec) > self.max_entries:
            self._exec.popitem(last=False)
        self.stats["aot_compiles"] += 1
        self.events.append(CompileEvent(
            kind=kind, key=ek, outcome="compiled",
            wall_s=time.perf_counter() - t0))
        return True

    # ------------------------------------------------------------------
    # serve-time entry points
    # ------------------------------------------------------------------
    def _get(self, kind: str, shape_key: tuple):
        try:
            self._check("lookup")
        except Exception as e:  # noqa: BLE001 — fault => traced fallback
            self.stats["fallbacks"] += 1
            self.events.append(CompileEvent(
                kind=kind,
                key=self._entry_key(kind, self._active_key, shape_key),
                outcome="fault", error=f"{type(e).__name__}: {e}"))
            return None
        ek = self._entry_key(kind, self._active_key, shape_key)
        exe = self._exec.get(ek)
        if exe is None:
            self.stats["misses"] += 1
            self.events.append(CompileEvent(kind=kind, key=ek,
                                            outcome="miss"))
            return None
        self._exec.move_to_end(ek)
        self.stats["hits"] += 1
        return exe

    def prefill(self, params, toks):
        """AOT-hit prefill, else the traced fallback.  Same signature
        and return value as the engines' historical jit lambda."""
        shape_key = tuple(int(d) for d in toks.shape)
        exe = self._get("prefill", shape_key)
        if exe is not None:
            try:
                return exe(params, toks)
            except Exception:  # noqa: BLE001 — shape/aval drift => fallback
                self.stats["fallbacks"] += 1
        return self._jit["prefill"](params, toks)

    def decode(self, params, toks, pos, states):
        """AOT-hit decode step, else the traced fallback."""
        shape_key = tuple(int(d) for d in toks.shape)
        exe = self._get("decode", shape_key)
        if exe is not None:
            try:
                return exe(params, toks, pos, states)
            except Exception:  # noqa: BLE001 — shape/aval drift => fallback
                self.stats["fallbacks"] += 1
        return self._jit["decode"](params, toks, pos, states)

    def chunk(self, params, toks, pos, states):
        """AOT-hit prefill chunk (``tfm.prefill_chunk``), else the traced
        fallback.  The chunk offset ``pos`` is a traced argument, so one
        executable per chunk *shape* serves every chunk position — the
        chunked-prefill shape set is {(1, chunk)} plus the pow2 tail
        buckets, bounded exactly like bucketed whole-prompt prefill."""
        shape_key = tuple(int(d) for d in toks.shape)
        exe = self._get("chunk", shape_key)
        if exe is not None:
            try:
                return exe(params, toks, pos, states)
            except Exception:  # noqa: BLE001 — shape/aval drift => fallback
                self.stats["fallbacks"] += 1
        return self._jit["chunk"](params, toks, pos, states)


def decode_state_struct(cfg: ModelConfig, b: int, max_len: int, *,
                        swapper=None, heads=None):
    """Shape/dtype pytree of the decode state for AOT lowering — built
    under ``jax.eval_shape`` so nothing is allocated.  With a swapper +
    realized ``heads``, the canonical state is re-sliced to the plan's
    KV shapes (fault hook disabled: this is shape inference, not a
    swap)."""
    def build():
        st = tfm.init_decode_state(cfg, b, max_len)
        if swapper is not None and heads is not None:
            full = np.full(len(swapper.refs), cfg.n_heads, dtype=np.int64)
            if (np.asarray(heads) != full).any():
                st = swapper.reshape_states(st, full, np.asarray(heads))
        return st

    if swapper is not None:
        hook, swapper.reshape_fault_hook = swapper.reshape_fault_hook, None
        try:
            return jax.eval_shape(build)
        finally:
            swapper.reshape_fault_hook = hook
    return jax.eval_shape(build)
