"""Live width-swap subsystem: materialize WidthPlans onto real params.

``ServingWidthPlanner`` (engine.py) plans per-traffic-class width configs
with the paper's Algorithm 2; this module closes the model-to-hardware
gap by *applying* a plan to a real ``repro.models.transformer`` param
pytree at a batch boundary:

  * **MLP widths** slice the FFN hidden dim: ``w_up``/``w_gate`` columns
    and ``w_down`` rows cut to the planned width.
  * **Attention widths** slice query heads (KV heads follow at the GQA
    ratio) after :func:`repro.core.plan_address.snap_heads` rounds the
    planned channel count to whole realizable heads.
  * **Stacked scan units** cannot be ragged: all layers sharing a unit
    slot are cut to the *maximum* planned width in the group and the
    channels between a layer's own width and the group cut are zeroed.
    Zeroed channels are exact — a zeroed FFN channel contributes 0
    through ``w_down``, a zeroed head contributes 0 through ``w_o`` — so
    a sliced forward equals the full forward with those channels zeroed
    (property-tested in tests/test_width_swap.py).

The canonical full-width params are retained by the swapper; every plan
is materialized *from* them, so swapping down and back up is lossless
(the full plan returns the original pytree object, bit for bit).
Materialized pytrees are cached per realized width assignment
(``plan_key``): a warm swap to an already-seen plan is a dict lookup —
zero new array allocations — which is what makes per-batch swapping at
serving rates affordable (``SwapEvent.cache_hit`` records this, and the
``width_swap`` benchmark phase pins cold/warm swap cost).

KV caches are laid out per plan by prefill; for engines that retain
decode state across a boundary, :meth:`WidthSwapper.reshape_states`
re-shapes the cached K/V head axis to the new plan — exact when
shrinking (kept heads keep their history), zero-filled when growing
(new heads have no history; the paper swaps at batch boundaries
precisely so this case starts from a fresh prefill).
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Mapping, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.candidates import analytic_candidates, realizable_candidates
from repro.core.plan_address import ModuleRef, plan_key, snap_heads
from repro.core.tail_model import LayerShape
from repro.core.tail_optimizer import TunableLayer
from repro.models import transformer as tfm


# ---------------------------------------------------------------------------
# templates: a transformer config as TunableLayers + module addresses
# ---------------------------------------------------------------------------
def serving_templates(cfg: ModelConfig, hw, *, tokens: int = 4096,
                      sites: Sequence[str] = ("mlp",),
                      shard_out: int = 1):
    """TunableLayer templates plus the name -> ModuleRef mapping for a
    transformer config — the two halves a live swap needs: the planner
    optimizes the templates, the swapper addresses the pytree.

    One template per decoder layer per requested site: ``"mlp"`` for
    dense-FFN layers (width = ``d_ff``), ``"attn"`` for self-attention
    layers (width = ``n_heads * head_dim`` channels).  MoE/recurrent
    layers have no width-swap site and are skipped.  Candidates come
    from the analytic staircase *on the realizable grid per site* —
    lane multiples for FFN widths, whole GQA head groups
    (``g * head_dim`` multiples) for attention — so every planned width
    is materializable by :class:`WidthSwapper` as-is, with no swap-time
    re-snap changing the width the plan was ranked by.  All candidates
    are capped at the canonical width — a live swap can only *slice*
    the trained weights, never invent wider ones.
    """
    for s in sites:
        if s not in ("mlp", "attn"):
            raise ValueError(f"unknown site {s!r}")
    d = cfg.d_model
    g = cfg.n_heads // max(cfg.n_kv_heads, 1)
    templates: list[TunableLayer] = []
    modules: dict[str, ModuleRef] = {}
    for i, (kind, mlpk) in enumerate(tfm.layer_plan(cfg, encoder=False)):
        if "mlp" in sites and mlpk == "dense":
            name = f"mlp{i}"
            shape = LayerShape(name, tokens=tokens, d_in=d, width=cfg.d_ff,
                               shard_out=shard_out)
            cands = analytic_candidates(hw, shape, max_width=cfg.d_ff)
            cands = cands[cands <= cfg.d_ff]
            if cands.size == 0:
                cands = np.array([cfg.d_ff], dtype=np.int64)
            templates.append(TunableLayer(
                layer=shape, candidates=cands,
                params_per_unit=(3 if cfg.mlp_gated else 2) * d,
                max_width=cfg.d_ff))
            modules[name] = ModuleRef(i, "mlp")
        if "attn" in sites and kind in ("attn", "local"):
            name = f"attn{i}"
            full_w = cfg.n_heads * cfg.head_dim
            shape = LayerShape(name, tokens=tokens, d_in=d, width=full_w,
                               shard_out=shard_out,
                               flop_multiplier=2.0 + 2.0 / g)
            # realizable grid: whole heads in GQA group-size multiples,
            # so a ladder/planner width never needs a swap-time re-snap
            cands = realizable_candidates(
                hw, shape, realize_quantum=g * cfg.head_dim,
                max_width=full_w, min_width=g * cfg.head_dim)
            if full_w not in cands:
                cands = np.append(cands, full_w)
            templates.append(TunableLayer(
                layer=shape, candidates=cands,
                # q + o rows per channel, k + v at the GQA ratio
                params_per_unit=2 * d + 2 * d / g,
                min_width=g * cfg.head_dim, max_width=full_w))
            modules[name] = ModuleRef(i, "attn")
    return templates, modules


# ---------------------------------------------------------------------------
# slicing primitives
# ---------------------------------------------------------------------------
def _mask(widths, wmax: int, stacked: bool):
    """Boolean keep-mask over the cut axis; None when nothing is masked
    (every layer in the group uses the full cut width)."""
    w = np.asarray(widths, dtype=np.int64)
    if (w == wmax).all():
        return None
    if stacked:
        return jnp.asarray(np.arange(wmax)[None, :] < w[:, None])
    return jnp.asarray(np.arange(wmax) < int(w))


def _expand(m, stacked: bool, before: int, after: int):
    """Reshape a keep-mask for broadcasting against a param tensor whose
    cut axis sits ``before`` axes after the (optional) stacked leading
    axis and ``after`` axes before the end."""
    if m is None:
        return None
    if stacked:  # (U, w) -> (U, 1*before, w, 1*after)
        shape = (m.shape[0],) + (1,) * before + (m.shape[1],) + (1,) * after
    else:        # (w,) -> (w, 1*after); leading dims broadcast on the left
        shape = (m.shape[0],) + (1,) * after
    return m.reshape(shape)


def _cut(x, m, axis_from_end: int, size: int):
    """Slice one axis (counted from the end) to ``size`` and zero the
    entries ``m`` masks out (``m`` pre-shaped for broadcasting)."""
    idx = [slice(None)] * x.ndim
    idx[x.ndim - 1 - axis_from_end] = slice(0, size)
    x = x[tuple(idx)]
    return x if m is None else jnp.where(m, x, 0)


def _slice_mlp(p: dict, widths, wmax: int, stacked: bool) -> dict:
    """Cut the FFN hidden dim of an (optionally stacked) mlp param dict
    to ``wmax`` columns, zeroing columns past each layer's own width."""
    m = _mask(widths, wmax, stacked)
    out = dict(p)
    for k in ("w_up", "w_gate"):
        if k in out:  # (..., d, f)
            out[k] = _cut(out[k], _expand(m, stacked, 1, 0), 0, wmax)
    out["w_down"] = _cut(out["w_down"], _expand(m, stacked, 0, 1), 1, wmax)
    if "b_up" in out:  # (..., f)
        out["b_up"] = _cut(out["b_up"], _expand(m, stacked, 0, 0), 0, wmax)
    return out


def _slice_attn(p: dict, heads, hmax: int, g: int, stacked: bool) -> dict:
    """Cut query heads to ``hmax`` (KV heads to ``hmax // g``), zeroing
    the projections of heads past each layer's own count.  Zeroing w_o
    rows alone removes a head's contribution; w_q/w_k/w_v are zeroed
    too so padded heads write exact zeros into the KV cache."""
    kvmax = max(hmax // g, 1)
    qm = _mask(heads, hmax, stacked)
    kvm = _mask(np.maximum(np.asarray(heads, dtype=np.int64) // g, 1),
                kvmax, stacked)
    out = dict(p)
    # wq (..., d, h, dh) / wk, wv (..., d, kv, dh): cut axis -2
    for k, hsz, m in (("wq", hmax, qm), ("wk", kvmax, kvm),
                      ("wv", kvmax, kvm)):
        if k in out:
            out[k] = _cut(out[k], _expand(m, stacked, 1, 1), 1, hsz)
    # wo (..., h, dh, d): cut axis -3
    out["wo"] = _cut(out["wo"], _expand(qm, stacked, 0, 2), 2, hmax)
    # biases (..., h|kv, dh): cut axis -2
    for k, hsz, m in (("bq", hmax, qm), ("bk", kvmax, kvm),
                      ("bv", kvmax, kvm)):
        if k in out:
            out[k] = _cut(out[k], _expand(m, stacked, 0, 1), 1, hsz)
    return out


def _resize_axis(x, axis: int, size: int):
    """Slice or zero-pad one axis of ``x`` to ``size``."""
    cur = x.shape[axis]
    if cur == size:
        return x
    if cur > size:
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(0, size)
        return x[tuple(idx)]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, size - cur)
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# the swapper
# ---------------------------------------------------------------------------
# Named checkpoints inside apply(), in execution order.  A fault_hook
# installed on the swapper is called with each step name and may raise —
# the chaos harness (serving.chaos.SwapFailureInjector) uses this to
# prove apply_guarded() rolls back cleanly from a failure at ANY step.
SWAP_STEPS = ("begin", "realize", "materialize", "commit", "finish")


@dataclasses.dataclass(frozen=True)
class SwapEvent:
    """One boundary swap, as recorded in ``ServeEngine.swap_log``."""

    plan_name: str            # traffic class the plan was built for
    key: tuple                # canonical realized-width identity
    realized: tuple           # ((module name, realized channel width), ...)
    swap_s: float             # wall time of the apply() call
    cache_hit: bool           # True: served from the plan cache, 0 allocs
    outcome: str = "ok"       # "ok" | "rolled_back" (guarded swap failed)
    error: str = ""           # repr of the mid-swap exception, if any
    masked: bool = False      # zero-masked full-shape realization (the
    #                           compile-cache cost-crossover rule)


class WidthSwapper:
    """Applies WidthPlans to a live param pytree, with a per-plan cache.

    ``full_params`` is the canonical tree; every plan is sliced from it
    (swap-back is lossless).  ``apply`` returns the materialized params
    plus a :class:`SwapEvent`; repeated swaps to the same realized plan
    return the cached tree with zero new array allocations.  ``max_plans``
    bounds the cache (LRU) — a serving tier has a handful of traffic
    classes, so the working set is small by construction.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_plans: int = 8,
                 fault_hook=None, reshape_fault_hook=None):
        self.full_params = params
        self.cfg = cfg
        self.refs = tfm.decoder_layer_refs(cfg)
        self.max_plans = max(int(max_plans), 1)
        self._cache: "OrderedDict[tuple, dict]" = OrderedDict()
        self._group_g = cfg.n_heads // max(cfg.n_kv_heads, 1)
        # Optional callable(step_name) invoked at every SWAP_STEPS
        # checkpoint inside apply(); it may raise to simulate a mid-swap
        # failure (the chaos harness's injection point).
        self.fault_hook = fault_hook
        # Optional callable() invoked at the top of reshape_states —
        # the KV-reshape analogue of fault_hook (the continuous engine's
        # boundary transaction must survive a fault here too; see
        # serving.chaos.ReshapeFailureInjector).
        self.reshape_fault_hook = reshape_fault_hook

    def _step(self, name: str) -> None:
        if self.fault_hook is not None:
            self.fault_hook(name)

    # ---- realization ---------------------------------------------------
    def realize(self, widths: Mapping[str, int],
                modules: Mapping[str, ModuleRef]):
        """Planned name->width mapping -> per-decoder-layer realized
        (mlp_width, query_heads) arrays.  Unplanned layers keep their
        canonical width.  Raises on names without an address or plans
        targeting a site the layer does not have."""
        cfg = self.cfg
        n = len(self.refs)
        mlp_w = np.full(n, cfg.d_ff, dtype=np.int64)
        heads = np.full(n, cfg.n_heads, dtype=np.int64)
        for name, w in widths.items():
            ref = modules.get(name)
            if ref is None:
                raise ValueError(f"plan names {name!r} but the module "
                                 f"mapping has no address for it")
            if ref.layer >= n:
                raise ValueError(f"{name!r} addresses layer {ref.layer} "
                                 f"but the model has {n} decoder layers")
            meta = self.refs[ref.layer]
            if ref.site == "mlp":
                if meta["mlp_kind"] != "dense":
                    raise ValueError(
                        f"{name!r}: layer {ref.layer} has mlp_kind "
                        f"{meta['mlp_kind']!r}, not a sliceable dense FFN")
                mlp_w[ref.layer] = min(max(int(w), 1), cfg.d_ff)
            else:
                if meta["kind"] not in ("attn", "local"):
                    raise ValueError(
                        f"{name!r}: layer {ref.layer} is {meta['kind']!r}, "
                        f"not self-attention")
                heads[ref.layer] = snap_heads(int(w), cfg.head_dim,
                                              cfg.n_heads, cfg.n_kv_heads)
        return mlp_w, heads

    def realized_widths(self, mlp_w, heads,
                        modules: Mapping[str, ModuleRef]) -> tuple:
        """Canonical ((name, channel width), ...) for the addressed
        modules — names come from the plan's own mapping, so SwapEvent
        entries always correlate with ``plan.widths`` keys."""
        out = {}
        for name, ref in modules.items():
            if ref.site == "mlp":
                out[name] = int(mlp_w[ref.layer])
            else:
                out[name] = int(heads[ref.layer]) * self.cfg.head_dim
        return plan_key(out)

    # ---- materialization -----------------------------------------------
    def materialize(self, mlp_w, heads, *, pad_to_full: bool = False):
        """Build the param tree realizing per-layer widths.

        ``pad_to_full`` keeps every array at its canonical shape and only
        zeroes the dropped channels — the reference the equivalence
        property compares against (sliced == zeroed, channel for
        channel)."""
        cfg = self.cfg
        cycle = tfm.unit_cycle(cfg)
        n_units = len(self.refs) // cycle
        g = self._group_g

        def cut_unit(unit: dict, lids: list, stacked: bool) -> dict:
            # `stacked` is the group type, not len(lids): a stack with a
            # single unit still carries the leading unit axis.
            meta = self.refs[lids[0]]
            out = unit
            if meta["mlp_kind"] == "dense" and "mlp" in unit:
                w = mlp_w[lids] if stacked else mlp_w[lids[0]]
                wmax = cfg.d_ff if pad_to_full else int(np.max(w))
                if pad_to_full or wmax < cfg.d_ff \
                        or (np.asarray(w) != wmax).any():
                    out = dict(out)
                    out["mlp"] = _slice_mlp(unit["mlp"], w, wmax, stacked)
            if meta["kind"] in ("attn", "local") and "attn" in unit:
                h = heads[lids] if stacked else heads[lids[0]]
                hmax = cfg.n_heads if pad_to_full else int(np.max(h))
                if pad_to_full or hmax < cfg.n_heads \
                        or (np.asarray(h) != hmax).any():
                    out = dict(out)
                    out["attn"] = _slice_attn(unit["attn"], h, hmax, g,
                                              stacked)
            return out

        decoder = dict(self.full_params["decoder"])
        if "stack" in decoder and n_units:
            stack = dict(decoder["stack"])
            for j in range(cycle):
                lids = [u * cycle + j for u in range(n_units)]
                stack[f"u{j}"] = cut_unit(stack[f"u{j}"], lids,
                                          stacked=True)
            decoder["stack"] = stack
        if "extra" in decoder:
            extra = dict(decoder["extra"])
            for j in range(len(self.refs) - n_units * cycle):
                lid = n_units * cycle + j
                extra[f"x{j}"] = cut_unit(extra[f"x{j}"], [lid],
                                          stacked=False)
            decoder["extra"] = extra
        params = dict(self.full_params)
        params["decoder"] = decoder
        return params

    # ---- the boundary swap ---------------------------------------------
    def apply(self, plan, *, masked: bool = False) -> tuple:
        """Materialize ``plan`` (a WidthPlan with a module mapping) and
        return ``(params, SwapEvent)``.  The full-width plan returns the
        canonical tree itself — swap-back is bit-for-bit the original.

        ``masked=True`` realizes the plan as zero-masked *full-shape*
        params (``materialize(..., pad_to_full=True)``): the dropped
        channels are zeroed but every array keeps its canonical shape,
        so the result runs on the already-compiled full-width executable
        — the compile cache's cost-crossover realization.  Masked and
        sliced materializations of the same widths are cached under
        distinct keys.

        The plan cache is only written *after* materialization completes
        (the "commit" checkpoint), so a failure at any step leaves no
        partially built tree behind — the invariant ``apply_guarded``'s
        rollback relies on."""
        t0 = time.perf_counter()
        if not getattr(plan, "modules", None):
            raise ValueError(
                "plan has no module mapping; build templates with "
                "width_swap.serving_templates and pass modules= to "
                "ServingWidthPlanner")
        self._step("begin")
        self._step("realize")
        mlp_w, heads = self.realize(plan.widths, plan.modules)
        key = (tuple(mlp_w.tolist()), tuple(heads.tolist()))
        full = (mlp_w == self.cfg.d_ff).all() \
            and (heads == self.cfg.n_heads).all()
        if full:
            masked = False          # nothing to mask at full width
        cache_key = key + (("masked",) if masked else ())
        hit = cache_key in self._cache
        if hit:
            params = self._cache[cache_key]
            self._cache.move_to_end(cache_key)
        else:
            self._step("materialize")
            if full:
                params = self.full_params
            else:
                params = self.materialize(mlp_w, heads,
                                          pad_to_full=masked)
            self._step("commit")
            self._cache[cache_key] = params
            while len(self._cache) > self.max_plans:
                self._cache.popitem(last=False)
        self._step("finish")
        name = plan.traffic.name if getattr(plan, "traffic", None) else ""
        event = SwapEvent(plan_name=name, key=key,
                          realized=self.realized_widths(mlp_w, heads,
                                                        plan.modules),
                          swap_s=time.perf_counter() - t0, cache_hit=hit,
                          masked=masked)
        return params, event

    def apply_guarded(self, plan, *, masked: bool = False) -> tuple:
        """Transactional :meth:`apply`: any mid-swap exception rolls back
        to the retained canonical tree instead of propagating.

        Returns ``(params, SwapEvent)`` exactly like ``apply``; on a
        failure the params are ``full_params`` (the canonical full-width
        tree, untouched by construction — every materialization builds a
        NEW tree from it) and the event records ``outcome="rolled_back"``
        plus the exception.  A plan without a module mapping still
        raises — that is a caller contract violation, not a runtime
        fault to degrade through."""
        t0 = time.perf_counter()
        if not getattr(plan, "modules", None):
            raise ValueError(
                "plan has no module mapping; build templates with "
                "width_swap.serving_templates and pass modules= to "
                "ServingWidthPlanner")
        try:
            return self.apply(plan, masked=masked)
        except Exception as e:  # noqa: BLE001 — the guard IS the point
            name = plan.traffic.name \
                if getattr(plan, "traffic", None) else ""
            event = SwapEvent(
                plan_name=name, key=(), realized=(),
                swap_s=time.perf_counter() - t0, cache_hit=False,
                outcome="rolled_back",
                error=f"{type(e).__name__}: {e}")
            return self.full_params, event

    # ---- plan realization helper ---------------------------------------
    def realize_plan(self, plan):
        """Per-decoder-layer realized ``(mlp_w, heads)`` arrays for a
        WidthPlan — the head vector :meth:`reshape_states` needs on each
        side of a boundary.  The full-width plan (``widths={}``) realizes
        to the canonical widths even without a module mapping."""
        if not getattr(plan, "widths", None):
            n = len(self.refs)
            return (np.full(n, self.cfg.d_ff, dtype=np.int64),
                    np.full(n, self.cfg.n_heads, dtype=np.int64))
        if not getattr(plan, "modules", None):
            raise ValueError(
                "plan has no module mapping; build templates with "
                "width_swap.serving_templates and pass modules= to "
                "ServingWidthPlanner")
        return self.realize(plan.widths, plan.modules)

    # ---- KV state re-shaping -------------------------------------------
    def reshape_states(self, states: Optional[dict], heads_from,
                       heads_to) -> Optional[dict]:
        """Re-shape decode KV caches from one plan's head counts to
        another's at a batch boundary.  Shrinking slices the cached
        K/V head prefix (exact: GQA keeps a prefix of KV heads); growing
        zero-pads the new head slots, which have no cached history —
        engines that prefill per batch never hit the growing case, and
        the continuous engine re-prefills grown requests from their own
        token history instead of decoding on zero-history heads."""
        if self.reshape_fault_hook is not None:
            self.reshape_fault_hook()
        if states is None:
            return None
        cfg = self.cfg
        g = self._group_g
        cycle = tfm.unit_cycle(cfg)
        n_units = len(self.refs) // cycle
        hf = np.asarray(heads_from, dtype=np.int64)
        ht = np.asarray(heads_to, dtype=np.int64)

        def cut_state(st: dict, lids: list) -> dict:
            meta = self.refs[lids[0]]
            if meta["kind"] not in ("attn", "local") or "k" not in st:
                return st
            kv_from = max(int(np.max(hf[lids])) // g, 1)
            kv_to = max(int(np.max(ht[lids])) // g, 1)
            if kv_from == kv_to:
                return st
            out = dict(st)
            for k in ("k", "v"):
                # (B, S, KV, dh) or stacked (U, B, S, KV, dh): KV = -2
                out[k] = _resize_axis(st[k], st[k].ndim - 2, kv_to)
            return out

        out = dict(states)
        if "stack" in states and n_units:
            stack = dict(states["stack"])
            for j in range(cycle):
                lids = [u * cycle + j for u in range(n_units)]
                stack[f"u{j}"] = cut_state(stack[f"u{j}"], lids)
            out["stack"] = stack
        if "extra" in states:
            extra = dict(states["extra"])
            for j in range(len(self.refs) - n_units * cycle):
                lid = n_units * cycle + j
                extra[f"x{j}"] = cut_state(extra[f"x{j}"], [lid])
            out["extra"] = extra
        return out
