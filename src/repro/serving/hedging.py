"""Width-variant request hedging: tail latency bought with narrow width.

Classic hedged requests (Dean & Barroso, "The Tail at Scale") send a
duplicate of a slow request to a second server once the original has
outlived a high quantile of the latency distribution, and take whichever
copy finishes first.  The paper's width planner gives the idea a twist a
plain replica cannot: the backup does not have to run the *same* model.
Every :class:`~repro.serving.degradation.DegradationLadder` rung is a
width plan with a *predicted* latency reduction, so the backup can run
on a narrower, faster rung — pinned via
``DegradationController.pin_floor`` for exactly the backup's lifetime —
making the hedge cheaper than the primary and more likely to beat it.

This module is pure policy — *when* to hedge and *at what rung*.  The
mechanics (which replica, slot-exact cancellation of the losing leg,
one-ledger-entry accounting of the pair) live in
:class:`~repro.serving.router.ReplicaRouter`:

  * the hedge delay comes from live planner telemetry
    (``ServingWidthPlanner.observed_percentile``: the observed latency
    quantile of the request's traffic class) with a fixed fallback
    before any data exists;
  * ``should_hedge`` gates on elapsed time, an outstanding-hedge cap
    (hedging must never amplify an overload — the cap bounds the extra
    load to a constant), and optionally on requests that carry
    deadlines at all.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.engine import Request, ServingWidthPlanner


@dataclasses.dataclass(frozen=True)
class HedgeEvent:
    """One hedge launch, in ``ReplicaRouter.hedge_log``."""

    lid: int                  # logical request id (router-level)
    launched_t: float         # router clock at backup launch
    delay_s: float            # hedge delay that was exceeded
    rung: int                 # degradation floor pinned for the backup
    replica: str              # replica the backup landed on
    winner: str = ""          # "primary" | "backup" (filled at resolve)


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """When to launch a backup, and how degraded it runs.

    ``quantile`` — the per-class observed-latency percentile used as the
    hedge delay (95 ⇒ at most ~5% of requests hedge, the classic
    tail-only budget).  ``default_delay_s`` serves until the planner has
    per-class data; ``min_delay_s`` floors the delay so a cold, fast
    class cannot hedge everything.  ``rung`` is the ladder floor pinned
    on the backup replica's controller (0 = same width: a plain Dean
    -style hedge).  ``max_outstanding`` caps concurrent hedge pairs.
    ``hedge_deadline_only`` restricts hedging to requests that carry a
    deadline — the ones for which a tail latency is actually a miss.
    """

    quantile: float = 95.0
    default_delay_s: float = 0.5
    min_delay_s: float = 0.0
    rung: int = 1
    max_outstanding: int = 4
    hedge_deadline_only: bool = False

    def __post_init__(self):
        if not 0.0 < self.quantile <= 100.0:
            raise ValueError(f"quantile must be in (0, 100], "
                             f"got {self.quantile}")
        if self.rung < 0:
            raise ValueError("rung must be >= 0")
        if self.max_outstanding < 1:
            raise ValueError("max_outstanding must be >= 1")

    def hedge_delay(self, planner: Optional[ServingWidthPlanner],
                    klass: str) -> float:
        """Delay before a request becomes hedge-eligible: the observed
        ``quantile`` of its class's finished-request latencies, else the
        configured default while no telemetry exists."""
        delay = None
        if planner is not None:
            delay = planner.observed_percentile(klass or "default",
                                                self.quantile)
        if delay is None:
            delay = self.default_delay_s
        return max(float(delay), self.min_delay_s)

    def should_hedge(self, *, elapsed_s: float, delay_s: float,
                     outstanding: int, request: Request) -> bool:
        """Gate one candidate: old enough, under the concurrency cap,
        and (optionally) deadline-carrying."""
        if outstanding >= self.max_outstanding:
            return False
        if self.hedge_deadline_only and request.deadline_s is None:
            return False
        return elapsed_s >= delay_s
