from repro.serving.engine import (
    Request, Result, ServeEngine, ServingWidthPlanner, TrafficClass,
    WidthPlan,
)

__all__ = ["Request", "Result", "ServeEngine", "ServingWidthPlanner",
           "TrafficClass", "WidthPlan"]
