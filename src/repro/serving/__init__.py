from repro.serving.engine import Request, Result, ServeEngine

__all__ = ["Request", "Result", "ServeEngine"]
