from repro.serving.engine import (
    AdmissionControl, BatchStats, Request, Result, ServeEngine,
    ServingWidthPlanner, TrafficClass, WidthPlan,
)
from repro.serving.width_swap import (
    SWAP_STEPS, SwapEvent, WidthSwapper, serving_templates,
)
from repro.serving.degradation import (
    DegradationController, DegradationLadder, LadderRung, Shift,
)
from repro.serving.continuous import (
    Arrival, BoundaryEvent, ChunkEvent, ContinuousServeEngine, Ledger,
)
from repro.serving.compile_cache import (
    COMPILE_STEPS, CompileEvent, TraceCounter, WidthVariantCompileCache,
    pow2_bucket, realized_exec_key,
)
from repro.serving.hedging import HedgeEvent, HedgePolicy
from repro.serving.router import (
    HealthEvent, Replica, ReplicaRouter, RouterLedger,
)
from repro.serving import chaos

__all__ = ["AdmissionControl", "BatchStats", "Request", "Result",
           "ServeEngine", "ServingWidthPlanner", "TrafficClass",
           "WidthPlan", "SWAP_STEPS", "SwapEvent", "WidthSwapper",
           "serving_templates", "DegradationController",
           "DegradationLadder", "LadderRung", "Shift", "Arrival",
           "BoundaryEvent", "ChunkEvent", "ContinuousServeEngine",
           "Ledger", "COMPILE_STEPS", "CompileEvent", "TraceCounter",
           "WidthVariantCompileCache", "pow2_bucket",
           "realized_exec_key", "HedgeEvent", "HedgePolicy",
           "HealthEvent", "Replica", "ReplicaRouter", "RouterLedger",
           "chaos"]
