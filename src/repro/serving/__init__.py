from repro.serving.engine import (
    Request, Result, ServeEngine, ServingWidthPlanner, TrafficClass,
    WidthPlan,
)
from repro.serving.width_swap import (
    SwapEvent, WidthSwapper, serving_templates,
)

__all__ = ["Request", "Result", "ServeEngine", "ServingWidthPlanner",
           "TrafficClass", "WidthPlan", "SwapEvent", "WidthSwapper",
           "serving_templates"]
