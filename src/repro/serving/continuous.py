"""Continuous-batching serve engine with in-flight fault recovery.

``ServeEngine`` (engine.py) is a *static*-batch engine: it pads requests
into lockstep batches, prefills each batch from scratch, and the whole
batch finishes together — so a short request queued behind a long one
pays the long one's decode tail (head-of-line blocking), and the tested
``WidthSwapper.reshape_states`` never runs against live state because
every boundary starts from a fresh prefill.  This module is the step
from that batch demo toward a loaded server:

  * **Slot-based continuous batching** — the engine owns ``batch_slots``
    decode slots over one shared KV cache; requests *join in flight*
    (a one-request prefill written into a free slot at its own
    position — the ragged-decode path in ``models.transformer`` scatters
    cache writes per slot) and *leave in flight* the moment they finish,
    freeing the slot for the next queued request.  No request ever waits
    for an unrelated request's tail.
  * **Admission + watchdogs** — joins go through the existing
    :class:`~repro.serving.engine.AdmissionControl` (deadline projection
    against an EWMA of per-request service times); once decoding, a
    per-request watchdog sheds any request that exceeds its deadline
    *during* decode (partial tokens returned, ``deadline_missed=True``)
    instead of letting a doomed request occupy a slot.
  * **Recoverable boundary transactions** — at a width-plan boundary the
    engine swaps params through ``WidthSwapper.apply_guarded`` and
    carries every live KV cache across via ``reshape_states`` (exact
    when the plan shrinks heads).  The crossing is a transaction: if the
    swap rolls back or the KV reshape faults
    (``serving.chaos.ReshapeFailureInjector``), the engine restores the
    canonical tree + fresh state and *requeues* every in-flight request
    with its already-generated tokens intact — bounded retries
    (``max_retries``), never a silent drop.  ``Result.retries`` counts
    requeues and ``Result.recovered`` marks requests that survived one.
    A boundary that would *grow* KV heads requeues live requests the
    same way (their history re-prefills at the new width) rather than
    decoding against zero-history head slots.
  * **Graceful drain** — :meth:`ContinuousServeEngine.drain` stops
    admitting, sheds the waiting queue, finishes (or sheds, on budget
    exhaustion) the in-flight slots, and returns a :class:`Ledger` in
    which every submitted request is accounted for as
    finished / shed / failed — the sums are exact by construction.
  * **Open-loop load** — :class:`Arrival` timestamps requests on the
    engine clock; ``serving.chaos.open_loop_arrivals`` generates
    Poisson/burst traffic per class on a ``VirtualClock`` so tail
    percentiles (p50/p99/p99.9 via ``chaos.TailReport``) are exactly
    reproducible from a seed.

Determinism contract: with a ``VirtualClock`` + ``batch_cost_fn`` every
join, shed, boundary crossing, and requeue is a pure function of the
seeds — the chaos tier asserts exact ledgers, not statistics.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.serving.engine import Request, Result, WidthPlan


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop arrival: a request hitting the server at time ``t``
    (engine-clock seconds), tagged with its traffic class for per-class
    tail reporting."""

    t: float
    request: Request
    klass: str = ""


@dataclasses.dataclass(frozen=True)
class BoundaryEvent:
    """One width-plan boundary crossing attempt, in ``boundary_log``."""

    step: int                 # engine step index at the crossing
    plan_name: str            # traffic class of the target plan
    outcome: str              # "ok" | "swap_rolled_back" |
    #                           "reshape_failed" | "requeued_grow"
    requeued: int             # in-flight requests sent back to the queue
    error: str = ""           # repr of the mid-boundary exception, if any


@dataclasses.dataclass(frozen=True)
class ChunkEvent:
    """One chunked-prefill fault, in ``chunk_log``: the request requeued
    holding ``committed`` prefilled tokens — its recovery checkpoint."""

    step: int                 # engine step index at the fault
    rid: int                  # faulted request
    committed: int            # prefill tokens surviving as checkpoint
    error: str = ""           # repr of the chunk exception


@dataclasses.dataclass(frozen=True)
class Ledger:
    """Complete accounting of a serve run: every submitted request ends
    in exactly one terminal state.  ``evicted`` counts requests handed
    off to another replica by ``evict_in_flight`` — terminal *on this
    engine* (the router re-submits them elsewhere), so they count toward
    ``accounted`` here and exactly one engine ultimately finishes,
    sheds, or fails each logical request."""

    submitted: int
    finished: int
    shed: int
    failed: int
    in_flight: int            # non-terminal (0 after drain())
    queued: int               # non-terminal (0 after drain())
    evicted: int = 0          # migrated off this engine (router failover)

    @property
    def accounted(self) -> int:
        return self.finished + self.shed + self.failed + self.evicted

    @property
    def complete(self) -> bool:
        """True when every submitted request reached a terminal state."""
        return self.accounted == self.submitted \
            and self.in_flight == 0 and self.queued == 0


@dataclasses.dataclass
class _Tracked:
    """Engine-internal per-request bookkeeping.

    The three ``chunk_*`` fields are the chunked-prefill checkpoint: a
    slot-local decode pytree holding every committed chunk's KV rows,
    plus the shape/effective head vectors it was built under.  The
    checkpoint travels with the request through requeues and replica
    migrations; it is resumable exactly when both head vectors still
    match the engine's active ones (otherwise the prefill restarts —
    never silently decodes against stale-width rows)."""

    rid: int
    request: Request
    klass: str
    arrival_t: float
    generated: List[int] = dataclasses.field(default_factory=list)
    retries: int = 0
    join_t: float = 0.0
    prefill_done: int = 0                       # committed prefill tokens
    chunk_state: Optional[dict] = None          # batch-1 decode pytree
    chunk_heads: Optional[np.ndarray] = None    # KV *shape* heads of it
    chunk_eff: Optional[np.ndarray] = None      # effective heads of it


class ContinuousServeEngine:
    """Requests join and leave the running decode batch in flight.

    The engine owns one decode-state pytree shaped ``(batch_slots,
    max_len, ...)`` (``models.transformer.init_decode_state`` layout) and
    a per-slot position vector; decode steps run all occupied slots in
    one ragged ``decode_step`` call (vector ``pos``).  Joining writes a
    single-request prefill into a free slot's rows; leaving just frees
    the slot.  Width-plan boundaries re-shape the *live* cache through
    ``WidthSwapper.reshape_states`` — see the module docstring for the
    transaction/recovery semantics.

    Decoder-only models only (``cfg.is_encdec`` is rejected): cross
    -attention caches have no slot-local rewrite path.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 512,
                 batch_slots: int = 4, rng_seed: int = 0,
                 planner=None, swapper=None, admission=None, degrader=None,
                 clock: Callable[[], float] = time.monotonic,
                 batch_cost_fn=None, max_retries: int = 2,
                 boundary_every: int = 4, boundary_cooldown: int = 8,
                 compile_cache=None,
                 prefill_bucketing: Optional[bool] = None,
                 prefill_bucket_min: int = 8,
                 prefill_chunk: Optional[int] = None,
                 step_token_budget: Optional[int] = None,
                 chunk_fault_hook: Optional[Callable[[], None]] = None):
        if cfg.is_encdec:
            raise ValueError("continuous batching supports decoder-only "
                             "models (no cross-attention cache rewrite)")
        if degrader is not None and admission is None:
            raise ValueError(
                "a degradation controller needs an AdmissionControl as "
                "its overload-signal source; pass admission= too")
        self.cfg = cfg
        self.max_len = int(max_len)
        self.slots = int(batch_slots)
        self.rng = jax.random.PRNGKey(rng_seed)
        self.planner = planner
        self.swapper = swapper
        self.admission = admission
        self.degrader = degrader
        self.clock = clock
        self.batch_cost_fn = batch_cost_fn
        self.max_retries = max(int(max_retries), 0)
        # Plan boundaries are only *considered* every `boundary_every`
        # engine steps (a continuous engine has no natural batch edge),
        # and after a failed crossing the engine serves `boundary_cooldown`
        # steps on the canonical tree before retrying — so a crash-looping
        # swap cannot starve the requeued requests out of their retries.
        self.boundary_every = max(int(boundary_every), 1)
        self.boundary_cooldown = max(int(boundary_cooldown), 0)

        # Active serving state: params + the realized widths they carry.
        self.params_active = params
        self._canonical = params if swapper is None else swapper.full_params
        n_refs = len(tfm.decoder_layer_refs(cfg))
        self._full_heads = np.full(n_refs, cfg.n_heads, dtype=np.int64)
        self._heads_active = self._full_heads.copy()
        # Head counts defining the KV-cache SHAPES, which differ from
        # `_heads_active` (the effective head values) exactly when the
        # active plan is realized as a zero-mask: masked params keep
        # canonical shapes, so reshape_states must source from the shape
        # vector while grow-detection compares effective values.
        self._shape_heads = self._full_heads.copy()
        self._masked_active = False
        self._plan_active: Optional[WidthPlan] = None
        self._key_active: Optional[tuple] = None

        # Prefill length bucketing: pow2-pad join prefills so the number
        # of distinct prefill shapes (jit traces / AOT executables) is
        # bounded by log2(max_len), not one per distinct prompt length.
        # Exact only for pure global-causal-attention dense stacks:
        # local-attention ring caches rotate by the *total* prefill
        # length and recurrent/MoE-capacity layers see the padded rows,
        # so bucketing is refused there.  Default: on when a compile
        # cache is attached (the cache is why bucket count matters).
        bucket_ok = not cfg.moe and all(
            kind == "attn" for kind, _ in tfm.layer_plan(cfg))
        if prefill_bucketing is None:
            self.prefill_bucketing = compile_cache is not None and bucket_ok
        elif prefill_bucketing and not bucket_ok:
            raise ValueError(
                "prefill_bucketing requires a pure global-attention "
                "dense decoder (local/recurrent layers and MoE capacity "
                "are length-sensitive)")
        else:
            self.prefill_bucketing = bool(prefill_bucketing)
        self.prefill_bucket_min = max(int(prefill_bucket_min), 1)

        # Chunked prefill: joins seat a request in a "prefilling" slot
        # and its prompt runs `prefill_chunk` tokens at a time from each
        # step's token budget, interleaved with the decode steps of the
        # other slots — a long prompt can no longer stall every decode
        # slot for its whole length, and each committed chunk is a
        # recovery checkpoint.  Same eligibility as bucketing: chunks
        # replay against a KV cache, which only global causal attention
        # supports.
        if prefill_chunk is not None:
            if not bucket_ok:
                raise ValueError(
                    "chunked prefill requires a pure global-attention "
                    "dense decoder (local/recurrent layers and MoE "
                    "capacity cannot replay a chunk against a cache)")
            if int(prefill_chunk) < 1:
                raise ValueError("prefill_chunk must be >= 1")
        self.prefill_chunk = None if prefill_chunk is None \
            else int(prefill_chunk)
        self.step_token_budget = None if step_token_budget is None \
            else max(int(step_token_budget), 1)
        self.chunk_fault_hook = chunk_fault_hook

        # Slot state: one shared decode pytree + per-slot positions.
        self.states = tfm.init_decode_state(cfg, self.slots, self.max_len)
        self.pos = np.zeros(self.slots, dtype=np.int64)
        self._slots: List[Optional[_Tracked]] = [None] * self.slots
        self._last_tok = np.zeros(self.slots, dtype=np.int32)

        # Queues: pending (future arrivals, by time), waiting (delivered,
        # not yet admitted), retry (admitted work evicted by a boundary
        # failure — rejoins ahead of the queue, without re-admission).
        self._pending: deque = deque()
        self._queue: deque = deque()
        self._retry: deque = deque()
        self.draining = False

        # Accounting.
        self._next_rid = 0
        self._results: dict[int, Result] = {}
        self._submitted = 0
        self._finished = 0
        self._shed = 0
        self._failed = 0
        self._evicted = 0
        self.steps = 0
        self._decode_steps = 0
        self._last_boundary_fail = -(10 ** 9)
        self.plan_log: List[WidthPlan] = []
        self.swap_log: List = []
        self.boundary_log: List[BoundaryEvent] = []
        self.chunk_log: List[ChunkEvent] = []
        self.join_count = 0
        self.chunk_steps = 0        # successful prefill chunks executed

        # AOT width-variant executables (serving/compile_cache.py): the
        # cache's prefill/decode entry points are lookup-or-traced
        # -fallback, so a cold cache behaves exactly like the historical
        # jit lambdas; warm_compile() makes boundary crossings traceless.
        self.compile_cache = compile_cache
        if compile_cache is not None:
            if compile_cache.cfg is not cfg and compile_cache.cfg != cfg:
                raise ValueError("compile_cache was built for a different "
                                 "ModelConfig than this engine")
            self._decode = compile_cache.decode
            self._prefill = compile_cache.prefill
            self._chunk = compile_cache.chunk
        else:
            self._decode = jax.jit(
                lambda p, t, pos, st: tfm.decode_step(p, cfg, t, pos, st))
            self._prefill = jax.jit(
                lambda p, toks: tfm.forward(p, cfg, tokens=toks,
                                            mode="prefill"))
            self._chunk = jax.jit(
                lambda p, toks, pos, st: tfm.prefill_chunk(p, cfg, toks,
                                                           pos, st))

    def _prefill_len(self, plen: int) -> int:
        """Padded prefill length for a ``plen``-token join."""
        from repro.serving.compile_cache import pow2_bucket
        if not self.prefill_bucketing:
            return plen
        return min(pow2_bucket(plen, self.prefill_bucket_min),
                   max(self.max_len, plen))

    def warm_compile(self, plans: Sequence[WidthPlan],
                     prefill_lengths: Sequence[int] = ()) -> int:
        """Plan-time AOT compilation: compile the ragged decode
        executable (and bucketed single-request prefill executables for
        ``prefill_lengths``) for every plan — plus the full-width
        baseline — so boundary crossings and joins are table lookups.
        Masked-crossover plans warm the full-width key.  Returns the
        number of executables warmed; compile faults are absorbed (the
        serve path falls back to the traced jit)."""
        if self.compile_cache is None:
            return 0
        from repro.serving.compile_cache import (
            decode_state_struct, realized_exec_key)
        cache = self.compile_cache
        prev_key = cache.active_key
        if self.prefill_chunk is None:
            buckets = sorted({self._prefill_len(int(l))
                              for l in prefill_lengths})
            chunk_buckets: list = []
        else:
            # Chunked joins never call the whole-prompt prefill: the
            # shape set is the chunk itself plus the pow2 buckets of
            # each prompt's final partial chunk (capped at the chunk).
            c = self.prefill_chunk
            shapes = {c}
            for plen in prefill_lengths:
                tail = int(plen) % c or c
                shapes.add(min(self._prefill_len(tail), c))
            chunk_buckets = sorted(shapes)
            buckets = []
        n = 0
        todo = ([None] if self.swapper is None else list(plans) + [None])
        for plan in todo:
            if plan is None:
                key = cache.full_key
                params = self._canonical
                heads = None
            else:
                masked = bool(plan.widths) \
                    and cache.decide(plan) == "masked"
                params, event = self.swapper.apply_guarded(
                    plan, masked=masked)
                if event.outcome != "ok":
                    continue
                mlp_w, heads_to = self.swapper.realize_plan(plan)
                if masked:
                    key, heads = cache.full_key, None
                else:
                    key = realized_exec_key(mlp_w, heads_to)
                    heads = heads_to
            cache.set_active(key)
            st = decode_state_struct(self.cfg, self.slots, self.max_len,
                                     swapper=self.swapper, heads=heads)
            cur = jnp.zeros((self.slots,), jnp.int32)
            posv = jnp.zeros((self.slots,), jnp.int32)
            n += cache.precompile("decode", key, (self.slots,),
                                  (params, cur, posv, st))
            for plen in buckets:
                toks = jnp.zeros((1, plen), jnp.int32)
                n += cache.precompile("prefill", key, (1, plen),
                                      (params, toks))
            if plan is not None:
                cache.mark_plan_warm(plan)
        cache.set_active(prev_key)
        return n

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, request: Request, *, arrival_t: Optional[float] = None,
               klass: str = "") -> int:
        """Register one request; returns its id.  Arrivals in the future
        (``arrival_t`` > now) are delivered when the clock reaches them.
        A draining engine sheds immediately — it no longer admits."""
        rid = self._next_rid
        self._next_rid += 1
        self._submitted += 1
        t = self.clock() if arrival_t is None else float(arrival_t)
        tr = _Tracked(rid=rid, request=request, klass=klass, arrival_t=t)
        if self.draining:
            self._terminal(tr, shed=True)
            return rid
        self._pending.append(tr)
        return rid

    def result(self, rid: int) -> Optional[Result]:
        return self._results.get(rid)

    def cancel(self, rid: int) -> bool:
        """Cancel one in-flight or queued request *slot-exactly*: only
        the named request's slot is freed (every other slot keeps
        decoding undisturbed) and it resolves as shed with
        ``cancelled=True``.  The hedging layer calls this on the losing
        leg of a resolved hedge pair.  Returns False for unknown or
        already-terminal ids."""
        for i, tr in enumerate(self._slots):
            if tr is not None and tr.rid == rid:
                self._slots[i] = None
                self.pos[i] = 0
                self._last_tok[i] = 0
                self._terminal(tr, shed=True, cancelled=True)
                return True
        for q in (self._retry, self._queue, self._pending):
            for tr in q:
                if tr.rid == rid:
                    q.remove(tr)
                    self._terminal(tr, shed=True, cancelled=True)
                    return True
        return False

    # ------------------------------------------------------------------
    # replica failover surface (used by serving.router)
    # ------------------------------------------------------------------
    def evict_in_flight(self) -> List[_Tracked]:
        """Strip every non-terminal request off this engine — slots,
        retry, waiting and pending queues — and return the trackers with
        generated tokens and chunk checkpoints intact.  No Results are
        written here: the requests are terminal *on this engine* only
        (``Ledger.evicted``); the router re-submits them elsewhere via
        :meth:`adopt`."""
        out: List[_Tracked] = []
        for i, tr in enumerate(self._slots):
            if tr is not None:
                self._slots[i] = None
                self.pos[i] = 0
                self._last_tok[i] = 0
                out.append(tr)
        out.extend(self._retry)
        self._retry.clear()
        out.extend(self._queue)
        self._queue.clear()
        out.extend(self._pending)
        self._pending.clear()
        self._evicted += len(out)
        return out

    def adopt(self, tr: _Tracked, *,
              arrival_t: Optional[float] = None) -> int:
        """Accept a request evicted from another replica: a fresh local
        rid, original arrival time (so deadlines and latency keep
        counting from the true arrival), generated tokens and chunk
        checkpoint carried over.  Checkpoint head vectors revalidate at
        join time against *this* engine's widths."""
        rid = self._next_rid
        self._next_rid += 1
        self._submitted += 1
        t = tr.arrival_t if arrival_t is None else float(arrival_t)
        adopted = _Tracked(
            rid=rid, request=tr.request, klass=tr.klass, arrival_t=t,
            generated=list(tr.generated), retries=tr.retries,
            prefill_done=tr.prefill_done, chunk_state=tr.chunk_state,
            chunk_heads=tr.chunk_heads, chunk_eff=tr.chunk_eff)
        if self.draining:
            self._terminal(adopted, shed=True)
            return rid
        self._pending.append(adopted)
        return rid

    def ledger(self) -> Ledger:
        return Ledger(
            submitted=self._submitted, finished=self._finished,
            shed=self._shed, failed=self._failed,
            in_flight=sum(tr is not None for tr in self._slots)
            + len(self._retry),
            queued=len(self._queue) + len(self._pending),
            evicted=self._evicted)

    # ------------------------------------------------------------------
    # terminal states
    # ------------------------------------------------------------------
    def _terminal(self, tr: _Tracked, *, shed: bool = False,
                  failed: bool = False, cancelled: bool = False) -> Result:
        now = self.clock()
        lat = now - tr.arrival_t
        d = tr.request.deadline_s
        res = Result(
            tokens=np.asarray(tr.generated, dtype=np.int32),
            steps=len(tr.generated), shed=shed,
            deadline_missed=(d is not None and lat > d
                             and (shed or not failed) and not cancelled
                             and bool(tr.generated or not shed)),
            latency_s=lat, retries=tr.retries, failed=failed,
            recovered=(tr.retries > 0 and not shed and not failed),
            cancelled=cancelled)
        self._results[tr.rid] = res
        if failed:
            self._failed += 1
        elif shed:
            self._shed += 1
        else:
            self._finished += 1
        return res

    def _finish(self, tr: _Tracked) -> None:
        res = self._terminal(tr)
        if self.admission is not None:
            self.admission.observe(self.clock() - tr.join_t)
        if self.planner is not None:
            name = (self._plan_active.traffic.name
                    if self._plan_active is not None else tr.klass)
            self.planner.record(name or "default", res.latency_s)

    # ------------------------------------------------------------------
    # queue movement
    # ------------------------------------------------------------------
    def _deliver(self) -> None:
        """Move pending arrivals whose time has come into the queue."""
        now = self.clock()
        ready = [tr for tr in self._pending if tr.arrival_t <= now]
        if ready:
            self._pending = deque(
                tr for tr in self._pending if tr.arrival_t > now)
            ready.sort(key=lambda tr: (tr.arrival_t, tr.rid))
            self._queue.extend(ready)

    def _free_slot(self) -> Optional[int]:
        for i, tr in enumerate(self._slots):
            if tr is None:
                return i
        return None

    def _join_waiting(self) -> int:
        """Fill free slots from the retry queue (pre-admitted) then the
        waiting queue (through admission).  Returns prefill token count
        for this step's cost accounting."""
        tokens = 0
        while True:
            i = self._free_slot()
            if i is None:
                break
            if self._retry:
                tr = self._retry.popleft()
            elif self._queue:
                tr = self._queue.popleft()
                if self.admission is not None and not self.admission.admit(
                        tr.request, now=self.clock(),
                        arrival=tr.arrival_t,
                        backlog_batches=len(self._queue) // self.slots):
                    self._terminal(tr, shed=True)
                    continue
            else:
                break
            tokens += self._join(i, tr)
        return tokens

    def _join(self, i: int, tr: _Tracked) -> int:
        """Prefill ``tr``'s prompt (plus any tokens generated before a
        requeue) into slot ``i``.  Returns the prefill token count."""
        prompt = np.concatenate(
            [np.asarray(tr.request.prompt, dtype=np.int32),
             np.asarray(tr.generated, dtype=np.int32)])
        remaining = tr.request.max_new_tokens - len(tr.generated)
        if remaining <= 0:          # requeued after its last token
            tr.join_t = self.clock()
            self._finish(tr)
            return 0
        if len(prompt) + remaining > self.max_len:
            self._terminal(tr, failed=True)
            return 0
        tr.join_t = self.clock()
        if self.prefill_chunk is not None:
            return self._join_chunked(i, tr)
        plen = len(prompt)
        padded = self._prefill_len(plen)
        if padded > plen:
            # pow2 bucket: right-pad so the prefill shape is one of
            # log2(max_len) buckets.  Exact for global causal attention
            # (rows < plen never attend the pad rows; _write_slot only
            # commits the first plen KV rows; logits read at plen-1).
            prompt_in = np.zeros(padded, np.int32)
            prompt_in[:plen] = prompt
        else:
            prompt_in = prompt
        logits, states, _ = self._prefill(self.params_active,
                                          prompt_in[None])
        self._write_slot(i, states, plen)
        last = logits[0, plen - 1, :self.cfg.vocab_size]
        first = int(jnp.argmax(last))
        tr.generated.append(first)
        self._slots[i] = tr
        self.pos[i] = len(prompt)
        self._last_tok[i] = first
        self.join_count += 1
        if self._done(tr):
            self._release(i)
        return len(prompt)

    def _join_chunked(self, i: int, tr: _Tracked) -> int:
        """Seat ``tr`` in slot ``i`` as a *prefilling* request: no model
        call happens at join time — :meth:`_advance_prefills` runs its
        prompt ``prefill_chunk`` tokens per step from the step token
        budget.  A checkpoint built under the engine's current head
        vectors resumes from its committed tokens; anything else (stale
        widths, or a requeue that shrank the target, which cannot happen
        but is guarded anyway) restarts from token zero."""
        plen = len(tr.request.prompt) + len(tr.generated)
        resumable = (
            tr.chunk_state is not None
            and tr.chunk_heads is not None and tr.chunk_eff is not None
            and tr.chunk_heads.shape == self._shape_heads.shape
            and (tr.chunk_heads == self._shape_heads).all()
            and (tr.chunk_eff == self._heads_active).all()
            and 0 < tr.prefill_done <= plen)
        if not resumable:
            tr.chunk_state = self._fresh_states(self._shape_heads, batch=1)
            tr.chunk_heads = self._shape_heads.copy()
            tr.chunk_eff = self._heads_active.copy()
            tr.prefill_done = 0
        self._slots[i] = tr
        self.pos[i] = 0
        self._last_tok[i] = 0
        self.join_count += 1
        return 0

    def _advance_prefills(self, budget: Optional[int]) -> int:
        """Run at most one prefill chunk per prefilling slot (round-robin,
        repeated until the budget is spent or no slot can advance).
        Returns padded chunk tokens executed, for step cost accounting.
        The first chunk of a pass always runs even over budget — a chunk
        larger than the budget must still make progress."""
        spent = 0
        progressed = True
        while progressed:
            progressed = False
            for i, tr in enumerate(self._slots):
                if tr is None or tr.chunk_state is None:
                    continue
                target = len(tr.request.prompt) + len(tr.generated)
                clen = min(self.prefill_chunk, target - tr.prefill_done)
                if clen <= 0:       # fully committed last pass
                    continue
                padded = min(self._prefill_len(clen), self.prefill_chunk)
                if budget is not None and spent > 0 \
                        and spent + padded > budget:
                    return spent
                prompt = np.concatenate(
                    [np.asarray(tr.request.prompt, dtype=np.int32),
                     np.asarray(tr.generated, dtype=np.int32)])
                buf = np.zeros(padded, np.int32)
                buf[:clen] = prompt[tr.prefill_done:tr.prefill_done + clen]
                try:
                    if self.chunk_fault_hook is not None:
                        self.chunk_fault_hook()
                    logits, tr.chunk_state = self._chunk(
                        self.params_active, buf[None],
                        jnp.asarray(tr.prefill_done, jnp.int32),
                        tr.chunk_state)
                except Exception as e:  # noqa: BLE001 — checkpoint restart
                    self._chunk_fault(i, tr, e)
                    continue
                tr.prefill_done += clen
                spent += padded
                self.chunk_steps += 1
                progressed = True
                if tr.prefill_done >= target:
                    self._commit_prefill(i, tr, logits, target, clen)
        return spent

    def _commit_prefill(self, i: int, tr: _Tracked, logits, plen: int,
                        clen: int) -> None:
        """Final chunk committed: write the checkpoint pytree into the
        shared slot cache, sample the first token from the last real
        row's logits, and switch the slot to decoding."""
        self._write_slot(i, tr.chunk_state, plen)
        tr.chunk_state = None
        tr.chunk_heads = None
        tr.chunk_eff = None
        tr.prefill_done = 0
        first = int(jnp.argmax(logits[0, clen - 1, :self.cfg.vocab_size]))
        tr.generated.append(first)
        self.pos[i] = plen
        self._last_tok[i] = first
        if self._done(tr):
            self._release(i)

    def _chunk_fault(self, i: int, tr: _Tracked, e: Exception) -> None:
        """A chunk execution faulted: free the slot and requeue the
        request *keeping its checkpoint* — recovery resumes from the last
        committed chunk, not token zero.  Past ``max_retries`` the
        request fails terminally (checkpoint dropped)."""
        self._slots[i] = None
        self.pos[i] = 0
        self._last_tok[i] = 0
        tr.retries += 1
        self.chunk_log.append(ChunkEvent(
            step=self.steps, rid=tr.rid, committed=tr.prefill_done,
            error=f"{type(e).__name__}: {e}"))
        if tr.retries > self.max_retries:
            tr.chunk_state = None
            tr.chunk_heads = None
            tr.chunk_eff = None
            self._terminal(tr, failed=True)
        else:
            self._retry.append(tr)

    def _done(self, tr: _Tracked) -> bool:
        if len(tr.generated) >= tr.request.max_new_tokens:
            return True
        return tr.request.eos_id >= 0 \
            and tr.generated[-1] == tr.request.eos_id

    def _release(self, i: int) -> None:
        tr = self._slots[i]
        self._slots[i] = None
        self.pos[i] = 0
        self._last_tok[i] = 0
        if tr is not None:
            self._finish(tr)

    # ------------------------------------------------------------------
    # slot cache writes
    # ------------------------------------------------------------------
    def _write_slot(self, i: int, prefill_states: dict, plen: int) -> None:
        """Write a one-request prefill's layer states into slot ``i`` of
        the shared decode pytree.  K/V caches land in rows ``0..plen`` of
        the slot's sequence axis; recurrent states replace the slot's
        row wholesale."""

        def write_group(gst: dict, lst: dict, stacked: bool) -> dict:
            out = dict(gst)
            for key, lv in lst.items():
                gv = gst[key]
                if key in ("k", "v"):
                    # (B, S, KV, dh) / stacked (U, B, S, KV, dh).  Only
                    # the first `plen` source rows are committed: a
                    # bucketed prefill carries junk KV in its pad rows
                    # (local-window ring caches may also carry fewer
                    # rows than plen — take what the source has).
                    s = min(plen, lv.shape[2 if stacked else 1])
                    if stacked:
                        upd = gv.at[:, i, :s] if s < gv.shape[2] \
                            else gv.at[:, i]
                        out[key] = upd.set(
                            lv[:, 0, :s].astype(gv.dtype))
                    else:
                        upd = gv.at[i, :s] if s < gv.shape[1] else gv.at[i]
                        out[key] = upd.set(lv[0, :s].astype(gv.dtype))
                else:
                    # per-slot state without a sequence axis (recurrent)
                    out[key] = (gv.at[:, i].set(lv[:, 0].astype(gv.dtype))
                                if stacked
                                else gv.at[i].set(lv[0].astype(gv.dtype)))
            return out

        st = dict(self.states)
        if "stack" in prefill_states:
            stack = dict(st["stack"])
            for key, lst in prefill_states["stack"].items():
                stack[key] = write_group(stack[key], lst, stacked=True)
            st["stack"] = stack
        if "extra" in prefill_states:
            extra = dict(st.get("extra", {}))
            for key, lst in prefill_states["extra"].items():
                extra[key] = write_group(extra[key], lst, stacked=False)
            st["extra"] = extra
        self.states = st

    def _fresh_states(self, heads, batch: Optional[int] = None) -> dict:
        """A fresh (empty) decode pytree shaped for realized ``heads`` —
        canonical shapes re-sliced through the swapper, no fault hook in
        the path (recovery must not be injectable).  ``batch`` overrides
        the slot count (chunk checkpoints are batch-1 pytrees)."""
        b = self.slots if batch is None else int(batch)
        st = tfm.init_decode_state(self.cfg, b, self.max_len)
        if self.swapper is None or (heads == self._full_heads).all():
            return st
        hook, self.swapper.reshape_fault_hook = \
            self.swapper.reshape_fault_hook, None
        try:
            return self.swapper.reshape_states(st, self._full_heads, heads)
        finally:
            self.swapper.reshape_fault_hook = hook

    # ------------------------------------------------------------------
    # boundary transactions
    # ------------------------------------------------------------------
    def _live_tokens(self) -> int:
        live = int(sum(self.pos[i] + (tr.prefill_done
                                      if tr.chunk_state is not None else 0)
                       for i, tr in enumerate(self._slots)
                       if tr is not None))
        return max(live, 1)

    def _requeue_in_flight(self) -> int:
        """Evict every occupied slot back to the retry queue, generated
        tokens intact.  Requests out of retries become terminal failures
        — accounted, never silently dropped."""
        n = 0
        for i, tr in enumerate(self._slots):
            if tr is None:
                continue
            self._slots[i] = None
            self.pos[i] = 0
            self._last_tok[i] = 0
            tr.retries += 1
            if tr.retries > self.max_retries:
                self._terminal(tr, failed=True)
            else:
                self._retry.append(tr)
            n += 1
        return n

    def _abort_boundary(self, outcome: str, plan, error: str) -> None:
        """Transaction rollback: restore the canonical tree + fresh
        canonical-shape state, requeue live work."""
        requeued = self._requeue_in_flight()
        self.params_active = self._canonical
        self._heads_active = self._full_heads.copy()
        self._shape_heads = self._full_heads.copy()
        self._masked_active = False
        self._plan_active = None
        self._key_active = None
        if self.compile_cache is not None:
            self.compile_cache.set_active(None)
        self.states = tfm.init_decode_state(self.cfg, self.slots,
                                            self.max_len)
        self._last_boundary_fail = self.steps
        self.boundary_log.append(BoundaryEvent(
            step=self.steps, plan_name=plan.traffic.name,
            outcome=outcome, requeued=requeued, error=error))

    def _maybe_cross_boundary(self) -> None:
        if self.swapper is None:
            return
        if self.degrader is not None:
            plan = self.degrader.select(self._live_tokens())
        elif self.planner is not None:
            plan = self.planner.select(self._live_tokens())
        else:
            return
        if self.steps - self._last_boundary_fail < self.boundary_cooldown:
            return                      # cooling down after a failure
        mlp_t, heads_to = self.swapper.realize_plan(plan)
        masked = (self.compile_cache is not None
                  and bool(getattr(plan, "widths", None))
                  and self.compile_cache.decide(plan) == "masked")
        key = (tuple(mlp_t.tolist()), tuple(heads_to.tolist()))
        if (key == self._key_active
                and masked == self._masked_active) or (
                self._key_active is None
                and (mlp_t == self.cfg.d_ff).all()
                and (heads_to == self.cfg.n_heads).all()):
            return                      # same realized widths: no boundary
        params_new, event = self.swapper.apply_guarded(plan, masked=masked)
        self.swap_log.append(event)
        if event.outcome != "ok":
            self._abort_boundary("swap_rolled_back", plan, event.error)
            return
        g = self.cfg.n_heads // max(self.cfg.n_kv_heads, 1)
        kv_from = np.maximum(self._heads_active // g, 1)
        kv_to = np.maximum(heads_to // g, 1)
        live = any(tr is not None for tr in self._slots)
        shape_to = self._full_heads.copy() if masked else heads_to
        if live and (kv_to > kv_from).any():
            # Growing KV heads cannot restore sliced-away history:
            # requeue the live requests so their tokens re-prefill at the
            # new width, then adopt the plan on a fresh cache.  (A masked
            # grow requeues too — the re-grown heads' history rows hold
            # zeros written while they were masked.)
            requeued = self._requeue_in_flight()
            self.states = self._fresh_states(shape_to)
            outcome = "requeued_grow"
        elif masked and (shape_to == self._shape_heads).all():
            # Masked realization on already-canonical shapes: the
            # dropped heads are zero-weighted on both the q and output
            # projections, so stale KV rows in them are unreadable — no
            # state op needed.  (Every other boundary goes through
            # reshape_states, preserving its transactional fault
            # surface even for value-only changes.)
            requeued = 0
            outcome = "ok"
        else:
            try:
                self.states = self.swapper.reshape_states(
                    self.states, self._shape_heads, shape_to)
                # Live chunk checkpoints cross the boundary with the
                # shared cache (same transaction: a fault here aborts the
                # whole crossing and the requeued checkpoints revalidate
                # against whatever widths the engine recovers to).
                for ctr in self._slots:
                    if ctr is not None and ctr.chunk_state is not None:
                        ctr.chunk_state = self.swapper.reshape_states(
                            ctr.chunk_state, self._shape_heads, shape_to)
                        ctr.chunk_heads = np.asarray(shape_to).copy()
                        ctr.chunk_eff = heads_to.copy()
                requeued = 0
                outcome = "ok"
            except Exception as e:  # noqa: BLE001 — the guard IS the point
                self._abort_boundary("reshape_failed", plan,
                                     f"{type(e).__name__}: {e}")
                return
        self.params_active = params_new
        self._heads_active = heads_to
        self._shape_heads = shape_to
        self._masked_active = masked
        self._plan_active = plan
        self._key_active = key
        if self.compile_cache is not None:
            from repro.serving.compile_cache import realized_exec_key
            self.compile_cache.set_active(
                None if masked else realized_exec_key(mlp_t, heads_to))
        self.plan_log.append(plan)
        self.boundary_log.append(BoundaryEvent(
            step=self.steps, plan_name=plan.traffic.name,
            outcome=outcome, requeued=requeued))

    # ------------------------------------------------------------------
    # the engine step
    # ------------------------------------------------------------------
    def _watchdog(self) -> None:
        """Shed any decoding request past its deadline — enforcement
        *during* decode, not only at admission."""
        now = self.clock()
        for i, tr in enumerate(self._slots):
            if tr is None or tr.request.deadline_s is None:
                continue
            if now - tr.arrival_t > tr.request.deadline_s:
                self._slots[i] = None
                self.pos[i] = 0
                self._last_tok[i] = 0
                self._terminal(tr, shed=True)

    def step(self) -> bool:
        """One engine step: deliver arrivals, join free slots, decode one
        token for every occupied slot, account time, enforce watchdogs,
        consider a plan boundary.  Returns True while work remains."""
        self.steps += 1
        self._deliver()
        if self.steps % self.boundary_every == 0:
            self._maybe_cross_boundary()
        prefill_tokens = self._join_waiting()
        chunk_tokens = 0
        if self.prefill_chunk is not None:
            # Chunk budget: whatever the step token budget leaves after
            # one decode token per decoding slot.  Budget-less engines
            # run every prefilling slot one chunk per step.
            n_decoding = sum(tr is not None and tr.chunk_state is None
                             for tr in self._slots)
            cbudget = None if self.step_token_budget is None \
                else max(self.step_token_budget - n_decoding, 0)
            chunk_tokens = self._advance_prefills(cbudget)
        active = [i for i, tr in enumerate(self._slots)
                  if tr is not None and tr.chunk_state is None]
        if not active and prefill_tokens == 0 and chunk_tokens == 0:
            if not (self._queue or self._retry) and self._pending:
                # idle until the next arrival: fast-forward a virtual
                # clock; a wall clock delivers immediately (open-loop
                # arrival times in the past).
                nxt = min(tr.arrival_t for tr in self._pending)
                advance = getattr(self.clock, "advance", None)
                if advance is not None and nxt > self.clock():
                    advance(nxt - self.clock())
                else:
                    self._queue.extend(
                        sorted(self._pending,
                               key=lambda tr: (tr.arrival_t, tr.rid)))
                    self._pending.clear()
                return self._outstanding()
            return self._outstanding()

        t0 = self.clock()
        decoded = 0
        if active:
            toks = jnp.asarray(self._last_tok)
            posv = jnp.asarray(self.pos)
            logits, self.states = self._decode(self.params_active, toks,
                                               posv, self.states)
            logits = logits[:, :self.cfg.vocab_size]
            cur = self._sample(logits, active)
            host = np.asarray(cur)
            for i in active:
                tr = self._slots[i]
                tr.generated.append(int(host[i]))
                self.pos[i] += 1
                self._last_tok[i] = int(host[i])
                decoded += 1
                if self._done(tr):
                    self._release(i)
            self._decode_steps += 1

        # time accounting: modeled (virtual clock) or measured
        step_tokens = decoded + prefill_tokens + chunk_tokens
        if self.batch_cost_fn is not None and step_tokens:
            dt = self.batch_cost_fn(self._plan_active, step_tokens)
            advance = getattr(self.clock, "advance", None)
            if advance is not None:
                advance(dt)
        self._watchdog()
        if self.admission is not None and self.degrader is not None:
            qb = (len(self._queue) + len(self._retry)
                  + self.slots - 1) // self.slots
            self.degrader.observe(self.admission.signal(qb))
        del t0
        return self._outstanding()

    def _sample(self, logits, active):
        temps = [self._slots[i].request.temperature for i in active]
        if not any(t > 0 for t in temps):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        temp = np.ones(self.slots, np.float32)
        use = np.zeros(self.slots, bool)
        for i in active:
            t = self._slots[i].request.temperature
            if t > 0:
                temp[i] = max(t, 1e-6)
                use[i] = True
        self.rng, sub = jax.random.split(self.rng)
        nxt = jax.random.categorical(
            sub, logits / jnp.asarray(temp)[:, None], axis=-1)
        greedy = jnp.argmax(logits, axis=-1)
        return jnp.where(jnp.asarray(use), nxt, greedy).astype(jnp.int32)

    def _outstanding(self) -> bool:
        return (bool(self._pending) or bool(self._queue)
                or bool(self._retry)
                or any(tr is not None for tr in self._slots))

    # ------------------------------------------------------------------
    # front doors
    # ------------------------------------------------------------------
    def run(self, arrivals: Sequence, *, max_steps: int = 1_000_000
            ) -> List[Result]:
        """Serve an open-loop workload (``Arrival``s or bare ``Request``s,
        which arrive immediately) to completion; results align with the
        input order."""
        rids = []
        for a in arrivals:
            if isinstance(a, Arrival):
                rids.append(self.submit(a.request, arrival_t=a.t,
                                        klass=a.klass))
            else:
                rids.append(self.submit(a))
        steps = 0
        while self._outstanding():
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"run exceeded {max_steps} steps")
            self.step()
        return [self._results[r] for r in rids]

    def drain(self, *, max_steps: int = 100_000) -> Ledger:
        """Stop admitting, shed the waiting queue, finish (or shed, once
        ``max_steps`` is spent) the in-flight work, and return a complete
        ledger."""
        self.draining = True
        self._deliver()
        for tr in list(self._pending) + list(self._queue):
            self._terminal(tr, shed=True)
        self._pending.clear()
        self._queue.clear()
        if not self._retry and all(tr is None for tr in self._slots):
            # Nothing in flight (including the zero-submission case):
            # return the — possibly empty — ledger without stepping the
            # engine at all.
            led = self.ledger()
            assert led.complete, f"drain ledger does not sum: {led}"
            return led
        steps = 0
        while self._retry or any(tr is not None for tr in self._slots):
            steps += 1
            if steps > max_steps:
                for i, tr in enumerate(self._slots):
                    if tr is not None:
                        self._slots[i] = None
                        self.pos[i] = 0
                        self._terminal(tr, shed=True)
                while self._retry:
                    self._terminal(self._retry.popleft(), shed=True)
                break
            self.step()
        led = self.ledger()
        assert led.complete, f"drain ledger does not sum: {led}"
        return led
