"""Health-aware replica routing with hedging and zero-loss failover.

One :class:`~repro.serving.continuous.ContinuousServeEngine` is a single
point of failure: a stalled device stalls every slot, and a crash loses
every in-flight request.  :class:`ReplicaRouter` fronts N engines and
turns replica failures into latency, never into loss:

  * **Discrete-event scheduling** — every replica runs its own
    ``VirtualClock``; the router always steps the furthest-behind
    healthy replica with outstanding work (ties break on replica
    index), so the fleet's clocks stay loosely synchronized and the
    entire interleaving is a pure function of the seeds.  Run twice,
    get the identical trace — the chaos tier asserts it.
  * **Health from existing telemetry** — a per-replica EWMA of
    per-step wall time (heartbeats) marks a replica *slow* when it
    exceeds ``slow_factor`` x the fleet's fastest EWMA (after
    ``min_beats`` observations), or when its ``boundary_log`` shows
    ``max_aborts`` failed boundary crossings; a replica whose
    ``step()`` raises is *dead*.  Both come from signals the engines
    already record — no new instrumentation inside the engine.
  * **Zero-loss failover** — a slow or dead replica is drained via
    ``evict_in_flight()``: every non-terminal request leaves with its
    generated tokens and chunked-prefill checkpoint intact and is
    ``adopt()``-ed by the least-loaded healthy replica under its
    original arrival time.  Migrations are bounded
    (``max_migrations``); a request out of moves fails *accountably*
    (a terminal ``Result``, counted in the ledger) — never silently.
  * **Width-variant hedging** — with a :class:`.hedging.HedgePolicy`,
    a request that outlives the observed latency quantile of its class
    gets a backup leg on a sibling replica, optionally pinned to a
    narrower :class:`~repro.serving.degradation.DegradationLadder`
    rung (``pin_floor``) for the backup's lifetime.  First completed
    leg wins; the loser is cancelled *slot-exactly*
    (``ContinuousServeEngine.cancel``) and the pair resolves to one
    logical :class:`~repro.serving.engine.Result` with
    ``hedged=True`` / ``won_by`` — one ledger entry, not two.

``RouterLedger`` accounts *logical* requests: a hedge pair is one
request, a migrated request is one request, and
``submitted == finished + shed + failed`` holds exactly after every run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.continuous import Arrival, ContinuousServeEngine
from repro.serving.engine import Request, Result
from repro.serving.hedging import HedgeEvent, HedgePolicy


@dataclasses.dataclass(frozen=True)
class RouterLedger:
    """Logical-request accounting across the fleet (hedge pair = one)."""

    submitted: int
    finished: int
    shed: int
    failed: int
    hedged: int               # logical requests that launched a backup
    hedge_wins_backup: int    # hedged requests won by the backup leg
    migrated: int             # logical requests that survived >=1 failover
    in_flight: int            # unresolved logicals (0 after run())

    @property
    def accounted(self) -> int:
        return self.finished + self.shed + self.failed

    @property
    def complete(self) -> bool:
        return self.accounted == self.submitted and self.in_flight == 0


@dataclasses.dataclass(frozen=True)
class HealthEvent:
    """One replica state transition, in ``health_log``."""

    t: float                  # router time at the transition
    replica: str
    state: str                # "slow" | "dead"
    reason: str


@dataclasses.dataclass
class Replica:
    """One engine behind the router, with its health bookkeeping."""

    name: str
    engine: ContinuousServeEngine
    index: int = 0
    state: str = "healthy"    # "healthy" | "slow" | "dead"
    ewma: float = 0.0         # per-step wall-time EWMA (heartbeats)
    beats: int = 0

    def outstanding(self) -> int:
        led = self.engine.ledger()
        return led.in_flight + led.queued


@dataclasses.dataclass
class _Logical:
    """Router-level request: one entry per arrival, across all legs."""

    lid: int
    request: Request
    klass: str
    arrival_t: float
    legs: Dict[str, Tuple[str, int]] = dataclasses.field(
        default_factory=dict)          # leg -> (replica name, engine rid)
    results: Dict[str, Result] = dataclasses.field(default_factory=dict)
    hedged: bool = False
    hedge_delay_s: float = 0.0
    hedge_event: int = -1              # index into hedge_log
    pin_replica: str = ""              # replica whose degrader is pinned
    migrations: int = 0
    done: Optional[Result] = None


class ReplicaRouter:
    """Route an open-loop workload over N continuous engines.

    ``replicas`` maps name -> engine (insertion order fixes the replica
    index used in every deterministic tie-break).  ``hedge`` enables
    width-variant hedging; ``planner`` supplies its latency telemetry
    (pass the planner the engines record() into).  ``slow_factor=None``
    disables EWMA slow detection (crash detection stays on)."""

    def __init__(self, replicas: Dict[str, ContinuousServeEngine], *,
                 hedge: Optional[HedgePolicy] = None, planner=None,
                 slow_factor: Optional[float] = 4.0, min_beats: int = 8,
                 ewma_alpha: float = 0.3, max_migrations: int = 2,
                 max_aborts: int = 3):
        if not replicas:
            raise ValueError("need at least one replica")
        self.replicas = [Replica(name=n, engine=e, index=i)
                         for i, (n, e) in enumerate(replicas.items())]
        self._by_name = {r.name: r for r in self.replicas}
        self.hedge = hedge
        self.planner = planner
        self.slow_factor = None if slow_factor is None else float(slow_factor)
        self.min_beats = max(int(min_beats), 1)
        self.ewma_alpha = float(ewma_alpha)
        self.max_migrations = max(int(max_migrations), 0)
        self.max_aborts = max(int(max_aborts), 1)
        self._logicals: List[_Logical] = []
        self._legmap: Dict[Tuple[str, int], Tuple[int, str]] = {}
        self._consumed: set = set()
        self.health_log: List[HealthEvent] = []
        self.hedge_log: List[HedgeEvent] = []

    # ------------------------------------------------------------------
    # replica selection
    # ------------------------------------------------------------------
    def _healthy(self) -> List[Replica]:
        return [r for r in self.replicas if r.state == "healthy"]

    def _least_loaded(self, exclude: Sequence[str] = ()) -> Optional[Replica]:
        cands = [r for r in self._healthy() if r.name not in exclude]
        if not cands:
            cands = self._healthy()
        if not cands:
            return None
        return min(cands, key=lambda r: (r.outstanding(), r.index))

    # ------------------------------------------------------------------
    # leg bookkeeping
    # ------------------------------------------------------------------
    def _attach(self, lg: _Logical, leg: str, r: Replica, rid: int) -> None:
        lg.legs[leg] = (r.name, rid)
        self._legmap[(r.name, rid)] = (lg.lid, leg)

    def _submit_leg(self, lg: _Logical, leg: str, r: Replica) -> None:
        rid = r.engine.submit(lg.request, arrival_t=lg.arrival_t,
                              klass=lg.klass)
        self._attach(lg, leg, r, rid)

    def _poll(self) -> None:
        """Collect newly-terminal leg results and resolve logicals."""
        for (name, rid), (lid, leg) in list(self._legmap.items()):
            if (name, rid) in self._consumed:
                continue
            res = self._by_name[name].engine.result(rid)
            if res is None:
                continue
            self._consumed.add((name, rid))
            lg = self._logicals[lid]
            lg.results[leg] = res
            if lg.done is None:
                self._resolve(lg)

    def _resolve(self, lg: _Logical) -> None:
        """First successful leg wins; the other leg is cancelled
        slot-exactly.  With every leg terminal and none successful the
        pair resolves failed (preferred over shed: a failure is the
        stronger, more actionable verdict)."""
        winner = None
        for leg in ("primary", "backup"):
            res = lg.results.get(leg)
            if res is not None and not res.shed and not res.failed:
                winner = leg
                break
        if winner is None:
            if len(lg.results) < len(lg.legs):
                return                  # a leg is still running
            pick = next((l for l in ("primary", "backup")
                         if lg.results.get(l) is not None
                         and lg.results[l].failed), None)
            pick = pick or next(l for l in ("primary", "backup")
                                if l in lg.results)
            lg.done = dataclasses.replace(
                lg.results[pick], hedged=lg.hedged,
                won_by="", migrations=lg.migrations)
            self._release_pin(lg)
            return
        for leg, (name, rid) in lg.legs.items():
            if leg != winner and leg not in lg.results:
                self._by_name[name].engine.cancel(rid)
                self._consumed.add((name, rid))
        lg.done = dataclasses.replace(
            lg.results[winner], hedged=lg.hedged,
            won_by=(winner if lg.hedged else ""),
            migrations=lg.migrations)
        self._release_pin(lg)
        if lg.hedged and lg.hedge_event >= 0:
            self.hedge_log[lg.hedge_event] = dataclasses.replace(
                self.hedge_log[lg.hedge_event], winner=winner)

    def _release_pin(self, lg: _Logical) -> None:
        if lg.pin_replica:
            r = self._by_name[lg.pin_replica]
            if r.engine.degrader is not None:
                r.engine.degrader.release_floor()
            lg.pin_replica = ""

    # ------------------------------------------------------------------
    # hedging
    # ------------------------------------------------------------------
    def _hedge_check(self) -> None:
        if self.hedge is None:
            return
        outstanding = sum(1 for lg in self._logicals
                          if lg.hedged and lg.done is None)
        for lg in self._logicals:
            if lg.done is not None or lg.hedged or "primary" not in lg.legs:
                continue
            pname, _ = lg.legs["primary"]
            primary = self._by_name[pname]
            if primary.state == "dead":
                continue                # failover path owns this one
            delay = self.hedge.hedge_delay(self.planner, lg.klass)
            elapsed = primary.engine.clock() - lg.arrival_t
            if not self.hedge.should_hedge(
                    elapsed_s=elapsed, delay_s=delay,
                    outstanding=outstanding, request=lg.request):
                continue
            backup = self._least_loaded(exclude=(pname,))
            if backup is None:
                continue
            lg.hedged = True
            lg.hedge_delay_s = delay
            outstanding += 1
            if self.hedge.rung > 0 and backup.engine.degrader is not None:
                backup.engine.degrader.pin_floor(self.hedge.rung)
                lg.pin_replica = backup.name
            self._submit_leg(lg, "backup", backup)
            lg.hedge_event = len(self.hedge_log)
            self.hedge_log.append(HedgeEvent(
                lid=lg.lid, launched_t=backup.engine.clock(),
                delay_s=delay, rung=self.hedge.rung, replica=backup.name))

    # ------------------------------------------------------------------
    # health + failover
    # ------------------------------------------------------------------
    def _demote(self, r: Replica, state: str, reason: str) -> None:
        r.state = state
        self.health_log.append(HealthEvent(
            t=r.engine.clock(), replica=r.name, state=state, reason=reason))
        for tr in r.engine.evict_in_flight():
            key = (r.name, tr.rid)
            mapped = self._legmap.pop(key, None)
            if mapped is None:
                continue
            lid, leg = mapped
            lg = self._logicals[lid]
            if lg.done is not None:
                continue
            self._rehome(lg, leg, tr)
        self._poll()

    def _rehome(self, lg: _Logical, leg: str, tr) -> None:
        lg.migrations += 1
        target = self._least_loaded()
        if target is None or lg.migrations > self.max_migrations:
            # Out of moves (or out of fleet): terminal failure with the
            # partial tokens — accounted, never dropped.
            lg.results[leg] = Result(
                tokens=np.asarray(tr.generated, dtype=np.int32),
                steps=len(tr.generated), failed=True, retries=tr.retries,
                latency_s=max(t.engine.clock() for t in self.replicas)
                - lg.arrival_t)
            lg.legs.setdefault(leg, ("", -1))
            if lg.done is None:
                self._resolve(lg)
            return
        rid = target.engine.adopt(tr)
        self._attach(lg, leg, target, rid)

    def _health_check(self, r: Replica) -> None:
        if r.state != "healthy":
            return
        aborts = sum(1 for ev in r.engine.boundary_log
                     if ev.outcome != "ok")
        if aborts >= self.max_aborts:
            self._demote(r, "slow", f"{aborts} boundary aborts")
            return
        if self.slow_factor is None or r.beats < self.min_beats:
            return
        floor = min((x.ewma for x in self._healthy()
                     if x.beats >= self.min_beats), default=r.ewma)
        if floor > 0 and r.ewma > self.slow_factor * floor:
            self._demote(r, "slow",
                         f"ewma {r.ewma:.4g}s > {self.slow_factor:g}x "
                         f"fleet floor {floor:.4g}s")

    # ------------------------------------------------------------------
    # the event loop
    # ------------------------------------------------------------------
    def run(self, arrivals: Sequence, *, max_steps: int = 1_000_000
            ) -> List[Result]:
        """Serve ``arrivals`` (``Arrival``s or bare ``Request``s) across
        the fleet to completion.  Results align with the input order;
        every logical request resolves (the ledger is complete) even
        under replica crashes, or the run raises."""
        for a in arrivals:
            if isinstance(a, Arrival):
                lg = _Logical(lid=len(self._logicals), request=a.request,
                              klass=a.klass, arrival_t=float(a.t))
            else:
                lg = _Logical(lid=len(self._logicals), request=a,
                              klass="", arrival_t=0.0)
            self._logicals.append(lg)
        todo = sorted(self._logicals, key=lambda lg: (lg.arrival_t, lg.lid))
        pending = list(todo)
        steps = 0
        while any(lg.done is None for lg in self._logicals):
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"router exceeded {max_steps} steps")
            healthy = self._healthy()
            if not healthy:
                # Whole fleet down: fail every unresolved logical.
                now = max(r.engine.clock() for r in self.replicas)
                for lg in self._logicals:
                    if lg.done is None:
                        lg.done = Result(
                            tokens=np.zeros(0, np.int32), steps=0,
                            failed=True, hedged=lg.hedged,
                            migrations=lg.migrations,
                            latency_s=max(now - lg.arrival_t, 0.0))
                break
            # Deliver arrivals the fleet has reached.
            horizon = max(r.engine.clock() for r in healthy)
            while pending and pending[0].arrival_t <= horizon:
                lg = pending.pop(0)
                r = self._least_loaded()
                self._submit_leg(lg, "primary", r)
            self._hedge_check()
            workers = [r for r in healthy if r.engine._outstanding()]
            if not workers:
                if pending:
                    nxt = pending[0].arrival_t
                    for r in healthy:
                        adv = getattr(r.engine.clock, "advance", None)
                        if adv is not None and r.engine.clock() < nxt:
                            adv(nxt - r.engine.clock())
                        elif adv is None:
                            # wall clock: deliver immediately
                            horizon = nxt
                    if all(getattr(r.engine.clock, "advance", None) is None
                           for r in healthy):
                        lg = pending.pop(0)
                        self._submit_leg(lg, "primary", self._least_loaded())
                    continue
                self._poll()
                if any(lg.done is None for lg in self._logicals):
                    # Legs all terminal but unresolved pairs remain.
                    for lg in self._logicals:
                        if lg.done is None and lg.results:
                            self._resolve(lg)
                    if any(lg.done is None for lg in self._logicals):
                        raise RuntimeError(
                            "router stalled with unresolved requests")
                continue
            # Step the furthest-behind worker (tie -> lowest index).
            r = min(workers, key=lambda x: (x.engine.clock(), x.index))
            t0 = r.engine.clock()
            try:
                r.engine.step()
            except Exception as e:  # noqa: BLE001 — crash = dead replica
                self._demote(r, "dead", f"{type(e).__name__}: {e}")
                continue
            dt = r.engine.clock() - t0
            r.beats += 1
            r.ewma = dt if r.beats == 1 else (
                self.ewma_alpha * dt + (1 - self.ewma_alpha) * r.ewma)
            self._poll()
            self._health_check(r)
        self._poll()
        return [lg.done for lg in self._logicals]

    def ledger(self) -> RouterLedger:
        fin = shed = failed = wins = 0
        for lg in self._logicals:
            if lg.done is None:
                continue
            if lg.done.failed:
                failed += 1
            elif lg.done.shed:
                shed += 1
            else:
                fin += 1
            if lg.done.hedged and lg.done.won_by == "backup":
                wins += 1
        return RouterLedger(
            submitted=len(self._logicals), finished=fin, shed=shed,
            failed=failed,
            hedged=sum(1 for lg in self._logicals if lg.hedged),
            hedge_wins_backup=wins,
            migrated=sum(1 for lg in self._logicals
                         if lg.migrations > 0 and lg.done is not None),
            in_flight=sum(1 for lg in self._logicals if lg.done is None))
