"""Width-downshift graceful degradation: the overload response ladder.

Queueing stacks latency without bound as arrival rate approaches service
rate — near saturation every queued batch pushes the tail out further, so
the p99 of an overloaded server is set by the queue, not the model.  The
paper's Algorithm 2 hands us a better lever than queueing: every
``WidthPlan`` carries a *predicted* ``latency_reduction``, so under
overload the correct response is to serve at a narrower, faster width
(trading accuracy the same way HALP's latency/accuracy pareto does
statically) and return to full width when the burst passes.

Two pieces:

  * :class:`DegradationLadder` — per traffic class, an ordered list of
    rungs from full width (level 0, the canonical tree, zero accuracy
    loss) through successively tighter Algorithm 2 targets, ranked by
    predicted ``latency_reduction`` from the existing stacked tables.
    Building the ladder is just repeated planning at tighter ``delta``
    targets — no new latency model, the same persistent profile tables.
  * :class:`DegradationController` — the runtime policy: consumes the
    engine's overload signal (queue depth + batch-latency EWMA, see
    ``engine.AdmissionControl.signal``) once per batch and downshifts /
    upshifts the active level with hysteresis (separate thresholds and
    patience counters per direction), so a single slow batch cannot
    thrash the width back and forth.  ``select`` is the boundary-time
    lookup the engine calls instead of ``planner.select`` when a
    controller is attached.

Every shift is recorded in ``shift_log`` — the serving telemetry that,
together with ``ServeEngine.swap_log`` outcomes, makes a chaos run
auditable after the fact.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.serving.engine import (
    ServingWidthPlanner, TrafficClass, WidthPlan,
)


@dataclasses.dataclass(frozen=True)
class LadderRung:
    """One degradation level: a plan per traffic class at one target."""

    level: int                      # 0 = full width, higher = narrower
    plans: dict                     # traffic-class name -> WidthPlan
    reduction: float                # max predicted latency_reduction

    def plan_for(self, tokens: int) -> WidthPlan:
        """Nearest class (log-scale token distance, like
        ``ServingWidthPlanner.select``) at this rung."""
        return min(
            self.plans.values(),
            key=lambda p: abs(np.log(max(tokens, 1))
                              - np.log(max(p.traffic.tokens, 1))))


@dataclasses.dataclass(frozen=True)
class Shift:
    """One ladder move, as recorded in ``shift_log``."""

    direction: str      # "down" | "up"
    level: int          # level AFTER the shift
    signal: float       # overload signal that triggered it
    batch_index: int    # observe() call count at the shift


class DegradationLadder:
    """Ordered width-plan rungs per traffic class, full width first."""

    def __init__(self, rungs: Sequence[LadderRung]):
        if not rungs:
            raise ValueError("empty degradation ladder")
        self.rungs = list(rungs)

    def __len__(self) -> int:
        return len(self.rungs)

    def rung(self, level: int) -> LadderRung:
        """Rung at ``level``, clamped to the ladder's range."""
        return self.rungs[max(0, min(level, len(self.rungs) - 1))]

    @classmethod
    def build(cls, planner: ServingWidthPlanner,
              traffic: Sequence[TrafficClass],
              deltas: Sequence[float] = (0.85, 0.7, 0.55),
              tile_hw=None) -> "DegradationLadder":
        """One Algorithm 2 pass per (traffic class, delta target).

        Level 0 is always the canonical full width (``widths={}`` — the
        swapper returns the retained original tree, so recovery is
        bit-for-bit); each ``delta`` adds one rung.  Rungs are ranked by
        their predicted ``latency_reduction`` — deltas may be given in
        any order, and a delta whose plan reduces nothing beyond the
        previous rung still gets a rung (downshifting to it is a no-op
        swap, which is correct: the ladder never *adds* latency).  All
        table builds go through the planner's optimizer, so a warm
        profile-table cache makes ladder construction sweep-free.

        With ``tile_hw``, equal-reduction rungs are ordered tail-free
        grids first (``planner.plan_tail_free`` on a planner carrying
        the same spec): the ladder reaches for a wave-aligned width
        before an equally-fast tail-heavy one.  ``tile_hw=None``
        preserves the historical ordering bit-for-bit.
        """
        traffic = list(traffic)
        if not traffic:
            raise ValueError("need at least one traffic class")
        full = {
            tc.name: WidthPlan(
                traffic=tc, widths={}, latency_s=0.0,
                baseline_latency_s=0.0, satisfied=True,
                modules=planner.modules)
            for tc in traffic
        }
        rungs = [LadderRung(level=0, plans=full, reduction=0.0)]
        planned = []
        for delta in deltas:
            plans = dict(planner.plan([
                dataclasses.replace(tc, delta=float(delta))
                for tc in traffic]))
            red = max(p.latency_reduction for p in plans.values())
            if tile_hw is None:
                tail_penalty = 0
            else:
                # Score through the planner's helper under the ladder's
                # tile spec (restored afterwards — build() must not
                # change the planner's own select() behavior).
                prev_hw, planner.tile_hw = planner.tile_hw, tile_hw
                try:
                    tail_penalty = int(not all(
                        planner.plan_tail_free(p) for p in plans.values()
                        if p.widths))
                finally:
                    planner.tile_hw = prev_hw
            planned.append((red, tail_penalty, plans))
        planned.sort(key=lambda rp: (rp[0], rp[1]))
        for i, (red, _, plans) in enumerate(planned):
            rungs.append(LadderRung(level=i + 1, plans=plans,
                                    reduction=red))
        return cls(rungs)


class DegradationController:
    """Hysteresis-gated walk over a :class:`DegradationLadder`.

    ``observe(signal)`` is called once per completed batch with the
    engine's overload signal (1.0 = at the configured limit).  The
    controller downshifts one level after ``down_patience`` consecutive
    observations at or above ``down_threshold``, and upshifts one level
    after ``up_patience`` consecutive observations at or below
    ``up_threshold``; signals in the dead band between the thresholds
    reset both streaks.  Separate patience per direction biases the
    policy the right way for tails: degrade fast (one hot batch streak),
    recover slowly (sustained calm), and never oscillate on a single
    boundary-straddling observation.
    """

    def __init__(self, ladder: DegradationLadder, *,
                 down_threshold: float = 1.0, up_threshold: float = 0.5,
                 down_patience: int = 2, up_patience: int = 4,
                 observe_every: int = 1):
        if up_threshold >= down_threshold:
            raise ValueError(
                f"hysteresis requires up_threshold < down_threshold "
                f"(got {up_threshold} >= {down_threshold})")
        self.ladder = ladder
        # The batch engine observes once per batch; the continuous
        # engine observes once per *decode step*, which at the same
        # patience would shift a ladder an order of magnitude faster.
        # observe_every coalesces: only every Nth observe() is scored.
        self.observe_every = max(int(observe_every), 1)
        self._observe_calls = 0
        self.down_threshold = down_threshold
        self.up_threshold = up_threshold
        self.down_patience = max(int(down_patience), 1)
        self.up_patience = max(int(up_patience), 1)
        self.level = 0
        self.shift_log: List[Shift] = []
        self._hot = 0
        self._cool = 0
        self._batches = 0
        # Level floors pinned from outside the hysteresis loop (request
        # hedging runs backup executions on a lower rung regardless of
        # the controller's own overload state).  Pins stack: the
        # effective floor is the max of all active pins, and observe()
        # keeps walking self.level underneath them, so releasing the
        # last pin restores exactly the state the controller would have
        # reached on its own.
        self._pins: List[int] = []

    def observe(self, signal: float) -> int:
        """Feed one per-batch overload signal; returns the (possibly
        shifted) active level."""
        self._observe_calls += 1
        if self._observe_calls % self.observe_every != 0:
            return self.level
        self._batches += 1
        if signal >= self.down_threshold:
            self._hot += 1
            self._cool = 0
        elif signal <= self.up_threshold:
            self._cool += 1
            self._hot = 0
        else:                       # dead band: no evidence either way
            self._hot = 0
            self._cool = 0
        if self._hot >= self.down_patience \
                and self.level < len(self.ladder) - 1:
            self.level += 1
            self._hot = 0
            self.shift_log.append(Shift("down", self.level, signal,
                                        self._batches))
        elif self._cool >= self.up_patience and self.level > 0:
            self.level -= 1
            self._cool = 0
            self.shift_log.append(Shift("up", self.level, signal,
                                        self._batches))
        return self.level

    def pin_floor(self, level: int) -> None:
        """Pin a minimum degradation level (clamped to the ladder).
        While any pin is active, :meth:`select` serves from at least the
        highest pinned rung — the width-variant hedging hook: a hedge
        backup's replica is pinned to a narrower, faster rung for the
        backup's lifetime.  Pins nest (LIFO with :meth:`release_floor`)."""
        self._pins.append(max(0, min(int(level), len(self.ladder) - 1)))

    def release_floor(self) -> None:
        """Release the most recent :meth:`pin_floor` (no-op when none)."""
        if self._pins:
            self._pins.pop()

    @property
    def effective_level(self) -> int:
        """The level :meth:`select` serves from: the controller's own
        hysteresis level, raised to any pinned floor."""
        return max([self.level] + self._pins)

    def select(self, tokens: int) -> WidthPlan:
        """The active rung's plan for a batch's token volume — the
        boundary-time lookup the engine performs in place of
        ``planner.select`` when degradation is enabled."""
        return self.ladder.rung(self.effective_level).plan_for(tokens)
