"""Disk-backed profile-table cache — persistent "Step 1: pre-analysis".

The staircase tables the optimizer sweeps (``tail_optimizer._build_tables``)
and the profiler derives (``profiler.analytic_profile``) depend only on the
hardware spec, the layer shape (minus its mutable width), and the width
vector swept.  All three are immutable inputs, so the tables can be
serialized once and reused by every later ``optimize_*`` call — across
processes: NAS sweeps, serving planners, CI — which is what hardware-aware
methods (HALP, the paper's own nvprof flow) assume: a lookup-table latency
oracle that is effectively free at optimization time.

Key = sha256 over

  * ``CACHE_VERSION`` — bumping it invalidates every existing entry (the
    staircase math changed, so the cached numbers are stale);
  * the ``HardwareSpec`` fields (``dataclasses.asdict``, sorted keys);
  * the ``LayerShape`` fields minus ``width`` and ``name`` (two identically
    shaped layers share entries; the swept start width is part of the width
    vector, not the shape);
  * the width vector's raw int64 bytes.

Entries are ``.npz`` files (parallel arrays + a JSON meta record) written
atomically (tmp + ``os.replace``), sharded into two-hex-char directories.
On load the meta is re-verified against the live hardware/shape/version —
a mismatched entry reads as a miss, never as wrong data.  An *unreadable*
entry (truncated zip, garbage bytes — e.g. a crashed writer on a
non-atomic filesystem, or disk corruption) is retried once and then
quarantined: renamed to ``*.bad`` and counted in ``stats.corrupted``, so
the key misses cleanly from then on (the caller re-sweeps and rewrites)
and repeated re-sweeps from a corrupt store stay visible in the stats
instead of masquerading as ordinary misses.

Two granularities share the store: per-layer entries (``get``/``put``,
fine-grained reuse for shallow models) and whole-stack bundles
(``get_stack``/``put_stack``) — one file per packed model sweep, because
at 1000+ layers the per-file open cost of fine-grained entries exceeds
resweeping the analytic model.  ``TailEffectOptimizer`` picks the
granularity by stack depth (``bundle_min_layers``).

Cache location
--------------
``ProfileTableCache(root)`` uses an explicit directory.
``ProfileTableCache.from_env()`` reads the ``REPRO_TABLE_CACHE_DIR``
environment variable: unset (or one of ``0/off/none/disabled/""``) disables
caching (returns ``None``); any other value is the cache root.  Pass
``default=...`` to fall back to a directory (e.g. the conventional
``~/.cache/repro-tail-tables``) when the variable is unset.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import tempfile
import zipfile
from pathlib import Path
from typing import Mapping, Sequence

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.tail_model import LayerShape, StairTable

# Bump when the staircase math (or this file's on-disk layout) changes:
# every existing entry then misses and is rebuilt.
CACHE_VERSION = 1

CACHE_DIR_ENV = "REPRO_TABLE_CACHE_DIR"
DEFAULT_CACHE_DIR = "~/.cache/repro-tail-tables"
_DISABLE_TOKENS = {"", "0", "off", "none", "disabled"}

_STAIR_FIELDS = ("latency_s", "utilization", "throughput", "waves",
                 "flops", "padded_flops")

# Errors an unreadable (truncated / garbage / half-written) npz entry can
# raise on load.  These quarantine the file; a *verify* mismatch (stale
# version, different hw/shape) is a legitimate miss and never does.
_READ_ERRORS = (OSError, ValueError, KeyError, EOFError,
                zipfile.BadZipFile, json.JSONDecodeError)


@functools.lru_cache(maxsize=64)
def _hw_json(hw: HardwareSpec) -> str:
    # dataclasses.asdict is ~100us a call; HardwareSpec is frozen, so one
    # serialization per spec suffices for the whole process.
    return json.dumps(dataclasses.asdict(hw), sort_keys=True)


def hardware_fingerprint(hw: HardwareSpec) -> str:
    """Short stable digest of every HardwareSpec field."""
    return hashlib.sha256(_hw_json(hw).encode()).hexdigest()[:16]


def _shape_fields(layer: LayerShape) -> dict:
    """LayerShape-minus-width (and minus name): the cache's shape key.

    Built field-by-field rather than via ``dataclasses.asdict`` — this
    runs once per layer per table build, and asdict's deep copy dominated
    cache lookups on 1000-layer stacks."""
    return {"tokens": layer.tokens, "d_in": layer.d_in,
            "shard_in": layer.shard_in, "shard_out": layer.shard_out,
            "dtype_bits": layer.dtype_bits,
            "flop_multiplier": layer.flop_multiplier}


def _meta(hw: HardwareSpec, layer: LayerShape, variant: str = "") -> str:
    # ``variant`` names the sweep engine that produced the tables (the
    # model's non-default ``backend``); engines agree only to tolerance,
    # so their entries must not share keys.  The empty string (the exact
    # numpy engine) keeps the historical meta/key unchanged.
    tail = f', "variant": {json.dumps(variant)}' if variant else ""
    return (f'{{"hw": {_hw_json(hw)}, "shape": '
            f'{json.dumps(_shape_fields(layer), sort_keys=True)}, '
            f'"version": {CACHE_VERSION}{tail}}}')


def table_key(hw: HardwareSpec, layer: LayerShape, widths: np.ndarray,
              variant: str = "") -> str:
    """Cache key: (hw fingerprint, shape-minus-width, width-vector hash,
    sweep-engine variant)."""
    w = np.ascontiguousarray(np.asarray(widths, dtype=np.int64))
    h = hashlib.sha256(_meta(hw, layer, variant).encode())
    h.update(w.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    writes: int = 0
    evictions: int = 0
    # entries whose npz could not be read (truncated/garbage file) and
    # were quarantined to *.bad — distinct from `misses` so repeated
    # re-sweeps caused by a corrupt store are visible, not silent
    corrupted: int = 0


def _atomic_savez(path: Path, **arrays) -> None:
    """np.savez to ``path`` via tmp + os.replace: readers never observe a
    partially written entry."""
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ProfileTableCache:
    """npz-file cache of per-layer (width -> latency/U/T/...) tables.

    ``max_bytes`` caps the on-disk size: after every write the oldest
    entries (least-recently *used* — reads touch an entry's mtime) are
    evicted until the store fits, so long-lived NAS sweeps cannot
    accumulate stale bundles without bound.  The entry just written
    always survives, even when it alone exceeds the cap — a cache that
    evicts its own write thrashes at 100%.  ``None`` (default) disables
    the cap; ``clear()`` remains the manual full wipe.
    """

    def __init__(self, root: str | os.PathLike, *,
                 max_bytes: int | None = None):
        self.root = Path(root).expanduser()
        self.max_bytes = max_bytes
        self.stats = CacheStats()

    @classmethod
    def from_env(cls, default: str | None = None,
                 max_bytes: int | None = None
                 ) -> "ProfileTableCache | None":
        """Cache at ``$REPRO_TABLE_CACHE_DIR``; disable tokens (or an unset
        variable with no ``default``) return None."""
        val = os.environ.get(CACHE_DIR_ENV)
        if val is None:
            if default is None:
                return None
            return cls(default, max_bytes=max_bytes)
        if val.strip().lower() in _DISABLE_TOKENS:
            return None
        return cls(val, max_bytes=max_bytes)

    # ---- raw array entries ---------------------------------------------
    def _path(self, key: str) -> Path:
        return self.root / key[:2] / f"{key}.npz"

    def get(self, hw: HardwareSpec, layer: LayerShape,
            widths: np.ndarray,
            variant: str = "") -> dict[str, np.ndarray] | None:
        """Arrays stored for (hw, shape, widths), or None on miss.

        A hit re-verifies the stored meta (version/hw/shape) and width
        vector; a mismatch is a miss.  An *unreadable* entry (truncated
        or garbage npz) is retried once — transient IO — then
        quarantined to ``*.bad`` and counted in ``stats.corrupted``, so
        the caller's re-sweep rewrites a fresh entry instead of
        re-reading the corrupt one forever."""
        w = np.asarray(widths, dtype=np.int64)
        path = self._path(table_key(hw, layer, w, variant))
        if not path.exists():
            self.stats.misses += 1
            return None
        for attempt in (0, 1):
            try:
                with np.load(path, allow_pickle=False) as z:
                    meta = str(z["__meta__"])
                    stored_w = z["widths"]
                    if meta != _meta(hw, layer, variant) \
                            or stored_w.shape != w.shape \
                            or (stored_w != w).any():
                        self.stats.misses += 1
                        return None
                    out = {k: z[k] for k in z.files
                           if k not in ("__meta__", "widths")}
                break
            except _READ_ERRORS:
                if attempt == 0 and path.exists():
                    continue
                self._quarantine(path)
                self.stats.misses += 1
                return None
        self.stats.hits += 1
        self._touch(path)
        return out

    def put(self, hw: HardwareSpec, layer: LayerShape, widths: np.ndarray,
            arrays: Mapping[str, np.ndarray], variant: str = "") -> Path:
        """Atomically persist parallel arrays for (hw, shape, widths)."""
        w = np.asarray(widths, dtype=np.int64)
        path = self._path(table_key(hw, layer, w, variant))
        _atomic_savez(path, __meta__=np.array(_meta(hw, layer, variant)),
                      widths=w, **dict(arrays))
        self.stats.writes += 1
        self._evict_to_cap(keep=path)
        return path

    # ---- whole-stack bundles -------------------------------------------
    # One npz per model sweep: at 1000+ layers, per-layer entries cost one
    # file open each (seconds of zipfile overhead), so large stacks are
    # cached as a single (w2d, counts, latency_2d) bundle keyed over every
    # layer's shape plus the packed width matrix.  Granularity trade-off:
    # any change to the stack misses the whole bundle — callers fall back
    # to one stacked sweep, which is far cheaper than 1000 file opens.

    def stack_key(self, hw: HardwareSpec, layers: Sequence[LayerShape],
                  w2d: np.ndarray, counts: np.ndarray,
                  variant: str = "") -> str:
        h = hashlib.sha256(
            f"stack:{CACHE_VERSION}:{variant}:{_hw_json(hw)}".encode()
            if variant else
            f"stack:{CACHE_VERSION}:{_hw_json(hw)}".encode())
        for layer in layers:
            h.update(repr(sorted(_shape_fields(layer).items())).encode())
        h.update(np.ascontiguousarray(w2d, dtype=np.int64).tobytes())
        h.update(np.ascontiguousarray(counts, dtype=np.int64).tobytes())
        return h.hexdigest()

    def get_stack(self, hw: HardwareSpec, layers: Sequence[LayerShape],
                  w2d: np.ndarray, counts: np.ndarray,
                  variant: str = "") -> np.ndarray | None:
        """The (L, C) latency matrix for a whole packed stack, or None.

        Unreadable bundles follow the same retry-then-quarantine path as
        per-layer entries (``stats.corrupted``, renamed to ``*.bad``)."""
        key = self.stack_key(hw, layers, w2d, counts, variant)
        path = self._path(key)
        if not path.exists():
            self.stats.misses += 1
            return None
        stack_meta = f"stack:{CACHE_VERSION}:{variant}" if variant \
            else f"stack:{CACHE_VERSION}"
        for attempt in (0, 1):
            try:
                with np.load(path, allow_pickle=False) as z:
                    if str(z["__meta__"]) != stack_meta \
                            or not np.array_equal(z["w2d"], w2d) \
                            or not np.array_equal(z["counts"], counts):
                        self.stats.misses += 1
                        return None
                    lat2d = z["latency_2d"]
                break
            except _READ_ERRORS:
                if attempt == 0 and path.exists():
                    continue
                self._quarantine(path)
                self.stats.misses += 1
                return None
        self.stats.hits += 1
        self._touch(path)
        return lat2d

    def put_stack(self, hw: HardwareSpec, layers: Sequence[LayerShape],
                  w2d: np.ndarray, counts: np.ndarray,
                  lat2d: np.ndarray, variant: str = "") -> Path:
        path = self._path(self.stack_key(hw, layers, w2d, counts, variant))
        stack_meta = f"stack:{CACHE_VERSION}:{variant}" if variant \
            else f"stack:{CACHE_VERSION}"
        _atomic_savez(path, __meta__=np.array(stack_meta),
                      w2d=np.asarray(w2d, dtype=np.int64),
                      counts=np.asarray(counts, dtype=np.int64),
                      latency_2d=np.asarray(lat2d, dtype=np.float64))
        self.stats.writes += 1
        self._evict_to_cap(keep=path)
        return path

    # ---- kernel tile configs --------------------------------------------
    # Tiny entries persisting the tile autotuner's chosen blocks per
    # (hardware, kernel, invocation shape+dtype) — see kernels/autotune.py.
    # Selection is deterministic, so these are pure lookup-table reuse: a
    # serving process resolves tiles from disk instead of re-enumerating
    # the candidate space.

    def _tiles_meta(self, hw: HardwareSpec, kernel: str,
                    shape: Sequence[int]) -> str:
        return (f'{{"tiles": {CACHE_VERSION}, "hw": {_hw_json(hw)}, '
                f'"kernel": {json.dumps(kernel)}, '
                f'"shape": {json.dumps(list(map(int, shape)))}}}')

    def tiles_key(self, hw: HardwareSpec, kernel: str,
                  shape: Sequence[int]) -> str:
        return hashlib.sha256(
            self._tiles_meta(hw, kernel, shape).encode()).hexdigest()

    def get_tiles(self, hw: HardwareSpec, kernel: str,
                  shape: Sequence[int]) -> tuple[int, ...] | None:
        """Persisted block tuple for (hw, kernel, shape), or None."""
        path = self._path(self.tiles_key(hw, kernel, shape))
        if not path.exists():
            self.stats.misses += 1
            return None
        for attempt in (0, 1):
            try:
                with np.load(path, allow_pickle=False) as z:
                    if str(z["__meta__"]) != \
                            self._tiles_meta(hw, kernel, shape):
                        self.stats.misses += 1
                        return None
                    blocks = tuple(int(b) for b in z["blocks"])
                break
            except _READ_ERRORS:
                if attempt == 0 and path.exists():
                    continue
                self._quarantine(path)
                self.stats.misses += 1
                return None
        self.stats.hits += 1
        self._touch(path)
        return blocks

    def put_tiles(self, hw: HardwareSpec, kernel: str,
                  shape: Sequence[int],
                  blocks: Sequence[int]) -> Path:
        path = self._path(self.tiles_key(hw, kernel, shape))
        _atomic_savez(
            path, __meta__=np.array(self._tiles_meta(hw, kernel, shape)),
            blocks=np.asarray(list(blocks), dtype=np.int64))
        self.stats.writes += 1
        self._evict_to_cap(keep=path)
        return path

    # ---- StairTable convenience ----------------------------------------
    def put_stair_table(self, hw: HardwareSpec, layer: LayerShape,
                        table: StairTable) -> Path:
        return self.put(hw, layer, table.widths,
                        {f: getattr(table, f) for f in _STAIR_FIELDS})

    def get_stair_table(self, hw: HardwareSpec, layer: LayerShape,
                        widths: np.ndarray) -> StairTable | None:
        arrays = self.get(hw, layer, widths)
        if arrays is None or any(f not in arrays for f in _STAIR_FIELDS):
            return None
        return StairTable(widths=np.asarray(widths, dtype=np.int64),
                          **{f: arrays[f] for f in _STAIR_FIELDS})

    # ---- maintenance ----------------------------------------------------
    def _quarantine(self, path: Path) -> bool:
        """Rename an unreadable entry to ``<name>.bad`` so the next read
        of the same key is a clean miss (re-sweep + rewrite) instead of
        another doomed parse.  The sidecar keeps the evidence on disk
        for postmortems; ``purge_quarantined`` deletes it."""
        bad = path.with_name(path.name + ".bad")
        try:
            os.replace(path, bad)
        except OSError:
            return False     # e.g. lost a race with another process
        self.stats.corrupted += 1
        return True

    def quarantined(self) -> list[Path]:
        """Quarantined (``*.npz.bad``) entries currently on disk."""
        return sorted(self.root.glob("??/*.npz.bad"))

    def purge_quarantined(self) -> int:
        """Delete quarantined entries; returns the number removed."""
        removed = 0
        for p in self.root.glob("??/*.npz.bad"):
            try:
                p.unlink()
                removed += 1
            except OSError:
                pass
        return removed

    @staticmethod
    def _touch(path: Path) -> None:
        """Bump an entry's mtime on a read hit: eviction order becomes
        least-recently-USED, so a hot entry survives a sweep of writes."""
        try:
            os.utime(path)
        except OSError:
            pass

    def _evict_to_cap(self, keep: Path | None = None) -> int:
        """Evict oldest-mtime entries until the store fits ``max_bytes``.
        ``keep`` (the entry just written) is never evicted.  Returns the
        number of entries removed."""
        if self.max_bytes is None:
            return 0
        entries = []
        total = 0
        for p in self.root.glob("??/*.npz"):
            try:
                stt = p.stat()
            except OSError:
                continue
            entries.append((stt.st_mtime, stt.st_size, p))
            total += stt.st_size
        if total <= self.max_bytes:
            return 0
        removed = 0
        for _, size, p in sorted(entries):
            if total <= self.max_bytes:
                break
            if keep is not None and p == keep:
                continue
            try:
                p.unlink()
            except OSError:
                continue
            total -= size
            removed += 1
        self.stats.evictions += removed
        return removed

    def size_bytes(self) -> int:
        """Total bytes currently stored under root (entries another
        process removes mid-scan count as 0, like everywhere else)."""
        total = 0
        for p in self.root.glob("??/*.npz"):
            try:
                total += p.stat().st_size
            except OSError:
                pass
        return total

    def clear(self) -> int:
        """Remove every cache entry under root (including quarantined
        ``*.bad`` sidecars); returns live entries removed."""
        removed = 0
        if not self.root.exists():
            return removed
        for p in self.root.glob("??/*.npz"):
            try:
                p.unlink()
                removed += 1
            except OSError:
                pass
        self.purge_quarantined()
        return removed
