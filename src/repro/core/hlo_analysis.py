"""Extract roofline inputs from lowered/compiled XLA artifacts.

``compiled.cost_analysis()`` provides HLO FLOPs and bytes accessed, but NOT
collective traffic — we recover that by parsing the (post-SPMD-partitioning)
HLO text and summing operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, as well as estimating the
actual ring traffic per device from the replica-group sizes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  bf16[256,4096]{1,0}  or  f32[] or  u32[8,16]
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# `%x = bf16[...] all-gather(%y), ...` — post-optimization HLO prints the
# RESULT shape but not operand shapes; we derive operand size from the
# result + group size.  `-done` halves of async pairs are skipped (the
# `-start` carries the shape).
_OP_RE = re.compile(
    r"=\s*(?:\(?\s*(?:" + "|".join(_DTYPE_BYTES)
    + r")\[[^=]*?)?\b(" + "|".join(COLLECTIVE_KINDS)
    + r")(-start|-done)?\("
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")


def shape_bytes(dtype: str, dims_str: str) -> int:
    n = 1
    if dims_str:
        for d in dims_str.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: int
    group_size: int
    line: str

    @property
    def operand_bytes(self) -> int:
        """Input-operand size, derived from the result shape."""
        n = max(self.group_size, 1)
        if self.kind == "all-gather":
            return self.result_bytes // n
        if self.kind == "reduce-scatter":
            return self.result_bytes * n
        return self.result_bytes   # all-reduce / all-to-all / permute

    @property
    def ring_traffic_bytes(self) -> float:
        """Per-device ICI bytes under a ring/bidirectional schedule."""
        n = max(self.group_size, 1)
        r = self.result_bytes
        if self.kind == "collective-permute":
            return float(r)                   # always moves one buffer
        if n == 1:
            return 0.0
        if self.kind == "all-gather":
            return r * (n - 1) / n            # result is the full gather
        if self.kind == "reduce-scatter":
            return r * (n - 1)                # result is one shard
        if self.kind == "all-reduce":
            return 2.0 * r * (n - 1) / n      # RS + AG
        if self.kind == "all-to-all":
            return r * (n - 1) / n
        if self.kind == "collective-permute":
            return float(r)
        return float(r)


@dataclasses.dataclass
class CollectiveSummary:
    ops: list[CollectiveOp]

    @property
    def total_operand_bytes(self) -> int:
        return sum(o.operand_bytes for o in self.ops)

    @property
    def total_ring_traffic_bytes(self) -> float:
        return sum(o.ring_traffic_bytes for o in self.ops)

    def by_kind(self) -> dict[str, dict[str, float]]:
        out: dict[str, dict[str, float]] = {}
        for o in self.ops:
            d = out.setdefault(o.kind, {"count": 0, "operand_bytes": 0,
                                        "ring_traffic_bytes": 0.0})
            d["count"] += 1
            d["operand_bytes"] += o.operand_bytes
            d["ring_traffic_bytes"] += o.ring_traffic_bytes
        return out


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota v2 format [g0,g1,...]<=[N]: groups are rows of the reshaped
        # device list -> group size is the product of all dims but the first.
        dims = [int(x) for x in m.group(1).split(",")]
        size = 1
        for d in dims[1:]:
            size *= d
        return max(size, 1)
    return 1


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    """Collect every collective in (post-partitioning) HLO, sized by its
    result shape.  Async `-done` halves are skipped (the `-start` carries
    the shape)."""
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if m.group(2) == "-done":
            continue
        # Result shapes: all dtype[dims] tokens between '=' and the op name.
        eq = line.find("=")
        before = line[eq + 1: m.start() + (m.end() - m.start())] \
            if eq >= 0 else line[: m.start()]
        before = line[eq + 1: line.find(kind, eq)] if eq >= 0 else before
        result_bytes = sum(
            shape_bytes(sm.group(1), sm.group(2))
            for sm in _SHAPE_RE.finditer(before)
        )
        ops.append(CollectiveOp(kind=kind, result_bytes=result_bytes,
                                group_size=_group_size(line),
                                line=line.strip()))
    return CollectiveSummary(ops=ops)


def count_ops(hlo_text: str, names: Iterable[str]) -> dict[str, int]:
    """Count occurrences of HLO op kinds (e.g. to spot remat recompute)."""
    out = {}
    for n in names:
        out[n] = len(re.findall(rf"[\s)]{re.escape(n)}\(", hlo_text))
    return out


def cost_summary(compiled) -> dict[str, float]:
    """Normalize compiled.cost_analysis() across jax versions."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {"flops": float(ca.get("flops", 0.0))}
    total_bytes = 0.0
    for k, v in ca.items():
        if k.startswith("bytes accessed") and k in ("bytes accessed",):
            total_bytes = float(v)
    if total_bytes == 0.0:
        total_bytes = float(ca.get("bytes accessed", 0.0))
    out["bytes_accessed"] = total_bytes
    for k in ("transcendentals", "optimal_seconds"):
        if k in ca:
            out[k] = float(ca[k])
    return out
