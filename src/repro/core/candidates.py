"""Optimal width-candidate generation — paper Eq. 4.

    C_i[m] = argmax_m ( U_i x T_i )

The paper identifies, per layer, the width configurations that maximize
(SM utilization x GPU throughput): these are the right edges of the latency
staircase (Fig. 6).  We provide two generators:

  * ``analytic_candidates`` — from the wave-quantization model: the right
    edges are exactly the multiples of the quantum Q = shard_out * lane.
  * ``profile_candidates`` — from a profiled/derived (width, U, T, L) table,
    exactly the paper's procedure, so the optimizer also works when fed
    measured tables (e.g. on hardware we do not have a closed form for).

Both return sorted unique widths.  ``profile_candidates`` on a table produced
by the analytic model must agree with ``analytic_candidates`` — this is a
property test in tests/test_tail_model.py.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.tail_model import (
    LayerShape, ModelStairTable, WaveQuantizationModel,
)


def analytic_candidates(
    hw: HardwareSpec,
    layer: LayerShape,
    max_width: int | None = None,
    min_width: int = 1,
) -> np.ndarray:
    """Multiples of the width quantum Q = shard_out * lane, in range."""
    model = WaveQuantizationModel(hw)
    q = model.width_quantum(layer.shard_out)
    hi = max_width if max_width is not None else layer.width
    first = max(q, ((min_width + q - 1) // q) * q)
    cands = np.arange(first, hi + 1, q, dtype=np.int64)
    if cands.size == 0:  # layer narrower than one quantum: only choice is Q
        cands = np.array([q], dtype=np.int64)
    return cands


def realizable_candidates(
    hw: HardwareSpec,
    layer: LayerShape,
    *,
    realize_quantum: int = 1,
    max_width: int | None = None,
    min_width: int = 1,
) -> np.ndarray:
    """Analytic stair edges snapped DOWN onto the realizable grid.

    The staircase grid (multiples of Q = shard_out * lane) and the grid a
    swapper can actually materialize disagree at some sites: attention
    widths are only realizable as whole GQA head groups
    (``realize_quantum = g * head_dim``), while FFN widths realize at any
    lane multiple (``realize_quantum = 1`` degenerates to
    ``analytic_candidates``).  Planning on the staircase grid and
    re-snapping at swap time silently changes the width — and therefore
    the latency the plan was ranked by.  Instead, floor each stair edge
    to the realizable grid: the result is the widest realizable width
    inside each stair (same wave count, so the modeled latency of the
    snapped width is the stair's own), and every returned candidate is
    materializable as-is.
    """
    if realize_quantum <= 1:
        return analytic_candidates(hw, layer, max_width=max_width,
                                   min_width=min_width)
    edges = analytic_candidates(hw, layer, max_width=max_width,
                                min_width=min_width)
    rq = int(realize_quantum)
    lo = max(rq, ((min_width + rq - 1) // rq) * rq)
    snapped = np.unique(edges // rq * rq)
    snapped = snapped[snapped >= lo]
    if max_width is not None:
        snapped = snapped[snapped <= max_width]
    if snapped.size == 0:  # every edge below one realizable quantum
        snapped = np.array([lo], dtype=np.int64)
    return snapped.astype(np.int64)


def profile_candidates(
    widths: Sequence[int],
    utilization: Sequence[float],
    throughput: Sequence[float],
    top_per_wave: int = 1,
) -> np.ndarray:
    """Paper Eq. 4 on a profiled table: argmax(U x T) within each stair.

    Stairs are segmented by strictly-increasing throughput runs: within one
    wave, throughput rises monotonically with width (same latency, more
    useful FLOPs) and drops when a new wave starts.  The argmax of U*T in
    each segment is the stair's right edge.
    """
    w = np.asarray(widths)
    score = np.asarray(utilization, dtype=np.float64) * np.asarray(
        throughput, dtype=np.float64
    )
    if w.size == 0:
        return np.array([], dtype=np.int64)

    # Segment boundaries: where the score drops (a new, mostly-idle wave).
    # Vectorized: one comparison over the diff'd table instead of a Python
    # scan per point.
    drops = np.flatnonzero(score[1:] < score[:-1] * (1 - 1e-9)) + 1
    seg_starts = [0] + drops.tolist() + [len(w)]

    out: list[int] = []
    prev_best = -np.inf
    segs = list(zip(seg_starts[:-1], seg_starts[1:]))
    for si, (a, b) in enumerate(segs):
        best = float(score[a:b].max())
        # A trailing segment that never recovers the previous wave's best
        # score is an incomplete wave (the sweep ended mid-stair): its
        # "edge" is an artifact of where sampling stopped, not a candidate.
        if si == len(segs) - 1 and si > 0 and best < prev_best:
            break
        seg = np.argsort(score[a:b])[::-1][:top_per_wave]
        out.extend(int(w[a + i]) for i in seg)
        prev_best = best
    return np.array(sorted(set(out)), dtype=np.int64)


def model_profile_candidates(
    table: ModelStairTable,
    top_per_wave: int = 1,
) -> list[np.ndarray]:
    """Paper Eq. 4 over a whole model's stacked sweep at once.

    One ``evaluate_model_batch`` table in, one candidate vector per layer
    out — each row identical to running ``profile_candidates`` on that
    layer's own sweep.  This is the model-level front half of the paper's
    pre-analysis: stacked sweep -> per-layer candidate sets -> Algorithm 2.
    """
    out = []
    for i in range(len(table)):
        t = table.layer_table(i)
        out.append(profile_candidates(t.widths, t.utilization,
                                      t.throughput,
                                      top_per_wave=top_per_wave))
    return out


def snap_down(candidates: np.ndarray, width: int) -> int | None:
    """Paper Eq. 8a: max candidate strictly below ``width`` (scale down).

    ``candidates`` must be sorted ascending (both generators return sorted
    arrays); the snap is then one binary search, not a mask scan.
    """
    i = int(np.searchsorted(candidates, width, side="left"))
    return int(candidates[i - 1]) if i > 0 else None


def snap_up(candidates: np.ndarray, width: int) -> int | None:
    """Paper Eq. 8b: min candidate strictly above ``width`` (scale up).

    ``candidates`` must be sorted ascending.
    """
    i = int(np.searchsorted(candidates, width, side="right"))
    return int(candidates[i]) if i < len(candidates) else None


def snap_nearest(candidates: np.ndarray, width: int) -> int:
    """Nearest candidate (used by pruning-space discretization, section 4.4)."""
    idx = int(np.argmin(np.abs(candidates - width)))
    return int(candidates[idx])


def kernel_tail_free(hw, tokens: int, d_in: int, width: int, *,
                     dtype_bits: int = 16, cache=None) -> bool:
    """True when the autotuned matmul grid for a (tokens x d_in) @ (d_in
    x width) projection lands on a full-wave boundary (paper Eq. 3: no
    partial wave, no padded tail).  This is the *kernel-level* tail
    check — the staircase model scores the layer, this scores the tile
    grid the layer would actually run on — and is what
    ``ServingWidthPlanner``/``DegradationLadder`` use to prefer widths
    whose executables waste no wave.  Memoized per (hw, shape) by the
    autotuner."""
    from repro.kernels.autotune import autotune_matmul
    cfg = autotune_matmul(hw, int(tokens), int(width), int(d_in),
                          dtype_bits=dtype_bits, cache=cache)
    return bool(cfg.tail_free)
