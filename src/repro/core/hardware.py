"""Hardware specifications for the wave-quantization (tail-effect) model.

The paper parameterizes its latency model by the GPU's SM count ``S``
(Titan-V: 80, P6000: 30, Jetson Nano: 1).  On TPU the scheduling granule is
not an SM wave but a *tile*: the MXU consumes 128x128 systolic tiles, the VPU
operates on (sublane x lane) = (8, 128) fp32 / (16, 128) bf16 registers, and a
mesh axis of size ``n`` quantizes a sharded dimension to ``ceil(d / n)`` per
device.  ``HardwareSpec`` carries everything the tail model and the roofline
need, so the same optimizer runs unchanged across platforms (paper Tables 4/5:
"no one-fit-all DNN configuration exists even for the same model running on
different GPU platforms").
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip TPU constants used by the tail model and roofline."""

    name: str
    # Roofline terms (per chip).
    peak_flops_bf16: float        # FLOP/s
    hbm_bandwidth: float          # bytes/s
    ici_bandwidth_per_link: float  # bytes/s, one direction per link
    ici_links: int                # links per chip participating in a ring
    hbm_bytes: int                # HBM capacity per chip
    vmem_bytes: int               # VMEM (fast scratch) per core

    # Quantization granules (the TPU analogue of the paper's SM count S).
    mxu_dim: int = 128            # systolic array is mxu_dim x mxu_dim
    lane: int = 128               # last-dim vector register quantum
    sublane_fp32: int = 8         # second-to-last-dim quantum, fp32
    sublane_bf16: int = 16        # second-to-last-dim quantum, bf16
    cores_per_chip: int = 1       # TensorCores (v4 megacore fuses 2 -> 1 logical)

    def sublane(self, dtype_bits: int) -> int:
        return self.sublane_fp32 if dtype_bits >= 32 else self.sublane_bf16

    @property
    def ici_bandwidth(self) -> float:
        """Aggregate ICI bytes/s per chip (all links)."""
        return self.ici_bandwidth_per_link * self.ici_links


# Graded target platform (constants fixed by the assignment brief).
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth_per_link=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

# Additional platforms for the generality study (paper Tables 4/5 analogue).
TPU_V4 = HardwareSpec(
    name="tpu_v4",
    peak_flops_bf16=275e12,
    hbm_bandwidth=1228e9,
    ici_bandwidth_per_link=50e9,
    ici_links=6,
    hbm_bytes=32 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

TPU_V5P = HardwareSpec(
    name="tpu_v5p",
    peak_flops_bf16=459e12,
    hbm_bandwidth=2765e9,
    ici_bandwidth_per_link=100e9,
    ici_links=6,
    hbm_bytes=95 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

# A deliberately small "embedded-class" spec, mirroring the paper's Jetson
# Nano row: one skinny core, to show the optimizer adapts the quantum.
TPU_LITE = HardwareSpec(
    name="tpu_lite",
    peak_flops_bf16=10e12,
    hbm_bandwidth=100e9,
    ici_bandwidth_per_link=0.0,
    ici_links=0,
    hbm_bytes=4 * 1024**3,
    vmem_bytes=32 * 1024**2,
)

REGISTRY: Dict[str, HardwareSpec] = {
    s.name: s for s in (TPU_V5E, TPU_V4, TPU_V5P, TPU_LITE)
}


def get_hardware(name: str) -> HardwareSpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware {name!r}; available: {sorted(REGISTRY)}"
        ) from None
