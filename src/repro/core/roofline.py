"""Three-term roofline from dry-run artifacts.

    compute term    = HLO_FLOPs     / (chips x peak_FLOP/s)
    memory term     = HLO_bytes     / (chips x HBM_bw)
    collective term = coll_bytes    / (chips x link_bw)

``cost_analysis()`` on a post-SPMD-partitioned executable reports the
*per-device* program, so per-device terms divide by per-chip peaks directly;
we report totals as per-device x chips so both conventions agree.
"""

from __future__ import annotations

import dataclasses
import json

from repro.core.hardware import HardwareSpec
from repro.core.hlo_analysis import CollectiveSummary


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities from the partitioned module
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_operand_bytes: float     # prompt accounting: sum of operands
    collective_ring_bytes: float        # ring-schedule traffic estimate
    model_flops_total: float            # 6*N*D (dense) / 6*N_active*D (MoE)
    hw: HardwareSpec
    collectives_by_kind: dict | None = None
    memory_per_device_bytes: float | None = None

    # ---- terms (seconds) -------------------------------------------------
    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_device / self.hw.peak_flops_bf16

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_device / self.hw.hbm_bandwidth

    @property
    def collective_s(self) -> float:
        if self.hw.ici_bandwidth == 0:
            return 0.0
        return self.collective_ring_bytes / self.hw.ici_bandwidth

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline step time: the max term (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — padding/remat/redundancy waste."""
        total_hlo = self.hlo_flops_per_device * self.chips
        if total_hlo == 0:
            return 0.0
        return self.model_flops_total / total_hlo

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOPs rate vs peak, at the modeled bound time.

        = (model_flops / bound_s) / (chips * peak) — the MFU the machine
        would achieve if it runs exactly at the dominant roofline term.
        """
        if self.bound_s == 0:
            return 0.0
        ach = self.model_flops_total / self.bound_s
        return ach / (self.chips * self.hw.peak_flops_bf16)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_operand_bytes": self.collective_operand_bytes,
            "collective_ring_bytes": self.collective_ring_bytes,
            "model_flops_total": self.model_flops_total,
            "memory_per_device_bytes": self.memory_per_device_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "bound_s": self.bound_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "hw": self.hw.name,
            "collectives_by_kind": self.collectives_by_kind,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    def row(self) -> str:
        return (
            f"{self.arch:>26} {self.shape:>12} {self.mesh:>10} "
            f"C={self.compute_s:9.3e}s M={self.memory_s:9.3e}s "
            f"X={self.collective_s:9.3e}s dom={self.dominant:<10} "
            f"useful={self.useful_flops_fraction:6.3f} "
            f"roofline_frac={self.roofline_fraction:6.3f}"
        )


def build_report(*, arch: str, shape: str, mesh: str, chips: int,
                 cost: dict, collectives: CollectiveSummary,
                 model_flops_total: float, hw: HardwareSpec,
                 memory_per_device_bytes: float | None = None
                 ) -> RooflineReport:
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops_per_device=cost.get("flops", 0.0),
        hlo_bytes_per_device=cost.get("bytes_accessed", 0.0),
        collective_operand_bytes=float(collectives.total_operand_bytes),
        collective_ring_bytes=float(collectives.total_ring_traffic_bytes),
        model_flops_total=model_flops_total,
        hw=hw,
        collectives_by_kind=collectives.by_kind(),
        memory_per_device_bytes=memory_per_device_bytes,
    )
