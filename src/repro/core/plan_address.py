"""Plan -> pytree addressing: where a planned width lands in a model.

The optimizer and the serving planner speak in flat layer *names*
("mlp3", "attn0") with integer widths; a real model is a nested param
pytree whose layers live at structured addresses (stacked scan units,
unrolled leftovers).  This module is the shared vocabulary between the
two worlds:

  * ``ModuleRef`` — the address of one width-adjustable module: the
    decoder layer index plus the site within the layer ("mlp" slices the
    FFN hidden dim, "attn" slices attention heads).
  * ``snap_heads`` — attention widths are planned in channels
    (heads x head_dim) on the staircase grid, but can only be realized
    as whole heads, in multiples of the GQA group size (every kept query
    head must keep its KV head).  This snap is the one place the
    modeled grid and the realizable grid disagree.
  * ``plan_key`` — the canonical hashable identity of a width
    assignment, used to key materialized-param caches: two plans that
    realize the same widths share one sliced pytree.

``repro.serving.width_swap`` materializes these addresses onto real
params; keeping the vocabulary here (core) lets profilers and future
backends address plans without importing the serving stack.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

# Sites a ModuleRef can point at.  "mlp" adjusts the FFN hidden width
# (w_up/w_gate columns, w_down rows); "attn" adjusts the attention width
# in head-channels (query heads, with KV heads following the GQA ratio).
MODULE_SITES = ("mlp", "attn")


@dataclasses.dataclass(frozen=True)
class ModuleRef:
    """Address of one width-adjustable module inside a decoder stack."""

    layer: int      # decoder layer index (0-based, pre-stacking order)
    site: str       # one of MODULE_SITES

    def __post_init__(self):
        if self.site not in MODULE_SITES:
            raise ValueError(
                f"unknown module site {self.site!r}; expected one of "
                f"{MODULE_SITES}")
        if self.layer < 0:
            raise ValueError(f"negative layer index {self.layer}")


def snap_heads(width: int, head_dim: int, n_heads: int,
               n_kv_heads: int) -> int:
    """Realizable query-head count for a planned attention width.

    ``width`` is in channels (the staircase axis: heads x head_dim).
    Rounds down to whole heads, then down to a multiple of the GQA group
    size g = n_heads // n_kv_heads so kept query heads map onto a prefix
    of KV heads; clamped to [g, n_heads] (at least one KV head's group
    always survives — a zero-head attention layer is not a width config,
    it is layer removal, which Algorithm 2 never proposes).
    """
    if n_heads % max(n_kv_heads, 1):
        raise ValueError(
            f"n_heads={n_heads} not divisible by n_kv_heads={n_kv_heads}")
    g = n_heads // max(n_kv_heads, 1)
    heads = (int(width) // max(head_dim, 1)) // g * g
    return max(g, min(heads, n_heads))


def plan_key(widths: Mapping[str, int]) -> tuple:
    """Canonical hashable identity of a width assignment."""
    return tuple(sorted((str(k), int(v)) for k, v in widths.items()))
