"""Structured filter-pruning baselines the paper compares against.

* HRank (Lin et al., CVPR'20): rank filters by the average matrix rank of
  their output feature maps on a probe batch; prune lowest-rank filters.
* SOFT / Soft Filter Pruning (He et al., IJCAI'18): rank filters by L2 norm;
  during training, zero the weakest filters each epoch but keep updating
  them (soft), hard-prune at the end.

Both produce *continuous* per-layer width targets; the paper's section 4.4
enhancement replaces those with the tail-free discrete candidate widths
(``discretize_pruning_space``) — same criteria, wave-aligned widths.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def feature_map_rank_scores(acts: jax.Array, tol: float | None = None
                            ) -> np.ndarray:
    """HRank criterion: per-channel mean matrix rank of feature maps.

    ``acts``: (batch, H, W, C) activations from a probe batch.
    Returns (C,) scores — higher rank = more informative = keep.
    """
    acts = jnp.asarray(acts, jnp.float32)
    b, h, w, c = acts.shape
    maps = jnp.transpose(acts, (0, 3, 1, 2)).reshape(b * c, h, w)
    sv = jnp.linalg.svd(maps, compute_uv=False)          # (b*c, min(h,w))
    if tol is None:
        tol = float(max(h, w)) * jnp.finfo(jnp.float32).eps
    thresh = sv[:, :1] * tol
    ranks = jnp.sum(sv > thresh, axis=-1).reshape(b, c)
    return np.asarray(jnp.mean(ranks.astype(jnp.float32), axis=0))


def l2_filter_scores(kernel: jax.Array) -> np.ndarray:
    """SOFT criterion: L2 norm per output filter.

    ``kernel``: (kh, kw, cin, cout) conv kernel or (din, dout) dense kernel.
    """
    k = jnp.asarray(kernel, jnp.float32)
    flat = k.reshape(-1, k.shape[-1])
    return np.asarray(jnp.linalg.norm(flat, axis=0))


def keep_indices(scores: np.ndarray, keep: int) -> np.ndarray:
    """Indices of the ``keep`` highest-scoring filters, in original order."""
    keep = int(max(1, min(keep, len(scores))))
    idx = np.argsort(scores)[::-1][:keep]
    return np.sort(idx)


def soft_prune_mask(scores: np.ndarray, keep: int) -> np.ndarray:
    """SOFT's in-training mask: 1 for kept filters, 0 for softly-pruned."""
    mask = np.zeros(len(scores), dtype=np.float32)
    mask[keep_indices(scores, keep)] = 1.0
    return mask


@dataclasses.dataclass
class PrunePlan:
    """Per-layer width plan: layer name -> (keep_width, filter indices)."""
    widths: dict[str, int]
    indices: dict[str, np.ndarray]

    @property
    def total_width(self) -> int:
        return sum(self.widths.values())


def uniform_flops_plan(base_widths: dict[str, int], ratio: float
                       ) -> dict[str, int]:
    """The naive baseline: prune every layer's width by the same ratio —
    the 'FLOPs reduction as the objective' strategy the paper critiques."""
    return {k: max(1, int(round(v * ratio))) for k, v in base_widths.items()}


def build_plan(score_fn: Callable[[str], np.ndarray],
               target_widths: dict[str, int]) -> PrunePlan:
    idx = {name: keep_indices(score_fn(name), w)
           for name, w in target_widths.items()}
    widths = {name: len(v) for name, v in idx.items()}
    return PrunePlan(widths=widths, indices=idx)
