"""Frozen scalar reference paths — the seed implementation, kept verbatim.

The table-driven engine in ``tail_model``/``tail_optimizer`` replaced a
scalar hot path: per-width ``evaluate()`` calls inside Python loops, sorted
lists popped from both ends, and O(layers) parameter rescans.  This module
preserves that seed implementation unchanged, for two purposes only:

  * ground truth for the batched-vs-scalar equivalence tests
    (tests/test_batched_equivalence.py): ``scalar_evaluate`` must match
    ``WaveQuantizationModel.evaluate_batch`` bit-for-bit, and
    ``ScalarTailEffectOptimizer`` must return the same widths/moves as the
    table-driven ``TailEffectOptimizer``;
  * the "before" side of ``benchmarks/optimizer_scale.py``, so the speedup
    of the table-driven engine stays measured, not asserted.

Do not "optimize" this file — its value is being the slow, known-good path.

One deliberate deviation from the seed: ``_one_latency_round``'s revert
used to pop the *last* Move, which could be a balancing up-move rather
than the down-move being reverted, so ``OptimizationResult.moves`` could
disagree with ``new_widths``.  Both this reference and the table-driven
path now delete the down-Move itself (coordinated behavior change; the
replay-consistency test in tests/test_batched_equivalence.py pins it).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.tail_model import LayerShape, StairPoint, ceil_div
from repro.core.tail_optimizer import Move, OptimizationResult, TunableLayer


def _snap_down(candidates: np.ndarray, width: int) -> int | None:
    below = candidates[candidates < width]
    return int(below.max()) if below.size else None


def _snap_up(candidates: np.ndarray, width: int) -> int | None:
    above = candidates[candidates > width]
    return int(above.min()) if above.size else None


class ScalarWaveModel:
    """Seed ``WaveQuantizationModel``: one width per ``evaluate`` call."""

    def __init__(self, hw: HardwareSpec):
        self.hw = hw
        self.eval_calls = 0
        self.eval_points = 0

    def width_quantum(self, shard_out: int) -> int:
        return shard_out * self.hw.lane

    def padded_dim(self, d: int, shard: int, tile: int) -> int:
        per_dev = ceil_div(d, shard)
        return ceil_div(per_dev, tile) * tile

    def waves(self, layer: LayerShape) -> int:
        per_dev = ceil_div(layer.width, layer.shard_out)
        return ceil_div(per_dev, self.hw.lane)

    def evaluate(self, layer: LayerShape) -> StairPoint:
        hw = self.hw
        self.eval_calls += 1
        self.eval_points += 1
        sub = hw.sublane(layer.dtype_bits)
        m_pad = ceil_div(layer.tokens, sub) * sub
        k_pad = self.padded_dim(layer.d_in, layer.shard_in, hw.lane)
        n_waves = self.waves(layer)
        n_pad = n_waves * hw.lane

        useful = 2.0 * layer.tokens * layer.d_in * layer.width \
            * layer.flop_multiplier
        padded_per_dev = 2.0 * m_pad * k_pad * n_pad * layer.flop_multiplier
        padded_total = padded_per_dev * layer.shard_in * layer.shard_out

        compute_s = padded_per_dev / hw.peak_flops_bf16
        bytes_per_dev = (
            m_pad * k_pad + k_pad * n_pad + m_pad * n_pad
        ) * layer.dtype_bits // 8
        memory_s = bytes_per_dev / hw.hbm_bandwidth
        latency = max(compute_s, memory_s)

        util = useful / padded_total if padded_total else 0.0
        return StairPoint(
            width=layer.width,
            latency_s=latency,
            utilization=util,
            throughput=useful / latency if latency else 0.0,
            waves=n_waves,
            flops=useful,
            padded_flops=padded_total,
        )


def scalar_evaluate(hw: HardwareSpec, layer: LayerShape) -> StairPoint:
    """Seed scalar staircase evaluation for one layer at ``layer.width``."""
    return ScalarWaveModel(hw).evaluate(layer)


class ScalarTailEffectOptimizer:
    """Seed Algorithm 2: sorted-list queues, O(layers) ``pg_total`` rescans,
    per-move re-ranking in accuracy pass 2 — every latency read is a fresh
    ``evaluate`` call."""

    def __init__(self, model: ScalarWaveModel):
        self.model = model

    # ---- helpers ---------------------------------------------------------
    def _latency(self, tl: TunableLayer, width: int) -> float:
        return self.model.evaluate(tl.layer.with_width(width)).latency_s

    def _total_latency(self, layers: Sequence[TunableLayer],
                       widths: dict[str, int]) -> float:
        return sum(self._latency(tl, widths[tl.layer.name]) for tl in layers)

    def _total_params(self, layers: Sequence[TunableLayer],
                      widths: dict[str, int]) -> float:
        return sum(tl.params(widths[tl.layer.name]) for tl in layers)

    def _down(self, tl: TunableLayer, width: int) -> int | None:
        w = _snap_down(tl.candidates, width)
        if w is not None and w < tl.min_width:
            return None
        return w

    def _up(self, tl: TunableLayer, width: int) -> int | None:
        w = _snap_up(tl.candidates, width)
        if w is not None and tl.max_width is not None and w > tl.max_width:
            return None
        return w

    # ---- latency-oriented (Eq. 7, Algorithm 2) ----------------------------
    def optimize_latency(
        self,
        layers: Sequence[TunableLayer],
        tau: float,
        delta: float = 0.9,
        max_rounds: int = 8,
    ) -> OptimizationResult:
        old_widths = {tl.layer.name: tl.layer.width for tl in layers}
        l_old = self._total_latency(layers, old_widths)
        p_old = self._total_params(layers, old_widths)

        best: OptimizationResult | None = None
        cur_tau = tau
        for _ in range(max_rounds):
            res = self._one_latency_round(layers, old_widths, l_old, p_old,
                                          cur_tau, delta)
            if best is None or res.latency_new_s < best.latency_new_s:
                best = res
            if res.satisfied:
                return res
            cur_tau *= 2.0
        assert best is not None
        return best

    def _one_latency_round(self, layers, old_widths, l_old, p_old, tau,
                           delta) -> OptimizationResult:
        widths = dict(old_widths)
        moves: list[Move] = []

        lg: dict[str, float] = {}
        for tl in layers:
            name = tl.layer.name
            down = self._down(tl, widths[name])
            lg[name] = (self._latency(tl, widths[name])
                        - self._latency(tl, down)) if down is not None else 0.0

        by_name = {tl.layer.name: tl for tl in layers}
        queue = sorted(lg, key=lambda n: lg[n], reverse=True)

        def pg_total() -> float:
            return (self._total_params(layers, widths) - p_old)

        while queue:
            j = queue.pop(0)
            tl = by_name[j]
            down = self._down(tl, widths[j])
            applied_down = False
            old_w = widths[j]
            down_move_at = len(moves)
            if down is not None and lg[j] > 0:
                gain = self._latency(tl, widths[j]) - self._latency(tl, down)
                dp = tl.params(down) - tl.params(widths[j])
                moves.append(Move(j, "down", widths[j], down, gain, dp))
                widths[j] = down
                applied_down = True

            while queue and not (-tau < pg_total() < tau):
                k = queue.pop(-1)
                tk = by_name[k]
                up = self._up(tk, widths[k])
                if up is None:
                    continue
                dp = tk.params(up) - tk.params(widths[k])
                if abs(pg_total() + dp) >= abs(pg_total()):
                    continue
                extra = self._latency(tk, up) - self._latency(tk, widths[k])
                moves.append(Move(k, "up", widths[k], up, -extra, dp))
                widths[k] = up

            # Revert removes the down-Move itself (up-moves appended after
            # it stay applied).  The seed popped the LAST move here, which
            # could be a balancing up-move, leaving ``moves`` inconsistent
            # with ``new_widths``; fixed in lockstep with the table-driven
            # path (the one deliberate deviation from the seed — see the
            # module docstring).
            if applied_down and not (-tau < pg_total() < tau):
                widths[j] = old_w
                del moves[down_move_at]

        l_new = self._total_latency(layers, widths)
        return OptimizationResult(
            old_widths=dict(old_widths), new_widths=widths,
            latency_old_s=l_old, latency_new_s=l_new,
            params_old=p_old, params_new=self._total_params(layers, widths),
            moves=moves, tau_final=tau,
            satisfied=l_new <= l_old * delta,
        )

    # ---- accuracy-oriented (Eq. 6) ----------------------------------------
    def optimize_accuracy(
        self,
        layers: Sequence[TunableLayer],
        latency_slack: float = 0.0,
    ) -> OptimizationResult:
        old_widths = {tl.layer.name: tl.layer.width for tl in layers}
        l_old = self._total_latency(layers, old_widths)
        p_old = self._total_params(layers, old_widths)
        budget = latency_slack * l_old

        widths = dict(old_widths)
        moves: list[Move] = []
        for tl in layers:
            name = tl.layer.name
            up = self._up(tl, widths[name])
            if up is None:
                continue
            extra = self._latency(tl, up) - self._latency(tl, widths[name])
            if extra <= 1e-15:
                dp = tl.params(up) - tl.params(widths[name])
                moves.append(Move(name, "up", widths[name], up, -extra, dp))
                widths[name] = up

        improved = True
        while improved and budget > 0:
            improved = False
            ranked: list[tuple[float, TunableLayer, int, float]] = []
            for tl in layers:
                name = tl.layer.name
                up = self._up(tl, widths[name])
                if up is None:
                    continue
                extra = self._latency(tl, up) - self._latency(tl, widths[name])
                dp = tl.params(up) - tl.params(widths[name])
                if extra <= budget and dp > 0:
                    ranked.append((dp / max(extra, 1e-15), tl, up, extra))
            if ranked:
                ranked.sort(key=lambda t: t[0], reverse=True)
                _, tl, up, extra = ranked[0]
                name = tl.layer.name
                dp = tl.params(up) - tl.params(widths[name])
                moves.append(Move(name, "up", widths[name], up, -extra, dp))
                widths[name] = up
                budget -= extra
                improved = True

        l_new = self._total_latency(layers, widths)
        return OptimizationResult(
            old_widths=old_widths, new_widths=widths,
            latency_old_s=l_old, latency_new_s=l_new,
            params_old=p_old, params_new=self._total_params(layers, widths),
            moves=moves, tau_final=0.0,
            satisfied=l_new <= l_old * (1 + latency_slack) + 1e-12,
        )
