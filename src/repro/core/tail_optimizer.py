"""GPU(-analogue)-aware model configuration optimization — paper Algorithm 2.

Two duals, exactly as in the paper section 4.3:

  * latency-oriented (Eq. 7):  maximize sum LG_i  s.t.  sum PG_i in (-tau, tau)
  * accuracy-oriented (Eq. 6): maximize sum PG_i  s.t.  sum LG_i >= 0

where per layer i (Eq. 5):  LG_i = L_i[R_old] - L_i[R_new]   (latency gain)
                            PG_i = params(R_new) - params(R_old)  (param gain)

The mechanics follow Algorithm 2: identify per-layer candidates C_i[m]
(Eq. 4, see candidates.py), keep two queues ranked by LG, greedily pop the
max-LG layer to *scale down* (Eq. 8a) and balance the parameter budget by
popping min-LG layers to *scale up* (Eq. 8b); after all layers are adjusted,
check L_new <= delta * L_old and loosen tau if the target is missed
(Algorithm 2 line 18).

Table-driven hot path
---------------------
This is the paper's own split: "Step 1: pre-analysis" builds per-layer
L/U/T tables, Algorithm 2 then only *reads* them.  Per ``optimize_*`` call
we precompute per-layer candidate tables with vectorized
``WaveQuantizationModel.latency_batch`` sweeps (latency per candidate plus
the starting width; params are an exact scalar multiply) — after that the
greedy loops are pure table lookups:

  * sweeps are batched across layers that share a ``LayerShape`` (all
    fields but width) and chunked to stay cache-resident; latency mode
    sweeps only each layer's reachable one-step probes (Alg. 2 moves a
    layer at most one candidate per round), accuracy mode with slack
    sweeps the full table for its wave-jump walk;
  * candidate navigation is index ±1 on the sorted-unique width table
    (Eq. 8a/8b snaps; the only binary searches happen once at build);
  * the two LG-ranked queues are binary heaps with lazy deletion, keyed on
    the precomputed LG and tie-broken by layer position so the pop order is
    identical to the historical sorted-list ``pop(0)``/``pop(-1)``, and the
    queues plus the per-layer LG estimates are hoisted out of the
    tau-loosening rounds (only tau changes between rounds);
  * the Eq. 7 window check keeps PG as an O(1) running sum instead of an
    O(layers) parameter rescan per move;
  * accuracy pass 2 keeps each layer's next wave-jump in a max-heap on
    PG/LG and re-pushes only the moved layer, instead of re-ranking every
    layer per accepted move.  (Entries are discarded permanently when they
    fail the budget filter — the budget only shrinks, so they can never
    become valid again.)

Model-level stacked sweeps and the profile-table cache
------------------------------------------------------
``_build_tables`` resolves each layer's latency vector from three sources,
cheapest first:

  1. a **measured profile** attached to the ``TunableLayer`` (``measured``;
     see ``tunable_from_profile``) — the optimizer only reads latency and
     params arrays, so Algorithm 2 runs unmodified over profiled hardware
     tables (the paper's original nvprof flow);
  2. the **disk cache** (``repro.core.table_cache.ProfileTableCache``,
     passed to the constructor): repeated ``optimize_*`` calls across
     processes skip the pre-analysis entirely (a fully warm cache makes
     zero model sweeps);
  3. one **stacked model sweep** for every remaining layer at once
     (``WaveQuantizationModel.latency_model_batch``): all layers x all
     sweep widths in a single chunked NumPy call instead of one dispatch
     per layer-shape group.  ``stacked=False`` keeps the historical
     per-group loop (bit-identical output) as the parity/benchmark
     baseline.

The seed scalar implementation is frozen in ``repro.core.scalar_ref`` and
``tests/test_batched_equivalence.py`` asserts both paths return identical
widths and moves; ``benchmarks/optimizer_scale.py`` measures the speedup
(tens of times faster on optimize_latency, hundreds on optimize_accuracy,
for a 64-layer x 1024-candidate scenario, plus the stacked table-build and
cold/warm cache phases on a 1024-layer scenario).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core import candidates as cand
from repro.core.tail_model import LayerShape, WaveQuantizationModel, ceil_div

if TYPE_CHECKING:  # import cycle: profiler imports tail_model
    from repro.core.profiler import LayerProfile
    from repro.core.table_cache import ProfileTableCache

# Max widths per evaluate_batch sweep: keeps the ~15 elementwise passes of
# the staircase math inside L2 (4096 widths x 8 B x a few temporaries);
# larger single sweeps go memory-bound and cost >5x more per point.
_SWEEP_CHUNK = 4096


@dataclasses.dataclass
class TunableLayer:
    """One width-adjustable layer handed to the optimizer.

    ``candidates`` is normalized to a sorted-unique int64 array at
    construction (snaps are set-based, so this is behavior-preserving);
    the optimizer's binary searches rely on it.

    ``measured`` optionally attaches a profiled (width, latency) table —
    any object with ``widths`` and ``latency_s`` parallel arrays, e.g.
    ``profiler.LayerProfile``.  When set, ``_build_tables`` reads every
    latency it needs from the table instead of sweeping the analytic
    model, so Algorithm 2 optimizes over measured hardware data; the
    table must cover every candidate width plus the starting width.
    """

    layer: LayerShape
    candidates: np.ndarray
    # parameters contributed per unit of width (e.g. d_in for a dense layer,
    # d_in + d_out for a conv filter that also feeds the next layer's input).
    params_per_unit: float
    min_width: int = 1
    max_width: int | None = None
    measured: "LayerProfile | None" = None

    def __post_init__(self):
        c = np.asarray(self.candidates, dtype=np.int64)
        if c.size > 1 and not np.all(c[:-1] < c[1:]):
            c = np.unique(c)
        self.candidates = c

    def params(self, width: int) -> float:
        return self.params_per_unit * width


def tunable_from_profile(
    layer: LayerShape,
    profile: "LayerProfile",
    params_per_unit: float,
    *,
    min_width: int = 1,
    max_width: int | None = None,
    top_per_wave: int = 1,
) -> TunableLayer:
    """Build a TunableLayer entirely from a measured profile table.

    Candidates come from paper Eq. 4 (argmax U x T per stair) on the
    profiled utilization/throughput columns, and ``measured`` wires the
    profiled latencies into ``_build_tables`` — so the optimizer runs on
    hardware we have no closed form for (the paper's nvprof flow).
    ``layer.width`` (the starting width) must appear in the profile.
    """
    cands = cand.profile_candidates(
        profile.widths, profile.utilization, profile.throughput,
        top_per_wave=top_per_wave)
    return TunableLayer(layer=layer, candidates=cands,
                        params_per_unit=params_per_unit,
                        min_width=min_width, max_width=max_width,
                        measured=profile)


def _measured_latencies(tl: TunableLayer, widths: np.ndarray) -> np.ndarray:
    """Latencies for ``widths`` read out of ``tl.measured``; raises when
    the profile does not cover a requested width."""
    prof = tl.measured
    pw = np.asarray(prof.widths, dtype=np.int64)
    order = np.argsort(pw, kind="stable")
    sorted_w = pw[order]
    idx = np.searchsorted(sorted_w, widths)
    clipped = np.minimum(idx, sorted_w.size - 1) if sorted_w.size else idx
    ok = sorted_w.size > 0 and bool(
        ((idx < sorted_w.size) & (sorted_w[clipped] == widths)).all())
    if not ok:
        have = set(int(x) for x in sorted_w)
        missing = sorted(int(x) for x in widths if int(x) not in have)
        raise ValueError(
            f"measured profile for layer {tl.layer.name!r} is missing "
            f"widths {missing}; profile covers {sorted_w.size} widths")
    lat = np.asarray(prof.latency_s, dtype=np.float64)[order]
    return lat[idx]


@dataclasses.dataclass
class Move:
    layer: str
    kind: str          # "down" | "up"
    old_width: int
    new_width: int
    latency_gain_s: float
    param_gain: float


@dataclasses.dataclass
class OptimizationResult:
    old_widths: dict[str, int]
    new_widths: dict[str, int]
    latency_old_s: float
    latency_new_s: float
    params_old: float
    params_new: float
    moves: list[Move]
    tau_final: float
    satisfied: bool

    @property
    def latency_reduction(self) -> float:
        if self.latency_old_s == 0:
            return 0.0
        return 1.0 - self.latency_new_s / self.latency_old_s

    @property
    def param_gain(self) -> float:
        return self.params_new - self.params_old

    def summary(self) -> str:
        lines = [
            f"latency: {self.latency_old_s * 1e6:.2f}us -> "
            f"{self.latency_new_s * 1e6:.2f}us "
            f"({self.latency_reduction * 100:+.1f}% reduction)",
            f"params:  {self.params_old / 1e6:.3f}M -> "
            f"{self.params_new / 1e6:.3f}M ({self.param_gain / 1e6:+.3f}M)",
            f"tau_final={self.tau_final:.3g} satisfied={self.satisfied}",
        ]
        for m in self.moves:
            lines.append(
                f"  [{m.kind:>4}] {m.layer}: {m.old_width} -> {m.new_width} "
                f"(LG {m.latency_gain_s * 1e6:+.2f}us, PG {m.param_gain:+.0f})"
            )
        return "\n".join(lines)


@dataclasses.dataclass(slots=True)
class _LayerTable:
    """Precomputed candidate table for one tunable layer (Step 1 output).

    Candidates are sorted and de-duplicated, so Eq. 8a/8b snaps from a
    candidate are just index ±1; the only binary searches happen once at
    build time (the starting width and the min/max-width fences).
    ``slots=True``: one instance per layer per build, so construction cost
    shows up directly in the stacked table-build wall time.
    """

    tl: TunableLayer
    pos: int                  # position in the ``layers`` sequence
    name: str
    cands: np.ndarray         # sorted unique candidate widths, int64
    # latency per candidate: a full float64 array (accuracy mode, whose
    # pass 2 walks many waves up) or a sparse {index: latency} dict holding
    # just the reachable one-step probes (latency mode — Alg. 2 moves each
    # layer at most one candidate from its start per round).
    lat: "np.ndarray | dict[int, float]"
    lo: int                   # first index with cands[i] >= min_width
    hi: int                   # last index with cands[i] <= max_width
    start_width: int
    start_lat: float
    start_par: float
    start_down: int           # index of max candidate < start_width, or -1
    start_up: int             # index of min candidate > start_width, or n

    def par_at(self, idx: int) -> float:
        # identical to the historical params(width): one exact scalar
        # multiply, so no per-candidate params array is materialized
        return self.tl.params(int(self.cands[idx]))

    def down_from(self, idx: int) -> int | None:
        """Eq. 8a: next candidate index below cursor (-1 = at start)."""
        i = self.start_down if idx < 0 else idx - 1
        return i if i >= self.lo else None

    def up_from(self, idx: int) -> int | None:
        """Eq. 8b: next candidate index above cursor (-1 = at start)."""
        i = self.start_up if idx < 0 else idx + 1
        return i if i <= self.hi else None


class _LayerState:
    """Mutable per-round cursor over a _LayerTable.  ``idx`` is the current
    candidate index, or -1 while still at the (possibly off-table) starting
    width."""

    __slots__ = ("table", "idx", "width", "lat", "par")

    def __init__(self, table: _LayerTable):
        self.table = table
        self.idx = -1
        self.width = table.start_width
        self.lat = table.start_lat
        self.par = table.start_par

    def move_to(self, idx: int) -> None:
        t = self.table
        self.idx = idx
        self.width = int(t.cands[idx])
        self.lat = float(t.lat[idx])
        self.par = t.tl.params(self.width)

    def reset(self) -> None:
        t = self.table
        self.idx = -1
        self.width, self.lat, self.par = (
            t.start_width, t.start_lat, t.start_par)

    def down(self) -> int | None:
        return self.table.down_from(self.idx)

    def up(self) -> int | None:
        return self.table.up_from(self.idx)


class TailEffectOptimizer:
    """Paper Algorithm 2 over precomputed per-layer candidate tables.

    ``cache`` (a ``table_cache.ProfileTableCache``) persists the swept
    tables on disk keyed on (hardware, shape-minus-width, width vector):
    a warm cache makes ``_build_tables`` skip the model entirely.
    """

    def __init__(self, model: WaveQuantizationModel,
                 cache: "ProfileTableCache | None" = None,
                 bundle_min_layers: int = 64):
        self.model = model
        self.cache = cache
        # Stacks at least this deep are cached as ONE whole-stack bundle
        # file instead of per-layer entries: above ~64 layers the per-file
        # open cost of fine-grained entries exceeds resweeping the model.
        self.bundle_min_layers = bundle_min_layers
        # Reused full-mode sweep matrix: every build rewrites every cell
        # (data, start and pad columns), so reuse is purely an allocation
        # saving — a fresh 8 MB matrix per build costs more in page
        # faults than the sweep's own arithmetic.
        self._w2d_buf: np.ndarray | None = None

    # ---- Step 1: pre-analysis -------------------------------------------
    def _build_tables(self, layers: Sequence[TunableLayer],
                      full: bool = True,
                      stacked: bool = True) -> list[_LayerTable]:
        """Per-layer candidate tables from measured / cached / swept data.

        Each layer needs latencies for one sweep vector: its candidates
        plus the starting width (``full=True``), or just the reachable
        one-step probes plus the start (``full=False``, latency mode —
        Algorithm 2's latency rounds move a layer at most one candidate
        from its start, so anything further is never read; accuracy mode
        needs the whole table for its wave-jump walk).

        The vector is resolved from the first source that has it:

          1. ``tl.measured`` — a profiled (width, latency) table;
          2. the disk cache (when this optimizer holds one): per-layer
             entries for shallow models, ONE whole-stack bundle entry for
             stacks of at least ``bundle_min_layers`` (per-layer file
             opens dominate at 1000+ layers);
          3. one stacked ``latency_model_packed`` sweep over every
             unresolved layer at once — all layers x all sweep widths in
             a single chunked NumPy call, then written back to the cache.

        ``stacked=False`` replays the historical per-shape-group engine
        verbatim (one ``latency_batch`` dispatch per group, per-layer
        Python array building — bit-identical output) as the parity-test /
        benchmark baseline; it ignores the cache and measured profiles.
        """
        if not stacked:
            return self._build_tables_grouped(layers, full)
        n_layers = len(layers)
        starts = np.fromiter((tl.layer.width for tl in layers),
                             np.int64, n_layers)
        # Cursor/fence arrays over all layers.  Layers handed the SAME
        # candidates array object (a transformer stack / NAS supernet
        # sharing one grid) are prepped in one vectorized pass per shared
        # grid — the binary searches and fence math run over the whole
        # stack at once; unshared layers fall back to the scalar path.
        sd_a = np.empty(n_layers, np.int64)
        su_a = np.empty(n_layers, np.int64)
        lo_a = np.empty(n_layers, np.int64)
        hi_a = np.empty(n_layers, np.int64)
        if full:
            # The sweep widths for ALL layers, packed into one (L, kmax)
            # matrix up front (pad width 1, masked by ``counts``): filling
            # rows is a memcpy per layer (one broadcast per shared grid),
            # where building L small arrays and re-packing them dominated
            # the whole table build.
            kmax = 1 + max((int(tl.candidates.size) for tl in layers),
                           default=0)
            # empty, not ones: each grid group fills its rows' data AND
            # pad cells exactly once below (ones would touch the whole
            # 8 MB matrix just to be overwritten)
            if self._w2d_buf is not None \
                    and self._w2d_buf.shape == (n_layers, kmax):
                w2d = self._w2d_buf
            else:
                w2d = self._w2d_buf = np.empty((n_layers, kmax),
                                               dtype=np.int64)
            counts = np.empty(n_layers, dtype=np.int64)
        else:
            # Latency mode: every row is the fixed 3-slot layout
            # [down-probe, up-probe, start]; unreachable probe slots hold
            # pad width 1 and are never read back.
            w2d = np.ones((n_layers, 3), dtype=np.int64)
            w2d[:, 2] = starts
            counts = np.full(n_layers, 3, dtype=np.int64)

        grids: dict[int, list[int]] = {}
        for pos, tl in enumerate(layers):
            grids.setdefault(id(tl.candidates), []).append(pos)
        for idxs in grids.values():
            cands = layers[idxs[0]].candidates  # sorted unique (init)
            n = int(cands.size)
            if n == 0:
                for pos in idxs:
                    sd_a[pos], su_a[pos] = -1, 0
                    lo_a[pos], hi_a[pos] = 0, -1
                    if full:
                        w2d[pos, 0] = starts[pos]
                        w2d[pos, 1:] = 1
                        counts[pos] = 1
                continue
            if len(idxs) < 4:
                # scalar path: vectorized overhead loses on tiny groups
                for pos in idxs:
                    tl = layers[pos]
                    start_w = int(starts[pos])
                    i = int(cands.searchsorted(start_w, side="left"))
                    sd = i - 1
                    su = i + 1 if (i < n and int(cands[i]) == start_w) \
                        else i
                    lo = (0 if tl.min_width <= int(cands[0]) else
                          int(cands.searchsorted(tl.min_width,
                                                 side="left")))
                    hi = (n - 1 if (tl.max_width is None
                                    or tl.max_width >= int(cands[-1])) else
                          int(cands.searchsorted(tl.max_width,
                                                 side="right")) - 1)
                    sd_a[pos], su_a[pos] = sd, su
                    lo_a[pos], hi_a[pos] = lo, hi
                    if full:
                        w2d[pos, :n] = cands
                        w2d[pos, n] = start_w
                        w2d[pos, n + 1:] = 1
                        counts[pos] = n + 1
                    else:
                        if sd >= lo:
                            w2d[pos, 0] = cands[sd]
                        if su <= hi:
                            w2d[pos, 1] = cands[su]
                continue
            pos = np.asarray(idxs)
            st = starts[pos]
            i = cands.searchsorted(st, side="left")
            sd = i - 1
            hit = (i < n) & (cands[np.minimum(i, n - 1)] == st)
            su = np.where(hit, i + 1, i)
            min_ws = np.fromiter((layers[j].min_width for j in idxs),
                                 np.int64, len(idxs))
            lo = np.where(min_ws <= int(cands[0]), 0,
                          cands.searchsorted(min_ws, side="left"))
            max_list = [layers[j].max_width for j in idxs]
            if all(m is None for m in max_list):
                hi = np.full(len(idxs), n - 1, dtype=np.int64)
            else:
                top = int(cands[-1])
                mw = np.fromiter((top if m is None else m
                                  for m in max_list), np.int64, len(idxs))
                hi = np.where(mw >= top, n - 1,
                              cands.searchsorted(mw, side="right") - 1)
            sd_a[pos], su_a[pos] = sd, su
            lo_a[pos], hi_a[pos] = lo, hi
            if full:
                w2d[pos, :n] = cands  # one broadcast per shared grid
                w2d[pos, n] = st
                w2d[pos, n + 1:] = 1
                counts[pos] = n + 1
            else:
                d_ok = sd >= lo
                u_ok = su <= hi
                w2d[pos, 0] = np.where(d_ok, cands[np.maximum(sd, 0)], 1)
                w2d[pos, 1] = np.where(u_ok, cands[np.minimum(su, n - 1)],
                                       1)

        down_ok_l = (sd_a >= lo_a).tolist()
        up_ok_l = (su_a <= hi_a).tolist()
        sd_l, su_l = sd_a.tolist(), su_a.tolist()
        lo_l, hi_l = lo_a.tolist(), hi_a.tolist()
        starts_l = starts.tolist()

        # Resolve each layer's sweep-vector latencies, cheapest source
        # first: measured profile -> disk cache -> stacked model sweep.
        # ``lat_vecs[i]`` may be a full padded row (swept) or an exact
        # ``counts[i]``-length vector (measured/cached); only indices
        # below ``counts[i]`` (and, in latency mode, only the reachable
        # probe slots) are read.
        lat_vecs: list = [None] * n_layers
        any_measured = False
        for i, tl in enumerate(layers):
            if tl.measured is not None:
                any_measured = True
                if full:
                    lat_vecs[i] = _measured_latencies(tl,
                                                      w2d[i, :counts[i]])
                else:
                    # look up only the real slots — pad slots (width 1)
                    # need not exist in the profile and are never read
                    mask = np.array([down_ok_l[i], up_ok_l[i], True])
                    vec = np.zeros(3, dtype=np.float64)
                    vec[mask] = _measured_latencies(tl, w2d[i, mask])
                    lat_vecs[i] = vec
        lat2d_all = None   # the full (L, C) sweep matrix, when one exists
        if self.cache is not None and not any_measured \
                and n_layers >= self.bundle_min_layers:
            # Deep stack: one whole-stack bundle file (per-layer entries
            # would cost one file open each — slower than resweeping).
            hw = self.model.hw
            variant = "" if getattr(self.model, "backend", "numpy") == "numpy" \
                else self.model.backend
            shapes = [tl.layer for tl in layers]
            lat2d = self.cache.get_stack(hw, shapes, w2d, counts,
                                         variant=variant)
            if lat2d is None:
                lat2d = self.model.latency_model_packed(shapes, w2d,
                                                        counts)
                self.cache.put_stack(hw, shapes, w2d, counts, lat2d,
                                     variant=variant)
            lat_vecs = list(lat2d)
            lat2d_all = lat2d
        else:
            variant = "" if getattr(self.model, "backend", "numpy") == "numpy" \
                else self.model.backend
            if self.cache is not None:
                hw = self.model.hw
                for i, tl in enumerate(layers):
                    if lat_vecs[i] is None:
                        hit = self.cache.get(hw, tl.layer,
                                             w2d[i, :counts[i]],
                                             variant=variant)
                        if hit is not None and "latency_s" in hit:
                            lat_vecs[i] = hit["latency_s"]
            miss = [i for i, v in enumerate(lat_vecs) if v is None]
            if miss:
                if len(miss) == n_layers:
                    lat2d = self.model.latency_model_packed(
                        [tl.layer for tl in layers], w2d, counts)
                    lat_vecs = list(lat2d)
                    lat2d_all = lat2d
                else:
                    rows = np.asarray(miss)
                    lat2d = self.model.latency_model_packed(
                        [layers[i].layer for i in miss],
                        w2d[rows], counts[rows])
                    for r, i in enumerate(miss):
                        lat_vecs[i] = lat2d[r]
                if self.cache is not None:
                    hw = self.model.hw
                    for i in miss:
                        k = int(counts[i])
                        self.cache.put(hw, layers[i].layer, w2d[i, :k],
                                       {"latency_s": lat_vecs[i][:k]},
                                       variant=variant)

        tables = []
        counts_l = counts.tolist()
        # start_par is params_per_unit * width per layer: one vectorized
        # multiply (elementwise float64 mul == the scalar `params` mul
        # bit-for-bit), not 1000 method calls.
        ppu = np.fromiter((tl.params_per_unit for tl in layers),
                          np.float64, n_layers)
        start_par_l = (ppu * starts).tolist()
        # Latency-mode rows convert to Python floats in ONE bulk tolist
        # when they all come from the stacked sweep matrix.
        rows_l = lat2d_all.tolist() if (not full and
                                        lat2d_all is not None) else None
        for pos, tl in enumerate(layers):
            vec = lat_vecs[pos]
            sd, su = sd_l[pos], su_l[pos]
            start_w = starts_l[pos]
            if full:
                k = counts_l[pos]
                lat = vec[: k - 1]
                start_lat = float(vec[k - 1])
            else:
                row = rows_l[pos] if rows_l is not None else \
                    vec[:3].tolist()
                lat = {}
                if down_ok_l[pos]:
                    lat[sd] = row[0]
                if up_ok_l[pos]:
                    lat[su] = row[1]
                start_lat = row[2]
            tables.append(_LayerTable(
                tl=tl, pos=pos, name=tl.layer.name,
                cands=tl.candidates,
                lat=lat,
                lo=lo_l[pos], hi=hi_l[pos],
                start_width=start_w,
                start_lat=start_lat,
                start_par=start_par_l[pos],
                start_down=sd,
                start_up=su,
            ))
        return tables

    def _build_tables_grouped(self, layers: Sequence[TunableLayer],
                              full: bool = True) -> list[_LayerTable]:
        """The historical per-shape-group table build (the engine this
        repo shipped before the stacked sweep), kept verbatim as the
        parity-test and benchmark baseline: layers sharing every
        ``LayerShape`` field but width are swept in one chunked
        ``latency_batch`` dispatch per group, with per-layer Python array
        building.  Output is bit-identical to the stacked path."""
        prepped = []
        groups: dict[tuple, list[int]] = {}
        for pos, tl in enumerate(layers):
            cands = tl.candidates  # sorted unique (TunableLayer init)
            n = int(cands.size)
            start_w = int(tl.layer.width)
            if n == 0:
                sd, su, lo, hi = -1, 0, 0, -1
            else:
                i = int(cands.searchsorted(start_w, side="left"))
                sd = i - 1
                su = i + 1 if (i < n and int(cands[i]) == start_w) else i
                lo = (0 if tl.min_width <= int(cands[0]) else
                      int(cands.searchsorted(tl.min_width, side="left")))
                hi = (n - 1 if (tl.max_width is None
                                or tl.max_width >= int(cands[-1])) else
                      int(cands.searchsorted(tl.max_width,
                                             side="right")) - 1)
            sl = tl.layer
            key = (sl.tokens, sl.d_in, sl.shard_in, sl.shard_out,
                   sl.dtype_bits, sl.flop_multiplier)
            groups.setdefault(key, []).append(pos)
            prepped.append((tl, cands, start_w, sd, su, lo, hi))

        lats: list = [None] * len(prepped)      # full array or sparse dict
        start_lats: list = [0.0] * len(prepped)
        for idxs in groups.values():
            ref_layer = prepped[idxs[0]][0].layer
            if full:
                # whole candidate sweep per layer + starts as a tail block
                arrs = [prepped[i][1] for i in idxs]
                widths = np.concatenate(
                    arrs + [np.array([prepped[i][2] for i in idxs],
                                     dtype=np.int64)])
            else:
                probe_idx = []
                wl = []
                for i in idxs:
                    _, cands, start_w, sd, su, lo, hi = prepped[i]
                    probes = ([sd] if sd >= lo else []) \
                        + ([su] if su <= hi else [])
                    probe_idx.append(probes)
                    wl.extend(int(cands[j]) for j in probes)
                    wl.append(start_w)
                widths = np.asarray(wl, dtype=np.int64)
            # Chunked so each sweep's working set stays cache-resident.
            lat_all = np.concatenate([
                self.model.latency_batch(ref_layer,
                                         widths[o:o + _SWEEP_CHUNK])
                for o in range(0, widths.size, _SWEEP_CHUNK)
            ]) if widths.size > _SWEEP_CHUNK else \
                self.model.latency_batch(ref_layer, widths)
            if full:
                off = 0
                starts_at = int(widths.size) - len(idxs)  # tail block
                for j, i in enumerate(idxs):
                    n = prepped[i][1].size
                    lats[i] = lat_all[off:off + n]
                    off += n
                    start_lats[i] = float(lat_all[starts_at + j])
            else:
                off = 0
                for j, i in enumerate(idxs):
                    probes = probe_idx[j]
                    lats[i] = {p: float(lat_all[off + k])
                               for k, p in enumerate(probes)}
                    off += len(probes)
                    start_lats[i] = float(lat_all[off])
                    off += 1

        tables = []
        for pos, (tl, cands, start_w, sd, su, lo, hi) in enumerate(prepped):
            tables.append(_LayerTable(
                tl=tl, pos=pos, name=tl.layer.name,
                cands=cands,
                lat=lats[pos] if lats[pos] is not None else {},
                lo=lo, hi=hi,
                start_width=start_w,
                start_lat=start_lats[pos],
                start_par=tl.params(start_w),
                start_down=sd,
                start_up=su,
            ))
        return tables

    # ---- latency-oriented (Eq. 7, Algorithm 2) ----------------------------
    def optimize_latency(
        self,
        layers: Sequence[TunableLayer],
        tau: float,
        delta: float = 0.9,
        max_rounds: int = 8,
    ) -> OptimizationResult:
        """Maximize sum LG subject to sum PG in (-tau, tau); retry with
        loosened tau until L_new <= delta * L_old (Algorithm 2 lines 15-18).

        ``tau`` is in absolute parameter counts.  The candidate tables are
        built once (reachable probes only — latency mode) and shared by
        every tau-loosening round.
        """
        tables = self._build_tables(layers, full=False)
        old_widths = {t.name: t.start_width for t in tables}
        l_old = sum(t.start_lat for t in tables)
        p_old = sum(t.start_par for t in tables)

        # Round-invariant state, hoisted out of the tau-loosening loop:
        # every round starts from the same widths, so the per-layer LG
        # estimates (Alg. 2 line 6) and the LG-ranked queues are identical —
        # only tau changes between rounds.
        states = [_LayerState(t) for t in tables]
        lg = []
        for t in tables:
            di = t.down_from(-1)
            lg.append(float(t.start_lat - t.lat[di]) if di is not None
                      else 0.0)
        # The historical implementation kept ONE list sorted descending by
        # LG (stable, so ties keep layer order) and popped max-LG from the
        # front / min-LG from the back.  Two heaps with lazy deletion
        # reproduce that exact pop sequence: ties at the front go to the
        # lowest layer position, ties at the back to the highest.
        base_down = [(-lg[i], i) for i in range(len(tables))]
        base_up = [(lg[i], -i) for i in range(len(tables))]
        heapq.heapify(base_down)
        heapq.heapify(base_up)

        best: OptimizationResult | None = None
        cur_tau = tau
        for _ in range(max_rounds):
            res = self._one_latency_round(tables, states, lg, base_down,
                                          base_up, old_widths, l_old, p_old,
                                          cur_tau, delta)
            if best is None or res.latency_new_s < best.latency_new_s:
                best = res
            if res.satisfied:
                return res
            cur_tau *= 2.0  # Algorithm 2 line 18: loosen and repeat
        assert best is not None
        return best

    def _one_latency_round(self, tables, states, lg, base_down, base_up,
                           old_widths, l_old, p_old, tau,
                           delta) -> OptimizationResult:
        for s in states:
            s.reset()
        moves: list[Move] = []
        pg = 0.0  # running sum PG (Eq. 7 window), exact for integer params

        down_heap = list(base_down)  # a copy of a heap is a valid heap
        up_heap = list(base_up)
        consumed = [False] * len(tables)
        remaining = len(tables)

        def pop_max_lg() -> int | None:
            while down_heap:
                _, i = heapq.heappop(down_heap)
                if not consumed[i]:
                    return i
            return None

        def pop_min_lg() -> int | None:
            while up_heap:
                _, neg = heapq.heappop(up_heap)
                i = -neg
                if not consumed[i]:
                    return i
            return None

        while remaining > 0:
            j = pop_max_lg()                 # Argmax LG (line 9)
            consumed[j] = True
            remaining -= 1
            sj = states[j]
            tj = tables[j]
            di = sj.down()
            applied_down = False
            dp_down = 0.0
            down_move_at = len(moves)
            if di is not None and lg[j] > 0:
                gain = sj.lat - float(tj.lat[di])
                dp_down = tj.par_at(di) - sj.par
                moves.append(Move(tj.name, "down", sj.width,
                                  int(tj.cands[di]), gain, dp_down))
                sj.move_to(di)
                pg += dp_down
                applied_down = True

            # Balance PG by scaling up min-LG layers (lines 11-13).
            while remaining > 0 and not (-tau < pg < tau):
                k = pop_min_lg()             # Argmin LG (line 12)
                consumed[k] = True
                remaining -= 1
                sk = states[k]
                tk = tables[k]
                ui = sk.up()
                if ui is None:
                    continue
                dp = tk.par_at(ui) - sk.par
                # only balance if the move brings PG closer to the window
                if abs(pg + dp) >= abs(pg):
                    continue
                extra = float(tk.lat[ui]) - sk.lat
                moves.append(Move(tk.name, "up", sk.width,
                                  int(tk.cands[ui]), -extra, dp))
                sk.move_to(ui)
                pg += dp

            # Eq. 7 is a hard constraint: if no up-candidates remain to
            # balance this scale-down, revert it — removing the down-Move
            # itself, not whatever Move happens to be last (the balance
            # loop may have appended up-moves after it that stay applied).
            # The seed popped the last entry, so ``moves`` could disagree
            # with ``new_widths`` in this corner; fixed in lockstep with
            # ``scalar_ref`` (coordinated behavior-change, see ROADMAP).
            if applied_down and not (-tau < pg < tau):
                sj.reset()
                pg -= dp_down
                del moves[down_move_at]

        l_new = sum(s.lat for s in states)
        widths = {s.table.name: s.width for s in states}
        return OptimizationResult(
            old_widths=dict(old_widths), new_widths=widths,
            latency_old_s=l_old, latency_new_s=l_new,
            params_old=p_old, params_new=p_old + pg,
            moves=moves, tau_final=tau,
            satisfied=l_new <= l_old * delta,
        )

    # ---- accuracy-oriented (Eq. 6) ----------------------------------------
    def optimize_accuracy(
        self,
        layers: Sequence[TunableLayer],
        latency_slack: float = 0.0,
    ) -> OptimizationResult:
        """Maximize sum PG subject to sum LG >= -latency_slack * L_old.

        Pass 1 snaps every layer *up* to the right edge of its current wave —
        by construction latency is unchanged (same wave) and capacity grows
        for free (the paper's EfficientNet move, Table 3).  Pass 2 greedily
        spends any remaining latency slack on full wave jumps, largest
        PG-per-latency first, via a max-heap over each layer's next jump.

        With no slack there is no pass-2 walk, so only the one-step probes
        are swept (``full=False``); with slack the walk can climb many
        waves and needs the whole table.
        """
        tables = self._build_tables(layers, full=latency_slack > 0)
        old_widths = {t.name: t.start_width for t in tables}
        l_old = sum(t.start_lat for t in tables)
        p_old = sum(t.start_par for t in tables)
        budget = latency_slack * l_old

        states = [_LayerState(t) for t in tables]
        moves: list[Move] = []
        for s in states:
            t = s.table
            ui = s.up()
            if ui is None:
                continue
            extra = float(t.lat[ui]) - s.lat
            if extra <= 1e-15:  # same wave: free capacity
                dp = t.par_at(ui) - s.par
                moves.append(Move(t.name, "up", s.width,
                                  int(t.cands[ui]), -extra, dp))
                s.move_to(ui)

        # Pass 2: spend the slack budget on wave jumps.  Each layer has one
        # live heap entry — its next jump; a popped entry that exceeds the
        # (monotonically shrinking) budget or has dp <= 0 can never become
        # valid again and is dropped for good.
        heap: list[tuple[float, int, int, float, float]] = []

        def push_next(i: int) -> None:
            s = states[i]
            t = s.table
            ui = s.up()
            if ui is None:
                return
            extra = float(t.lat[ui]) - s.lat
            dp = t.par_at(ui) - s.par
            ratio = dp / max(extra, 1e-15)
            heapq.heappush(heap, (-ratio, i, ui, extra, dp))

        if budget > 0:
            for i in range(len(states)):
                push_next(i)
        while heap and budget > 0:
            _, i, ui, extra, dp = heapq.heappop(heap)
            if extra > budget or dp <= 0:
                continue
            s = states[i]
            t = s.table
            moves.append(Move(t.name, "up", s.width,
                              int(t.cands[ui]), -extra, dp))
            s.move_to(ui)
            budget -= extra
            push_next(i)

        l_new = sum(s.lat for s in states)
        p_new = sum(s.par for s in states)
        widths = {s.table.name: s.width for s in states}
        return OptimizationResult(
            old_widths=old_widths, new_widths=widths,
            latency_old_s=l_old, latency_new_s=l_new,
            params_old=p_old, params_new=p_new,
            moves=moves, tau_final=0.0,
            satisfied=l_new <= l_old * (1 + latency_slack) + 1e-12,
        )


def discretize_pruning_space(
    layers: Sequence[TunableLayer],
    target_widths: dict[str, int],
) -> dict[str, int]:
    """Section 4.4 "Advancing Filter Pruning": replace a pruning method's
    continuous per-layer width targets with the nearest tail-free candidates,
    giving the pruner a *discrete* search space with no GPU-tail waste."""
    out = {}
    for tl in layers:
        name = tl.layer.name
        out[name] = cand.snap_nearest(tl.candidates, target_widths[name])
    return out
