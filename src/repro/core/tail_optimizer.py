"""GPU(-analogue)-aware model configuration optimization — paper Algorithm 2.

Two duals, exactly as in the paper section 4.3:

  * latency-oriented (Eq. 7):  maximize sum LG_i  s.t.  sum PG_i in (-tau, tau)
  * accuracy-oriented (Eq. 6): maximize sum PG_i  s.t.  sum LG_i >= 0

where per layer i (Eq. 5):  LG_i = L_i[R_old] - L_i[R_new]   (latency gain)
                            PG_i = params(R_new) - params(R_old)  (param gain)

The mechanics follow Algorithm 2: identify per-layer candidates C_i[m]
(Eq. 4, see candidates.py), keep two queues ranked by LG, greedily pop the
max-LG layer to *scale down* (Eq. 8a) and balance the parameter budget by
popping min-LG layers to *scale up* (Eq. 8b); after all layers are adjusted,
check L_new <= delta * L_old and loosen tau if the target is missed
(Algorithm 2 line 18).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core import candidates as cand
from repro.core.tail_model import LayerShape, WaveQuantizationModel, ceil_div


@dataclasses.dataclass
class TunableLayer:
    """One width-adjustable layer handed to the optimizer."""

    layer: LayerShape
    candidates: np.ndarray
    # parameters contributed per unit of width (e.g. d_in for a dense layer,
    # d_in + d_out for a conv filter that also feeds the next layer's input).
    params_per_unit: float
    min_width: int = 1
    max_width: int | None = None

    def params(self, width: int) -> float:
        return self.params_per_unit * width


@dataclasses.dataclass
class Move:
    layer: str
    kind: str          # "down" | "up"
    old_width: int
    new_width: int
    latency_gain_s: float
    param_gain: float


@dataclasses.dataclass
class OptimizationResult:
    old_widths: dict[str, int]
    new_widths: dict[str, int]
    latency_old_s: float
    latency_new_s: float
    params_old: float
    params_new: float
    moves: list[Move]
    tau_final: float
    satisfied: bool

    @property
    def latency_reduction(self) -> float:
        if self.latency_old_s == 0:
            return 0.0
        return 1.0 - self.latency_new_s / self.latency_old_s

    @property
    def param_gain(self) -> float:
        return self.params_new - self.params_old

    def summary(self) -> str:
        lines = [
            f"latency: {self.latency_old_s * 1e6:.2f}us -> "
            f"{self.latency_new_s * 1e6:.2f}us "
            f"({self.latency_reduction * 100:+.1f}% reduction)",
            f"params:  {self.params_old / 1e6:.3f}M -> "
            f"{self.params_new / 1e6:.3f}M ({self.param_gain / 1e6:+.3f}M)",
            f"tau_final={self.tau_final:.3g} satisfied={self.satisfied}",
        ]
        for m in self.moves:
            lines.append(
                f"  [{m.kind:>4}] {m.layer}: {m.old_width} -> {m.new_width} "
                f"(LG {m.latency_gain_s * 1e6:+.2f}us, PG {m.param_gain:+.0f})"
            )
        return "\n".join(lines)


class TailEffectOptimizer:
    """Paper Algorithm 2 on the wave-quantization latency model."""

    def __init__(self, model: WaveQuantizationModel):
        self.model = model

    # ---- helpers ---------------------------------------------------------
    def _latency(self, tl: TunableLayer, width: int) -> float:
        return self.model.evaluate(tl.layer.with_width(width)).latency_s

    def _total_latency(self, layers: Sequence[TunableLayer],
                       widths: dict[str, int]) -> float:
        return sum(self._latency(tl, widths[tl.layer.name]) for tl in layers)

    def _total_params(self, layers: Sequence[TunableLayer],
                      widths: dict[str, int]) -> float:
        return sum(tl.params(widths[tl.layer.name]) for tl in layers)

    def _down(self, tl: TunableLayer, width: int) -> int | None:
        w = cand.snap_down(tl.candidates, width)
        if w is not None and w < tl.min_width:
            return None
        return w

    def _up(self, tl: TunableLayer, width: int) -> int | None:
        w = cand.snap_up(tl.candidates, width)
        if w is not None and tl.max_width is not None and w > tl.max_width:
            return None
        return w

    # ---- latency-oriented (Eq. 7, Algorithm 2) ----------------------------
    def optimize_latency(
        self,
        layers: Sequence[TunableLayer],
        tau: float,
        delta: float = 0.9,
        max_rounds: int = 8,
    ) -> OptimizationResult:
        """Maximize sum LG subject to sum PG in (-tau, tau); retry with
        loosened tau until L_new <= delta * L_old (Algorithm 2 lines 15-18).

        ``tau`` is in absolute parameter counts.
        """
        old_widths = {tl.layer.name: tl.layer.width for tl in layers}
        l_old = self._total_latency(layers, old_widths)
        p_old = self._total_params(layers, old_widths)

        best: OptimizationResult | None = None
        cur_tau = tau
        for _ in range(max_rounds):
            res = self._one_latency_round(layers, old_widths, l_old, p_old,
                                          cur_tau, delta)
            if best is None or res.latency_new_s < best.latency_new_s:
                best = res
            if res.satisfied:
                return res
            cur_tau *= 2.0  # Algorithm 2 line 18: loosen and repeat
        assert best is not None
        return best

    def _one_latency_round(self, layers, old_widths, l_old, p_old, tau,
                           delta) -> OptimizationResult:
        widths = dict(old_widths)
        moves: list[Move] = []

        # Per-layer LG/PG estimates for one scale-down step (Alg. 2 line 6).
        lg: dict[str, float] = {}
        for tl in layers:
            name = tl.layer.name
            down = self._down(tl, widths[name])
            lg[name] = (self._latency(tl, widths[name])
                        - self._latency(tl, down)) if down is not None else 0.0

        by_name = {tl.layer.name: tl for tl in layers}
        # Queue ranked by LG (Alg. 2 line 7).  Layers appear once each.
        queue = sorted(lg, key=lambda n: lg[n], reverse=True)

        def pg_total() -> float:
            return (self._total_params(layers, widths) - p_old)

        while queue:
            j = queue.pop(0)                 # Argmax LG (line 9)
            tl = by_name[j]
            down = self._down(tl, widths[j])
            applied_down = False
            old_w = widths[j]
            if down is not None and lg[j] > 0:
                gain = self._latency(tl, widths[j]) - self._latency(tl, down)
                dp = tl.params(down) - tl.params(widths[j])
                moves.append(Move(j, "down", widths[j], down, gain, dp))
                widths[j] = down
                applied_down = True

            # Balance PG by scaling up min-LG layers (lines 11-13).
            while queue and not (-tau < pg_total() < tau):
                k = queue.pop(-1)            # Argmin LG (line 12)
                tk = by_name[k]
                up = self._up(tk, widths[k])
                if up is None:
                    continue
                dp = tk.params(up) - tk.params(widths[k])
                # only balance if the move brings PG closer to the window
                if abs(pg_total() + dp) >= abs(pg_total()):
                    continue
                extra = self._latency(tk, up) - self._latency(tk, widths[k])
                moves.append(Move(k, "up", widths[k], up, -extra, dp))
                widths[k] = up

            # Eq. 7 is a hard constraint: if no up-candidates remain to
            # balance this scale-down, revert it.
            if applied_down and not (-tau < pg_total() < tau):
                widths[j] = old_w
                moves.pop()

        l_new = self._total_latency(layers, widths)
        return OptimizationResult(
            old_widths=dict(old_widths), new_widths=widths,
            latency_old_s=l_old, latency_new_s=l_new,
            params_old=p_old, params_new=self._total_params(layers, widths),
            moves=moves, tau_final=tau,
            satisfied=l_new <= l_old * delta,
        )

    # ---- accuracy-oriented (Eq. 6) ----------------------------------------
    def optimize_accuracy(
        self,
        layers: Sequence[TunableLayer],
        latency_slack: float = 0.0,
    ) -> OptimizationResult:
        """Maximize sum PG subject to sum LG >= -latency_slack * L_old.

        Pass 1 snaps every layer *up* to the right edge of its current wave —
        by construction latency is unchanged (same wave) and capacity grows
        for free (the paper's EfficientNet move, Table 3).  Pass 2 greedily
        spends any remaining latency slack on full wave jumps, largest
        PG-per-latency first.
        """
        old_widths = {tl.layer.name: tl.layer.width for tl in layers}
        l_old = self._total_latency(layers, old_widths)
        p_old = self._total_params(layers, old_widths)
        budget = latency_slack * l_old

        widths = dict(old_widths)
        moves: list[Move] = []
        for tl in layers:
            name = tl.layer.name
            up = self._up(tl, widths[name])
            if up is None:
                continue
            extra = self._latency(tl, up) - self._latency(tl, widths[name])
            if extra <= 1e-15:  # same wave: free capacity
                dp = tl.params(up) - tl.params(widths[name])
                moves.append(Move(name, "up", widths[name], up, -extra, dp))
                widths[name] = up

        # Pass 2: spend the slack budget on wave jumps.
        improved = True
        while improved and budget > 0:
            improved = False
            ranked: list[tuple[float, TunableLayer, int, float]] = []
            for tl in layers:
                name = tl.layer.name
                up = self._up(tl, widths[name])
                if up is None:
                    continue
                extra = self._latency(tl, up) - self._latency(tl, widths[name])
                dp = tl.params(up) - tl.params(widths[name])
                if extra <= budget and dp > 0:
                    ranked.append((dp / max(extra, 1e-15), tl, up, extra))
            if ranked:
                ranked.sort(key=lambda t: t[0], reverse=True)
                _, tl, up, extra = ranked[0]
                name = tl.layer.name
                dp = tl.params(up) - tl.params(widths[name])
                moves.append(Move(name, "up", widths[name], up, -extra, dp))
                widths[name] = up
                budget -= extra
                improved = True

        l_new = self._total_latency(layers, widths)
        return OptimizationResult(
            old_widths=old_widths, new_widths=widths,
            latency_old_s=l_old, latency_new_s=l_new,
            params_old=p_old, params_new=self._total_params(layers, widths),
            moves=moves, tau_final=0.0,
            satisfied=l_new <= l_old * (1 + latency_slack) + 1e-12,
        )


def discretize_pruning_space(
    layers: Sequence[TunableLayer],
    target_widths: dict[str, int],
) -> dict[str, int]:
    """Section 4.4 "Advancing Filter Pruning": replace a pruning method's
    continuous per-layer width targets with the nearest tail-free candidates,
    giving the pruner a *discrete* search space with no GPU-tail waste."""
    out = {}
    for tl in layers:
        name = tl.layer.name
        out[name] = cand.snap_nearest(tl.candidates, target_widths[name])
    return out
