"""The latency-staircase / tail-effect model, adapted from GPU waves to TPU tiles.

Paper Eq. 3 models one conv layer as

    L = dL * ceil(B / S),      B = threads_per_filter * F / threads_per_block

i.e. work is quantized into *waves* of S SMs and a partial last wave (the GPU
tail) costs a full cycle.  On TPU the same ceil-quantization appears at three
levels (see DESIGN.md section 2):

  1. MXU/VPU tiles:  a (M, K) x (K, N) matmul issues
         ceil(M/Tm) * ceil(K/Tk) * ceil(N/Tn)
     systolic tile passes; the residual of each dim burns a full tile.
  2. Pallas grid "waves": grid cells map onto ``cores_per_chip`` cores,
     L = dL * ceil(num_cells / cores) — literally paper Eq. 3.
  3. Mesh shards: a dim d sharded n ways costs ceil(d/n) per device; every
     device pays the max (ragged) shard.

``WaveQuantizationModel`` composes (1) and (3) into per-layer staircase
functions L(width), U(width), T(width) — the quantities the paper profiles
with nvprof — and ``GridWaveModel`` implements (2) for the Fig. 5
verification benchmark.

Table-driven evaluation
-----------------------
The model is closed-form, so a whole width sweep is one vectorized NumPy
expression.  ``evaluate_batch(layer, widths)`` returns a ``StairTable`` —
parallel arrays of latency / utilization / throughput / waves / FLOPs over a
width vector — and is the primitive everything else is built on:

  * ``evaluate`` is a thin one-width wrapper over ``evaluate_batch``;
  * ``profiler.analytic_profile`` is ``evaluate_batch`` plus a name tag;
  * ``latency_batch`` is the latency column alone (bit-identical, fewer
    array passes) — ``tail_optimizer`` sweeps it once per ``optimize_*``
    call to build per-layer candidate tables and then runs Algorithm 2
    entirely on table lookups, never calling back into the model inside
    its greedy loops.

Stacked model-level sweeps
--------------------------
``evaluate_batch`` is per-layer, so a 1000+-layer config still pays one
NumPy dispatch (and one Python loop iteration) per layer-shape group.  The
model-level engine stacks the whole sweep instead: layers are flattened
into padded ``(n_layers, max_candidates)`` width arrays (``pack_widths``)
and the per-layer constants — tile-padded token/d_in dims, shard counts,
dtype, flop multiplier — are broadcast as ``(n_layers, 1)`` columns
(``_LayerColumns``), so all layers x all candidate widths evaluate in ONE
stacked NumPy call:

  * ``evaluate_model_batch(layers, widths_per_layer)`` returns a
    ``ModelStairTable`` — the 2-D counterpart of ``StairTable`` with a
    per-layer ``counts`` mask; ``layer_table(i)`` slices row ``i`` back to
    a plain ``StairTable``;
  * ``latency_model_batch`` is its latency-only fast path (ragged list of
    row views), the primitive under ``tail_optimizer._build_tables`` and
    the disk-backed profile-table cache (``repro.core.table_cache``);
  * both are chunked over row blocks so the ~10 elementwise temporaries
    stay cache-resident however many layers are stacked.

Every row is bit-for-bit equal to the per-layer ``evaluate_batch`` sweep:
the float expressions keep the exact scalar operand order, and the
exact-identity factors the per-layer path skips (shard 1, flop multiplier
1.0) are IEEE no-ops when multiplied in as columns.

This mirrors the paper's "Step 1: pre-analysis": profile (here: derive) the
per-layer L/U/T tables once, then optimize over the tables.  The float
arithmetic is ordered identically to the historical scalar path, so batched
results are bit-for-bit equal to per-width evaluation (property-tested in
tests/test_batched_equivalence.py against the frozen scalar reference in
``repro.core.scalar_ref``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.hardware import HardwareSpec


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _ceil_div_arr(a: np.ndarray, b: int, nonneg: bool) -> np.ndarray:
    """Elementwise ceil_div; a shift when ``b`` is a power of two and the
    numerator is known nonnegative (bit-identical, ~2x cheaper)."""
    if nonneg and b & (b - 1) == 0:
        return (a + (b - 1)) >> (b.bit_length() - 1)
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One width-adjustable matmul layer: (tokens, d_in) @ (d_in, width).

    ``shard_in`` / ``shard_out`` are the mesh-axis sizes sharding ``d_in`` and
    ``width`` respectively (1 = unsharded).  ``tokens`` is the *per-device*
    token count (batch already sharded by data parallelism).  ``flop_multiplier``
    scales FLOPs for layers where one "width unit" does more than one MAC per
    token-input pair (e.g. GQA heads, experts).
    """

    name: str
    tokens: int
    d_in: int
    width: int
    shard_in: int = 1
    shard_out: int = 1
    dtype_bits: int = 16
    flop_multiplier: float = 1.0

    def with_width(self, width: int) -> "LayerShape":
        return dataclasses.replace(self, width=width)


@dataclasses.dataclass(frozen=True)
class StairPoint:
    width: int
    latency_s: float        # modeled L
    utilization: float      # paper's U: useful / (padded quantum) work
    throughput: float       # paper's T: FLOP/s achieved
    waves: int              # ceil count along the width dim
    flops: float            # useful (model) FLOPs
    padded_flops: float     # FLOPs actually executed incl. tile padding


@dataclasses.dataclass(frozen=True)
class StairTable:
    """One layer's staircase over a width vector: parallel arrays.

    The batched counterpart of ``StairPoint`` — the paper's profiled
    (width, L, U, T) table, derived in one vectorized shot.
    """

    widths: np.ndarray        # (n,) int64
    latency_s: np.ndarray     # (n,) float64
    utilization: np.ndarray   # (n,) float64
    throughput: np.ndarray    # (n,) float64
    waves: np.ndarray         # (n,) int64
    flops: np.ndarray         # (n,) float64
    padded_flops: np.ndarray  # (n,) float64

    def __len__(self) -> int:
        return int(self.widths.size)

    def point(self, i: int) -> StairPoint:
        return StairPoint(
            width=int(self.widths[i]),
            latency_s=float(self.latency_s[i]),
            utilization=float(self.utilization[i]),
            throughput=float(self.throughput[i]),
            waves=int(self.waves[i]),
            flops=float(self.flops[i]),
            padded_flops=float(self.padded_flops[i]),
        )

    def points(self) -> list[StairPoint]:
        return [self.point(i) for i in range(len(self))]


@dataclasses.dataclass(frozen=True)
class ModelStairTable:
    """All layers x all candidate widths: one stacked sweep, 2-D arrays.

    Rows are layers, columns are candidates; rows shorter than
    ``widths.shape[1]`` are padded (pad width 1) and masked by ``counts``.
    ``layer_table(i)`` slices row ``i`` back to a per-layer ``StairTable``
    whose arrays are bit-for-bit what ``evaluate_batch`` would return.
    """

    layer_names: tuple[str, ...]
    widths: np.ndarray        # (L, C) int64, rows padded with width 1
    counts: np.ndarray        # (L,) int64: valid candidates per row
    latency_s: np.ndarray     # (L, C) float64
    utilization: np.ndarray   # (L, C) float64
    throughput: np.ndarray    # (L, C) float64
    waves: np.ndarray         # (L, C) int64
    flops: np.ndarray         # (L, C) float64
    padded_flops: np.ndarray  # (L, C) float64

    def __len__(self) -> int:
        return len(self.layer_names)

    def layer_table(self, i: int) -> StairTable:
        n = int(self.counts[i])
        return StairTable(
            widths=self.widths[i, :n],
            latency_s=self.latency_s[i, :n],
            utilization=self.utilization[i, :n],
            throughput=self.throughput[i, :n],
            waves=self.waves[i, :n],
            flops=self.flops[i, :n],
            padded_flops=self.padded_flops[i, :n],
        )


@dataclasses.dataclass(frozen=True)
class _LayerColumns:
    """Per-layer constants of the staircase math as (L, 1) columns.

    Derived quantities that the scalar path computes from ints
    (``two_mk = (2.0 * m_pad) * k_pad`` etc.) are hoisted here once per
    stack in the scalar operand order, so broadcasting them over a width
    block reproduces the per-layer float sequence exactly.  ``all_*``
    flags let the stacked core skip whole passes when a factor is the
    identity for EVERY row (the per-layer path skips them per layer; for
    mixed stacks the multiply runs everywhere and is an IEEE no-op on the
    identity rows).
    """

    shard_out: np.ndarray   # (L, 1) int64
    shard_in: np.ndarray    # (L, 1) int64
    fm: np.ndarray          # (L, 1) float64 flop_multiplier
    bits: np.ndarray        # (L, 1) int64 dtype_bits
    m_pad: np.ndarray       # (L, 1) int64
    k_pad: np.ndarray       # (L, 1) int64
    two_mk: np.ndarray      # (L, 1) float64: (2.0 * m_pad) * k_pad
    mk: np.ndarray          # (L, 1) int64: m_pad * k_pad
    k_plus_m: np.ndarray    # (L, 1) int64: k_pad + m_pad
    two_td: np.ndarray      # (L, 1) float64: (2.0 * tokens) * d_in
    all_so1: bool           # every shard_out == 1
    all_si1: bool           # every shard_in == 1
    all_fm1: bool           # every flop_multiplier == 1.0
    bytes_aligned: bool     # every dtype_bits % 8 == 0

    def block(self, sl: slice) -> "_LayerColumns":
        return dataclasses.replace(
            self, shard_out=self.shard_out[sl], shard_in=self.shard_in[sl],
            fm=self.fm[sl], bits=self.bits[sl], m_pad=self.m_pad[sl],
            k_pad=self.k_pad[sl], two_mk=self.two_mk[sl], mk=self.mk[sl],
            k_plus_m=self.k_plus_m[sl], two_td=self.two_td[sl])


# Elements per stacked row-block sweep: with ~10 float64 temporaries this
# keeps the working set around 2.5 MB (L2/L3-resident); one giant pass over
# a 1000-layer stack goes memory-bound and costs several times more per
# point.
_STACKED_CHUNK = 32768

# Staircase evaluation engines (see ``repro.kernels.staircase_fused``):
#   numpy            exact reference — bit-for-bit vs the frozen scalar path
#   fused            affine-in-waves factoring, one fused NumPy pass; same
#                    staircase (identical wave counts, latency within a few
#                    ulp — the rounding order differs by the factoring)
#   pallas           the fused sweep as a Pallas TPU kernel (float32 on
#                    hardware; falls back to the fp64 fused reference off-TPU)
#   pallas_interpret the Pallas kernel in interpret mode (runs anywhere;
#                    float32 like the hardware kernel)
BACKENDS = ("numpy", "fused", "pallas", "pallas_interpret")


class WaveQuantizationModel:
    """Closed-form staircase model L(width) = dL * ceil(width / Q).

    ``evaluate_batch`` is the primitive; ``evaluate``/``staircase`` are thin
    wrappers over it.  ``evaluate_model_batch``/``latency_model_batch``
    stack many layers into one call (see module docstring).  ``eval_points``
    counts widths evaluated since construction (benchmark instrumentation
    for the table-driven refactor).

    ``backend`` selects the sweep engine (``BACKENDS``).  The non-numpy
    engines require byte-aligned dtypes and widths >= 1 (the affine
    factoring is exact only there) and fall back to the exact numpy core
    otherwise, so every backend is total over the model's input domain.
    """

    def __init__(self, hw: HardwareSpec, backend: str = "numpy"):
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of {BACKENDS}")
        self.hw = hw
        self.backend = backend
        self.eval_calls = 0    # number of evaluate/evaluate_batch calls
        self.eval_points = 0   # total widths evaluated across those calls

    # ---- quanta ---------------------------------------------------------
    def width_quantum(self, shard_out: int) -> int:
        """Q: widths that are multiples of this have zero tail."""
        return shard_out * self.hw.lane

    def padded_dim(self, d: int, shard: int, tile: int) -> int:
        """Per-device padded size of dim ``d`` sharded ``shard`` ways."""
        per_dev = ceil_div(d, shard)
        return ceil_div(per_dev, tile) * tile

    # ---- per-layer staircase -------------------------------------------
    def waves(self, layer: LayerShape) -> int:
        """Tile waves along the adjustable width dim (paper's ceil(B/S))."""
        per_dev = ceil_div(layer.width, layer.shard_out)
        return ceil_div(per_dev, self.hw.lane)

    # ---- fused backends -------------------------------------------------
    def _kernel_staircase(self, w2d, shard_out, ca, mb, mc):
        """Route a fused (rows, C) sweep through the Pallas kernel (via
        ``kernels.ops`` dispatch; jax loads lazily there)."""
        from repro.kernels import ops
        force = "pallas_interpret" if self.backend == "pallas_interpret" \
            else None
        lat, waves, _ = ops.staircase_latency(
            w2d, shard_out, ca, mb, mc, lane=self.hw.lane, force=force)
        return lat.astype(np.float64), waves.astype(np.int64)

    def _staircase_core_fused(self, layer: LayerShape, w: np.ndarray):
        """Per-layer fused evaluation, or None when the input is outside
        the fused domain (empty / signed widths, non-byte-aligned dtype)
        and the exact numpy core must run instead."""
        hw = self.hw
        if w.size == 0 or int(w.min()) < 1 or layer.dtype_bits % 8 != 0:
            return None
        from repro.kernels.staircase_fused import fused_coeffs, fused_latency
        sub = hw.sublane(layer.dtype_bits)
        m_pad = ceil_div(layer.tokens, sub) * sub
        k_pad = self.padded_dim(layer.d_in, layer.shard_in, hw.lane)
        two_mk = (2.0 * m_pad) * k_pad
        ca, mb, mc = fused_coeffs(
            hw, two_mk=two_mk, mk=m_pad * k_pad, k_plus_m=k_pad + m_pad,
            fm=layer.flop_multiplier, bits=layer.dtype_bits)
        if self.backend in ("pallas", "pallas_interpret"):
            latency, n_waves = self._kernel_staircase(
                w[None, :], np.array([[layer.shard_out]], np.int64),
                np.array([[ca]]), np.array([[mb]]), np.array([[mc]]))
            latency, n_waves = latency[0], n_waves[0]
        else:
            latency, n_waves = fused_latency(
                w, layer.shard_out, ca, mb, mc, lane=hw.lane,
                all_so1=layer.shard_out == 1)
        padded_per_dev = ((two_mk * layer.flop_multiplier) * hw.lane) \
            * n_waves
        return latency, n_waves, padded_per_dev, True

    def _staircase_core(self, layer: LayerShape, w: np.ndarray):
        """Shared vectorized core: (latency, n_waves, padded_per_dev, nonneg).

        The float expressions are ordered exactly as the historical scalar
        path (see ``repro.core.scalar_ref``) so every element is bit-for-bit
        equal to evaluating that width alone.  Multiplies/divides by
        exact-identity factors (shard 1, flop_multiplier 1.0) are skipped
        and power-of-two ceil-divs become shifts on the nonnegative fast
        path — bit-identical results, fewer/cheaper array passes.
        """
        if self.backend != "numpy":
            res = self._staircase_core_fused(layer, w)
            if res is not None:
                return res
        hw = self.hw
        sub = hw.sublane(layer.dtype_bits)
        m_pad = ceil_div(layer.tokens, sub) * sub
        k_pad = self.padded_dim(layer.d_in, layer.shard_in, hw.lane)
        nonneg = w.size == 0 or int(w.min()) >= 1
        per_dev = w if layer.shard_out == 1 else \
            _ceil_div_arr(w, layer.shard_out, nonneg)
        n_waves = _ceil_div_arr(per_dev, hw.lane, nonneg)
        n_pad = n_waves * hw.lane

        # Per-device padded work (d_in and width divided across shards).
        padded_per_dev = 2.0 * m_pad * k_pad * n_pad
        if layer.flop_multiplier != 1.0:
            padded_per_dev = padded_per_dev * layer.flop_multiplier

        compute_s = padded_per_dev / hw.peak_flops_bf16
        # == (m_pad*k_pad + k_pad*n_pad + m_pad*n_pad) * bits // 8, with the
        # n_pad terms factored and the //8 folded into the multiplier for
        # byte-aligned dtypes (both exact in int64).
        elems = m_pad * k_pad + (k_pad + m_pad) * n_pad
        if layer.dtype_bits % 8 == 0:
            bytes_per_dev = elems * (layer.dtype_bits // 8)
        else:
            bytes_per_dev = elems * layer.dtype_bits // 8
        memory_s = bytes_per_dev / hw.hbm_bandwidth
        latency = np.maximum(compute_s, memory_s)
        return latency, n_waves, padded_per_dev, nonneg

    def latency_batch(self, layer: LayerShape,
                      widths: Sequence[int]) -> np.ndarray:
        """The latency column of ``evaluate_batch`` alone — identical math
        and bit-identical values, skipping the utilization / throughput /
        FLOPs columns.  This is the optimizer's table-build fast path (its
        tables only need L and params)."""
        w = np.atleast_1d(np.asarray(widths, dtype=np.int64))
        self.eval_calls += 1
        self.eval_points += int(w.size)
        return self._staircase_core(layer, w)[0]

    def evaluate_batch(self, layer: LayerShape,
                       widths: Sequence[int]) -> StairTable:
        """Vectorized staircase: one ``StairTable`` over a width vector.

        Every row is bit-for-bit equal to evaluating that width alone (the
        frozen scalar path in ``repro.core.scalar_ref``).  ``layer.width``
        is ignored; the sweep variable is ``widths``.
        """
        w = np.atleast_1d(np.asarray(widths, dtype=np.int64))
        self.eval_calls += 1
        self.eval_points += int(w.size)
        latency, n_waves, padded_per_dev, nonneg = \
            self._staircase_core(layer, w)

        useful = 2.0 * layer.tokens * layer.d_in * w
        if layer.flop_multiplier != 1.0:
            useful = useful * layer.flop_multiplier
        padded_total = padded_per_dev
        if layer.shard_in != 1:
            padded_total = padded_total * layer.shard_in
        if layer.shard_out != 1:
            padded_total = padded_total * layer.shard_out

        if nonneg:
            # widths >= 1 ⇒ n_pad >= lane ⇒ padded/latency strictly positive
            util = useful / padded_total
            thr = useful / latency
        else:
            util = np.divide(useful, padded_total,
                             out=np.zeros_like(useful),
                             where=padded_total != 0.0)
            thr = np.divide(useful, latency,
                            out=np.zeros_like(useful),
                            where=latency != 0.0)
        return StairTable(
            widths=w,
            latency_s=latency,
            utilization=util,
            throughput=thr,
            waves=n_waves,
            flops=useful,
            padded_flops=padded_total,
        )

    def evaluate(self, layer: LayerShape) -> StairPoint:
        return self.evaluate_batch(layer, [layer.width]).point(0)

    def staircase(
        self, layer: LayerShape, widths: Sequence[int]
    ) -> list[StairPoint]:
        return self.evaluate_batch(layer, widths).points()

    def staircase_arrays(self, layer: LayerShape, widths: Sequence[int]):
        t = self.evaluate_batch(layer, widths)
        return t.widths, t.latency_s, t.utilization, t.throughput

    # ---- stacked model-level sweep --------------------------------------
    @staticmethod
    def pack_widths(
        widths_per_layer: Sequence[Sequence[int]],
    ) -> tuple[np.ndarray, np.ndarray]:
        """Ragged per-layer width vectors -> padded (L, C) int64 + counts.

        Pad value is 1 (any valid width); padded cells compute ordinary
        staircase values and are masked out by ``counts`` downstream.
        """
        vecs = [np.atleast_1d(np.asarray(v, dtype=np.int64))
                for v in widths_per_layer]
        counts = np.array([v.size for v in vecs], dtype=np.int64)
        n_layers = len(vecs)
        n_cols = int(counts.max()) if n_layers else 0
        if n_layers and int(counts.min()) == n_cols:
            return (np.stack(vecs) if n_cols else
                    np.zeros((n_layers, 0), np.int64)), counts
        # empty + per-row fill: each cell written exactly once (np.ones
        # would write the whole matrix and then overwrite the data region)
        packed = np.empty((n_layers, n_cols), dtype=np.int64)
        for i, v in enumerate(vecs):
            packed[i, : v.size] = v
            packed[i, v.size:] = 1
        return packed, counts

    def _stack_columns(self, layers: Sequence[LayerShape]) -> _LayerColumns:
        hw = self.hw

        def col(vals, dtype):
            return np.asarray(vals, dtype=dtype)[:, None]

        tokens = col([l.tokens for l in layers], np.int64)
        d_in = col([l.d_in for l in layers], np.int64)
        shard_in = col([l.shard_in for l in layers], np.int64)
        shard_out = col([l.shard_out for l in layers], np.int64)
        bits = col([l.dtype_bits for l in layers], np.int64)
        fm = col([l.flop_multiplier for l in layers], np.float64)
        sub = np.where(bits >= 32, hw.sublane_fp32, hw.sublane_bf16)
        m_pad = -(-tokens // sub) * sub
        k_pad = -(-(-(-d_in // shard_in)) // hw.lane) * hw.lane
        return _LayerColumns(
            shard_out=shard_out, shard_in=shard_in, fm=fm, bits=bits,
            m_pad=m_pad, k_pad=k_pad,
            two_mk=(2.0 * m_pad) * k_pad,
            mk=m_pad * k_pad,
            k_plus_m=k_pad + m_pad,
            two_td=(2.0 * tokens) * d_in,
            all_so1=bool((shard_out == 1).all()) if len(layers) else True,
            all_si1=bool((shard_in == 1).all()) if len(layers) else True,
            all_fm1=bool((fm == 1.0).all()) if len(layers) else True,
            bytes_aligned=bool((bits % 8 == 0).all()) if len(layers) else True,
        )

    def _stacked_fused(self, cols: _LayerColumns, w: np.ndarray,
                       need_padded: bool, out, scratch=None):
        """Stacked fused evaluation, or None when outside the fused domain
        (see ``_staircase_core_fused``)."""
        hw = self.hw
        if w.size == 0 or not cols.bytes_aligned or int(w.min()) < 1:
            return None
        from repro.kernels.staircase_fused import fused_coeffs, fused_latency
        ca, mb, mc = fused_coeffs(
            hw, two_mk=cols.two_mk, mk=cols.mk, k_plus_m=cols.k_plus_m,
            fm=cols.fm, bits=cols.bits)
        if self.backend in ("pallas", "pallas_interpret"):
            latency, n_waves = self._kernel_staircase(
                w, cols.shard_out, ca, mb, mc)
            if out is not None:
                out[...] = latency
                latency = out
        else:
            latency, n_waves = fused_latency(
                w, cols.shard_out, ca, mb, mc, lane=hw.lane,
                all_so1=cols.all_so1, out=out, scratch=scratch,
                need_waves=need_padded)
        padded_per_dev = None
        if need_padded:
            padded_per_dev = ((cols.two_mk * cols.fm) * hw.lane) * n_waves
        return latency, n_waves, padded_per_dev, True

    def _staircase_core_stacked(self, cols: _LayerColumns, w: np.ndarray,
                                need_padded: bool = True, out=None,
                                scratch=None):
        """Stacked counterpart of ``_staircase_core`` over a (rows, C) width
        block with (rows, 1) layer-constant columns.

        Same float operand order as the scalar path; identity factors the
        per-layer path skips are multiplied in uniformly (IEEE no-ops on
        the identity rows), so every element is bit-for-bit equal to the
        per-layer sweep of its row.

        ``need_padded=False`` lets fused backends skip the padded-FLOPs
        pass (latency-only callers); ``out`` receives the latency block in
        place when given.  The numpy path always computes padded FLOPs
        (it is an intermediate of the latency there anyway).
        """
        if self.backend != "numpy":
            res = self._stacked_fused(cols, w, need_padded, out, scratch)
            if res is not None:
                return res
        hw = self.hw
        nonneg = w.size == 0 or int(w.min()) >= 1
        per_dev = w if cols.all_so1 else -(-w // cols.shard_out)
        n_waves = _ceil_div_arr(per_dev, hw.lane, nonneg)
        n_pad = n_waves * hw.lane

        padded_per_dev = cols.two_mk * n_pad
        if not cols.all_fm1:
            padded_per_dev = padded_per_dev * cols.fm

        compute_s = padded_per_dev / hw.peak_flops_bf16
        elems = cols.mk + cols.k_plus_m * n_pad
        if cols.bytes_aligned:
            bytes_per_dev = elems * (cols.bits // 8)
        else:
            bytes_per_dev = elems * cols.bits // 8
        memory_s = bytes_per_dev / hw.hbm_bandwidth
        latency = np.maximum(compute_s, memory_s, out=out)
        return latency, n_waves, padded_per_dev, nonneg

    def latency_model_packed(
        self,
        layers: Sequence[LayerShape],
        w2d: np.ndarray,
        counts: np.ndarray,
    ) -> np.ndarray:
        """(L, C) latency matrix for a pre-packed width matrix (rows padded
        with any valid width past ``counts[i]``; pad cells compute ordinary
        staircase values the caller masks out).  The packed core under
        ``latency_model_batch``, exposed so hot callers (the optimizer's
        table build) can fill one matrix instead of L small arrays."""
        if len(layers) != w2d.shape[0]:
            raise ValueError("one width row per layer required")
        self.eval_calls += 1
        self.eval_points += int(np.asarray(counts).sum())
        n_layers, n_cols = w2d.shape
        cols = self._stack_columns(layers)
        lat = np.empty((n_layers, n_cols), dtype=np.float64)
        rows = max(1, _STACKED_CHUNK // max(1, n_cols))
        # per-call scratch: chunks share one set of work buffers, and
        # nothing returned from this loop aliases them past the call
        scratch: dict = {}
        for r0 in range(0, n_layers, rows):
            sl = slice(r0, r0 + rows)
            self._staircase_core_stacked(
                cols.block(sl), w2d[sl], need_padded=False, out=lat[sl],
                scratch=scratch)
        return lat

    def latency_model_batch(
        self,
        layers: Sequence[LayerShape],
        widths_per_layer: Sequence[Sequence[int]],
    ) -> list[np.ndarray]:
        """The latency columns of ``evaluate_model_batch`` alone — one
        stacked sweep over all layers, returned as a ragged list of row
        views (bit-identical to per-layer ``latency_batch`` calls).  This
        is the optimizer's model-level table-build fast path."""
        if len(layers) != len(widths_per_layer):
            raise ValueError("one width vector per layer required")
        w2d, counts = self.pack_widths(widths_per_layer)
        lat = self.latency_model_packed(layers, w2d, counts)
        return [lat[i, : int(counts[i])] for i in range(len(layers))]

    def evaluate_model_batch(
        self,
        layers: Sequence[LayerShape],
        widths_per_layer: Sequence[Sequence[int]],
    ) -> ModelStairTable:
        """Stacked staircase: one ``ModelStairTable`` over all layers x all
        candidate widths.  ``layer_table(i)`` is bit-for-bit what
        ``evaluate_batch(layers[i], widths_per_layer[i])`` returns;
        ``layers[i].width`` is ignored (the sweep variable is the width
        vector)."""
        if len(layers) != len(widths_per_layer):
            raise ValueError("one width vector per layer required")
        w2d, counts = self.pack_widths(widths_per_layer)
        self.eval_calls += 1
        self.eval_points += int(counts.sum())
        n_layers, n_cols = w2d.shape
        cols = self._stack_columns(layers)
        shape = (n_layers, n_cols)
        lat = np.empty(shape, dtype=np.float64)
        util = np.empty(shape, dtype=np.float64)
        thr = np.empty(shape, dtype=np.float64)
        waves = np.empty(shape, dtype=np.int64)
        flops = np.empty(shape, dtype=np.float64)
        padded = np.empty(shape, dtype=np.float64)
        rows = max(1, _STACKED_CHUNK // max(1, n_cols))
        for r0 in range(0, n_layers, rows):
            sl = slice(r0, r0 + rows)
            blk = cols.block(sl)
            w = w2d[sl]
            latency, n_waves, padded_per_dev, nonneg = \
                self._staircase_core_stacked(blk, w)

            useful = blk.two_td * w
            if not cols.all_fm1:
                useful = useful * blk.fm
            padded_total = padded_per_dev
            if not cols.all_si1:
                padded_total = padded_total * blk.shard_in
            if not cols.all_so1:
                padded_total = padded_total * blk.shard_out

            if nonneg:
                util[sl] = useful / padded_total
                thr[sl] = useful / latency
            else:
                util[sl] = np.divide(useful, padded_total,
                                     out=np.zeros_like(useful),
                                     where=padded_total != 0.0)
                thr[sl] = np.divide(useful, latency,
                                    out=np.zeros_like(useful),
                                    where=latency != 0.0)
            lat[sl] = latency
            waves[sl] = n_waves
            flops[sl] = useful
            padded[sl] = padded_total
        return ModelStairTable(
            layer_names=tuple(l.name for l in layers),
            widths=w2d, counts=counts,
            latency_s=lat, utilization=util, throughput=thr,
            waves=waves, flops=flops, padded_flops=padded,
        )


@dataclasses.dataclass(frozen=True)
class GridWave:
    blocks: int     # B: number of grid cells (thread blocks in the paper)
    waves: int      # W: ceil(B / S)
    latency_s: float  # L = dL * W


class GridWaveModel:
    """Paper Eq. 3 verbatim, for a Pallas kernel grid.

    A ``pallas_call`` with grid (gm, gn, gk) issues B = gm*gn*gk cells; cells
    are scheduled onto ``cores_per_chip`` cores, so L = dL * ceil(B / S).
    This is the direct TPU transcription of the paper's block->SM wave model
    and is what ``benchmarks/wave_verification.py`` checks against the
    analytic staircase (paper Fig. 5's B / W / L panels).
    """

    def __init__(self, hw: HardwareSpec, block_flops: float):
        self.hw = hw
        self.block_flops = block_flops
        # dL: one core processes one cell's FLOPs at peak.
        self.delta_l = block_flops / hw.peak_flops_bf16

    def blocks_for(self, m: int, n: int, k: int, bm: int, bn: int, bk: int) -> int:
        return ceil_div(m, bm) * ceil_div(n, bn) * ceil_div(k, bk)

    def evaluate(self, blocks: int) -> GridWave:
        waves = ceil_div(blocks, self.hw.cores_per_chip)
        return GridWave(blocks=blocks, waves=waves,
                        latency_s=self.delta_l * waves)


def staircase_edges(widths: np.ndarray, latency: np.ndarray) -> np.ndarray:
    """Right edges of each stair: the last width before latency increases.

    These are the paper's profile-derived optimal candidates (Fig. 6: the
    right edge point has max utilization and max throughput within a wave).
    """
    widths = np.asarray(widths, dtype=np.int64)
    latency = np.asarray(latency)
    if widths.size == 0:
        return np.array([], dtype=np.int64)
    rises = latency[1:] > latency[:-1] * (1 + 1e-9)
    edges = np.append(widths[:-1][rises], widths[-1])
    return np.unique(edges)
