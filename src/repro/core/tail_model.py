"""The latency-staircase / tail-effect model, adapted from GPU waves to TPU tiles.

Paper Eq. 3 models one conv layer as

    L = dL * ceil(B / S),      B = threads_per_filter * F / threads_per_block

i.e. work is quantized into *waves* of S SMs and a partial last wave (the GPU
tail) costs a full cycle.  On TPU the same ceil-quantization appears at three
levels (see DESIGN.md section 2):

  1. MXU/VPU tiles:  a (M, K) x (K, N) matmul issues
         ceil(M/Tm) * ceil(K/Tk) * ceil(N/Tn)
     systolic tile passes; the residual of each dim burns a full tile.
  2. Pallas grid "waves": grid cells map onto ``cores_per_chip`` cores,
     L = dL * ceil(num_cells / cores) — literally paper Eq. 3.
  3. Mesh shards: a dim d sharded n ways costs ceil(d/n) per device; every
     device pays the max (ragged) shard.

``WaveQuantizationModel`` composes (1) and (3) into per-layer staircase
functions L(width), U(width), T(width) — the quantities the paper profiles
with nvprof — and ``GridWaveModel`` implements (2) for the Fig. 5
verification benchmark.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.hardware import HardwareSpec


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One width-adjustable matmul layer: (tokens, d_in) @ (d_in, width).

    ``shard_in`` / ``shard_out`` are the mesh-axis sizes sharding ``d_in`` and
    ``width`` respectively (1 = unsharded).  ``tokens`` is the *per-device*
    token count (batch already sharded by data parallelism).  ``flop_multiplier``
    scales FLOPs for layers where one "width unit" does more than one MAC per
    token-input pair (e.g. GQA heads, experts).
    """

    name: str
    tokens: int
    d_in: int
    width: int
    shard_in: int = 1
    shard_out: int = 1
    dtype_bits: int = 16
    flop_multiplier: float = 1.0

    def with_width(self, width: int) -> "LayerShape":
        return dataclasses.replace(self, width=width)


@dataclasses.dataclass(frozen=True)
class StairPoint:
    width: int
    latency_s: float        # modeled L
    utilization: float      # paper's U: useful / (padded quantum) work
    throughput: float       # paper's T: FLOP/s achieved
    waves: int              # ceil count along the width dim
    flops: float            # useful (model) FLOPs
    padded_flops: float     # FLOPs actually executed incl. tile padding


class WaveQuantizationModel:
    """Closed-form staircase model L(width) = dL * ceil(width / Q)."""

    def __init__(self, hw: HardwareSpec):
        self.hw = hw

    # ---- quanta ---------------------------------------------------------
    def width_quantum(self, shard_out: int) -> int:
        """Q: widths that are multiples of this have zero tail."""
        return shard_out * self.hw.lane

    def padded_dim(self, d: int, shard: int, tile: int) -> int:
        """Per-device padded size of dim ``d`` sharded ``shard`` ways."""
        per_dev = ceil_div(d, shard)
        return ceil_div(per_dev, tile) * tile

    # ---- per-layer staircase -------------------------------------------
    def waves(self, layer: LayerShape) -> int:
        """Tile waves along the adjustable width dim (paper's ceil(B/S))."""
        per_dev = ceil_div(layer.width, layer.shard_out)
        return ceil_div(per_dev, self.hw.lane)

    def evaluate(self, layer: LayerShape) -> StairPoint:
        hw = self.hw
        sub = hw.sublane(layer.dtype_bits)
        m_pad = ceil_div(layer.tokens, sub) * sub
        k_pad = self.padded_dim(layer.d_in, layer.shard_in, hw.lane)
        n_waves = self.waves(layer)
        n_pad = n_waves * hw.lane

        useful = 2.0 * layer.tokens * layer.d_in * layer.width \
            * layer.flop_multiplier
        # Per-device padded work (d_in and width divided across shards).
        padded_per_dev = 2.0 * m_pad * k_pad * n_pad * layer.flop_multiplier
        padded_total = padded_per_dev * layer.shard_in * layer.shard_out

        compute_s = padded_per_dev / hw.peak_flops_bf16
        bytes_per_dev = (
            m_pad * k_pad + k_pad * n_pad + m_pad * n_pad
        ) * layer.dtype_bits // 8
        memory_s = bytes_per_dev / hw.hbm_bandwidth
        latency = max(compute_s, memory_s)

        util = useful / padded_total if padded_total else 0.0
        return StairPoint(
            width=layer.width,
            latency_s=latency,
            utilization=util,
            throughput=useful / latency if latency else 0.0,
            waves=n_waves,
            flops=useful,
            padded_flops=padded_total,
        )

    def staircase(
        self, layer: LayerShape, widths: Sequence[int]
    ) -> list[StairPoint]:
        return [self.evaluate(layer.with_width(int(w))) for w in widths]

    def staircase_arrays(self, layer: LayerShape, widths: Sequence[int]):
        pts = self.staircase(layer, widths)
        return (
            np.array([p.width for p in pts]),
            np.array([p.latency_s for p in pts]),
            np.array([p.utilization for p in pts]),
            np.array([p.throughput for p in pts]),
        )


@dataclasses.dataclass(frozen=True)
class GridWave:
    blocks: int     # B: number of grid cells (thread blocks in the paper)
    waves: int      # W: ceil(B / S)
    latency_s: float  # L = dL * W


class GridWaveModel:
    """Paper Eq. 3 verbatim, for a Pallas kernel grid.

    A ``pallas_call`` with grid (gm, gn, gk) issues B = gm*gn*gk cells; cells
    are scheduled onto ``cores_per_chip`` cores, so L = dL * ceil(B / S).
    This is the direct TPU transcription of the paper's block->SM wave model
    and is what ``benchmarks/wave_verification.py`` checks against the
    analytic staircase (paper Fig. 5's B / W / L panels).
    """

    def __init__(self, hw: HardwareSpec, block_flops: float):
        self.hw = hw
        self.block_flops = block_flops
        # dL: one core processes one cell's FLOPs at peak.
        self.delta_l = block_flops / hw.peak_flops_bf16

    def blocks_for(self, m: int, n: int, k: int, bm: int, bn: int, bk: int) -> int:
        return ceil_div(m, bm) * ceil_div(n, bn) * ceil_div(k, bk)

    def evaluate(self, blocks: int) -> GridWave:
        waves = ceil_div(blocks, self.hw.cores_per_chip)
        return GridWave(blocks=blocks, waves=waves,
                        latency_s=self.delta_l * waves)


def staircase_edges(widths: np.ndarray, latency: np.ndarray) -> np.ndarray:
    """Right edges of each stair: the last width before latency increases.

    These are the paper's profile-derived optimal candidates (Fig. 6: the
    right edge point has max utilization and max throughput within a wave).
    """
    widths = np.asarray(widths)
    latency = np.asarray(latency)
    edges = []
    for i in range(len(widths) - 1):
        if latency[i + 1] > latency[i] * (1 + 1e-9):
            edges.append(int(widths[i]))
    if len(widths):
        edges.append(int(widths[-1]))
    return np.array(sorted(set(edges)))
