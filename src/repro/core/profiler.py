"""Per-layer L/U/T table builders — the paper's "Step 1: pre-analysis".

The paper profiles each layer's latency / SM-utilization / throughput over a
width sweep with nvprof.  Off-GPU we derive the same tables from three
sources (cross-checked against each other in tests):

  * ``analytic``     — the wave-quantization closed form (tail_model.py)
  * ``hlo``          — lower+compile the layer at each width on the current
                       backend and read cost_analysis() FLOPs (validates the
                       useful-FLOPs accounting; CPU XLA does not tile-pad, so
                       padding comes from the analytic overlay)
  * ``pallas_grid``  — grid-cell counts for a kernel's BlockSpec (the literal
                       ceil(B/S) of paper Eq. 3)

``analytic_profile_stack`` profiles a whole model (all layers x all widths)
in one stacked sweep; persisting these tables across processes is
``repro.core.table_cache``'s job.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.hardware import HardwareSpec
from repro.core.tail_model import (
    GridWaveModel, LayerShape, WaveQuantizationModel, ceil_div,
)


@dataclasses.dataclass
class LayerProfile:
    name: str
    widths: np.ndarray
    latency_s: np.ndarray
    utilization: np.ndarray
    throughput: np.ndarray
    waves: np.ndarray
    source: str

    def as_table(self) -> str:
        rows = ["width,latency_us,utilization,throughput_tflops,waves"]
        for i in range(len(self.widths)):
            rows.append(
                f"{self.widths[i]},{self.latency_s[i] * 1e6:.4f},"
                f"{self.utilization[i]:.4f},"
                f"{self.throughput[i] / 1e12:.4f},{self.waves[i]}"
            )
        return "\n".join(rows)


def analytic_profile_stack(
    hw: HardwareSpec,
    layers: Sequence[LayerShape],
    widths_per_layer: Sequence[Sequence[int]],
) -> list[LayerProfile]:
    """All layers x all widths in ONE stacked model call.

    The model-level counterpart of ``analytic_profile``: a whole model's
    pre-analysis (the paper's Step 1 over every layer) is a single
    ``evaluate_model_batch`` sweep instead of one dispatch per layer; each
    returned profile is bit-for-bit what the per-layer sweep yields.
    """
    model = WaveQuantizationModel(hw)
    stacked = model.evaluate_model_batch(layers, widths_per_layer)
    out = []
    for i, layer in enumerate(layers):
        t = stacked.layer_table(i)
        out.append(LayerProfile(
            name=layer.name,
            widths=t.widths,
            latency_s=t.latency_s,
            utilization=t.utilization,
            throughput=t.throughput,
            waves=t.waves,
            source="analytic",
        ))
    return out


def analytic_profile(hw: HardwareSpec, layer: LayerShape,
                     widths: Sequence[int]) -> LayerProfile:
    """One-layer wrapper over the stacked engine — no per-width loop."""
    return analytic_profile_stack(hw, [layer], [widths])[0]


# One module-level jit for the profiled matmul: hoisted out of the sweep
# loop so its trace/lowering caches are shared across every width (a fresh
# ``jax.jit(lambda ...)`` per width defeats them all) and across repeated
# ``hlo_profile`` calls in one process.
_MATMUL_JIT = None


def _matmul_jit():
    global _MATMUL_JIT
    if _MATMUL_JIT is None:
        import jax
        _MATMUL_JIT = jax.jit(lambda a, b: a @ b)
    return _MATMUL_JIT


def hlo_profile(hw: HardwareSpec, layer: LayerShape,
                widths: Sequence[int]) -> LayerProfile:
    """Compile (tokens, d_in) @ (d_in, w) per width; read HLO FLOPs.

    Latency is HLO_FLOPs (with analytic tile padding applied to the width
    dim) over peak — i.e. the compiled artifact supplies the useful work and
    the hardware model supplies the quantization, mirroring how the paper
    derives throughput from "theoretical FLOPs and profiled latency" (4.3
    Step 1).
    """
    import jax
    import jax.numpy as jnp

    model = WaveQuantizationModel(hw)
    # Analytic overlay for the whole sweep in one batched call; the per-width
    # loop below only pays for compilation + cost_analysis.
    tbl = model.evaluate_batch(layer, widths)
    jitted = _matmul_jit()
    lat, util, thr, wav = [], [], [], []
    for i, w in enumerate(widths):
        x = jax.ShapeDtypeStruct((layer.tokens, layer.d_in), jnp.bfloat16)
        wt = jax.ShapeDtypeStruct((layer.d_in, int(w)), jnp.bfloat16)
        compiled = jitted.lower(x, wt).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        useful = float(ca.get("flops", 2.0 * layer.tokens * layer.d_in * w))
        pt = tbl.point(i)
        lat.append(pt.latency_s)
        util.append(useful / pt.padded_flops if pt.padded_flops else 0.0)
        thr.append(useful / pt.latency_s if pt.latency_s else 0.0)
        wav.append(pt.waves)
    assert len(lat) == len(widths), "profile rows must match the sweep"
    return LayerProfile(
        name=layer.name, widths=np.asarray(list(widths)),
        latency_s=np.asarray(lat), utilization=np.asarray(util),
        throughput=np.asarray(thr), waves=np.asarray(wav), source="hlo",
    )


def pallas_grid_profile(hw: HardwareSpec, layer: LayerShape,
                        widths: Sequence[int],
                        block_m: int = 256, block_n: int = 256,
                        block_k: int = 512) -> LayerProfile:
    """Grid-cell wave counts for the tiled-matmul kernel's BlockSpec."""
    block_flops = 2.0 * block_m * block_n * block_k
    gw = GridWaveModel(hw, block_flops)
    lat, util, thr, wav, blocks = [], [], [], [], []
    for w in widths:
        per_dev_w = ceil_div(int(w), layer.shard_out)
        b = gw.blocks_for(layer.tokens, per_dev_w, layer.d_in,
                          block_m, block_n, block_k)
        g = gw.evaluate(b)
        useful = 2.0 * layer.tokens * layer.d_in * w
        padded = g.waves * hw.cores_per_chip * block_flops \
            * layer.shard_out
        lat.append(g.latency_s)
        util.append(min(useful / padded, 1.0) if padded else 0.0)
        thr.append(useful / g.latency_s if g.latency_s else 0.0)
        wav.append(g.waves)
        blocks.append(b)
    return LayerProfile(
        name=layer.name, widths=np.asarray(list(widths)),
        latency_s=np.asarray(lat), utilization=np.asarray(util),
        throughput=np.asarray(thr), waves=np.asarray(wav),
        source="pallas_grid",
    )
