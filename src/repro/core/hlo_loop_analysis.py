"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so a scanned
64-layer model reports 1/64th of its FLOPs — and collectives inside the
layer loop are likewise undercounted.  This module parses the
post-optimization HLO text, recovers each while loop's trip count from its
condition, propagates execution multipliers through the call graph
(while/fusion/call/conditional), and recomputes:

  * dot FLOPs, exactly, per computation x multiplier;
  * collective result bytes / ring traffic, per op x multiplier.

Verified against unrolled references in tests/test_loop_analysis.py.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.hlo_analysis import (
    COLLECTIVE_KINDS, CollectiveOp, CollectiveSummary, _DTYPE_BYTES,
    _GROUPS_IOTA_RE, _GROUPS_LIST_RE, _OP_RE, _SHAPE_RE, shape_bytes,
)

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_FIRST_SHAPE = re.compile(
    r"^\(?\s*(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# Operand lists may carry full type annotations depending on the XLA
# version ("dot(f32[128,128]{1,0} %a, ...)" vs "dot(%a, ...)"); the lazy
# [^%()]*? prefix skips the dtype[shape]{layout} token (which may itself
# contain commas) up to the %name that follows it.
_OPND = r"[^%()]*?%([\w.\-]+)"
_DOT_RE = re.compile(
    r"\bdot\(\s*" + _OPND + r"\s*,\s*" + _OPND + r"\s*\)(.*)$")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_COMP_RE = re.compile(
    r"(?:true_computation|false_computation)=%([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")
_COMPARE_RE = re.compile(
    r"compare\(\s*[^%()]*?%([\w.\-]+)\s*,\s*[^%()]*?"
    r"%([\w.\-]+)\s*\),\s*direction=(\w+)")


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    shapes: Dict[str, Tuple[str, Tuple[int, ...]]]  # %name -> (dtype, dims)


def _parse_shape(txt: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    m = _FIRST_SHAPE.match(txt.strip())
    if not m:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",")) \
        if m.group(2) else ()
    return m.group(1), dims


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), lines=[], shapes={})
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            sh = _parse_shape(dm.group(2))
            if sh:
                cur.shapes[dm.group(1)] = sh
    if entry:
        comps["__entry__"] = comps[entry]
    return comps


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Recover the loop bound from the condition computation.

    jax scans compare an induction var (starting at 0) LT a constant; the
    compare may sit inside a wrapped fusion.  Fallback: the max s32
    constant in the condition; final fallback 1 (flagged by caller)."""
    # direct compare in the cond
    for line in cond.lines:
        cm = _COMPARE_RE.search(line)
        if cm and cm.group(3) in ("LT", "GT"):
            for opnd in (cm.group(2), cm.group(1)):
                defn = _find_def(cond, opnd)
                if defn is not None:
                    k = re.search(r"constant\((\d+)\)", defn)
                    if k:
                        return int(k.group(1))
    # compare inside a called fusion: any s32 constant at cond level
    consts = [int(m.group(1)) for line in cond.lines
              for m in _CONST_RE.finditer(line)]
    # also search one level of called computations for constants
    for line in cond.lines:
        for cm in _CALLS_RE.finditer(line):
            callee = comps.get(cm.group(1))
            if callee:
                consts += [int(m.group(1)) for ln in callee.lines
                           for m in _CONST_RE.finditer(ln)]
    return max(consts) if consts else 1


def _find_def(comp: Computation, name: str) -> Optional[str]:
    for line in comp.lines:
        dm = _DEF_RE.match(line)
        if dm and dm.group(1) == name:
            return dm.group(2)
    return None


def computation_multipliers(hlo: str) -> Tuple[Dict[str, float],
                                               Dict[str, Computation]]:
    comps = split_computations(hlo)
    entry = comps.get("__entry__")
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        for name in comps:
            mult[name] = 1.0
        return mult, comps
    mult[entry.name] = 1.0

    # propagate in dependency order (iterate to fixpoint; call DAG small)
    for _ in range(64):
        changed = False
        for name, comp in comps.items():
            if name == "__entry__" or mult[name] == 0.0:
                continue
            m = mult[name]
            for line in comp.lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond_n, body_n = wm.group(1), wm.group(2)
                    trip = _trip_count(comps[cond_n], comps)
                    for callee, factor in ((body_n, trip),
                                           (cond_n, trip + 1)):
                        new = m * factor
                        if new > mult[callee]:
                            mult[callee] = new
                            changed = True
                    continue
                for cm in _CALLS_RE.finditer(line):
                    if mult[cm.group(1)] < m:
                        mult[cm.group(1)] = m
                        changed = True
                bm = _BRANCHES_RE.search(line)
                if bm:
                    for b in re.findall(r"%([\w.\-]+)", bm.group(1)):
                        if mult[b] < m:
                            mult[b] = m
                            changed = True
                for tm in _TF_COMP_RE.finditer(line):
                    if mult[tm.group(1)] < m:
                        mult[tm.group(1)] = m
                        changed = True
        if not changed:
            break
    return mult, comps


def _dot_flops(comp: Computation, line: str) -> float:
    dm = _DOT_RE.search(line)
    if not dm:
        return 0.0
    defm = _DEF_RE.match(line)
    if not defm:
        return 0.0
    res = _parse_shape(defm.group(2))
    lhs = comp.shapes.get(dm.group(1))
    if res is None or lhs is None:
        return 0.0
    cm = _CONTRACT_RE.search(dm.group(3))
    if not cm:
        return 0.0
    k = 1
    if cm.group(1):
        for idx in cm.group(1).split(","):
            k *= lhs[1][int(idx)]
    n_out = 1
    for d in res[1]:
        n_out *= d
    return 2.0 * n_out * k


@dataclasses.dataclass
class LoopAwareCost:
    flops: float                 # loop-corrected dot FLOPs
    flops_uncorrected: float     # same ops counted once (sanity ref)
    bytes_accessed: float        # loop-corrected operand+result bytes
    bytes_uncorrected: float
    collectives: CollectiveSummary
    trip_warnings: int = 0


_FREE_OPS = ("parameter(", "get-tuple-element(", "tuple(", "bitcast(",
             "constant(", "after-all(", "iota(", " while(", "conditional(",
             "optimization-barrier(", " copy(")
_SLICE_OPS = ("dynamic-slice(", " slice(", "gather(")
_REF_RE = re.compile(r"%([\w.\-]+)")


def _shape_nbytes(sh) -> int:
    n = 1
    for d in sh[1]:
        n *= d
    return n * _DTYPE_BYTES[sh[0]]


def _fusion_param_traffic(called: Computation) -> int:
    """HBM read traffic of a fused kernel's inputs, slice-aware: a param
    consumed only by (dynamic-)slice/gather ops is read at slice size."""
    total = 0
    for line in called.lines:
        dm = _DEF_RE.match(line)
        if dm is None or "parameter(" not in dm.group(2):
            continue
        name = dm.group(1)
        sh = called.shapes.get(name)
        if sh is None:
            continue
        slice_bytes = 0
        all_slices = True
        used = False
        for ln in called.lines:
            um = _DEF_RE.match(ln)
            if um is None or um.group(1) == name:
                continue
            rhs = um.group(2)
            if not re.search(rf"%{re.escape(name)}\b", rhs):
                continue
            used = True
            if any(op in rhs for op in _SLICE_OPS):
                r = _parse_shape(rhs)
                if r:
                    slice_bytes += _shape_nbytes(r)
            else:
                all_slices = False
        total += slice_bytes if (used and all_slices and slice_bytes) \
            else _shape_nbytes(sh)
    return total


def _op_bytes(comp: Computation, line: str,
              comps: Dict[str, Computation]) -> int:
    """HBM traffic of one op (HloCostAnalysis-style, with TPU-realistic
    refinements: loop-carry copies alias, slices read slice-sized data,
    dynamic-update-slice writes only the update)."""
    dm = _DEF_RE.match(line)
    if dm is None:
        return 0
    rhs = dm.group(2)
    if any(op in rhs for op in _FREE_OPS):
        return 0
    if "vmem_resident" in rhs:
        # region tagged as VMEM-resident in the Pallas kernel (ops.py) —
        # no HBM traffic on the target hardware
        return 0
    res = _parse_shape(rhs)
    res_b = _shape_nbytes(res) if res else 0

    if any(op in rhs for op in _SLICE_OPS):
        return 2 * res_b

    par = rhs.find("(")
    operand_shapes = []
    if par >= 0:
        args = rhs[par + 1:].split(")", 1)[0]
        for rm in _REF_RE.finditer(args):
            sh = comp.shapes.get(rm.group(1))
            if sh:
                operand_shapes.append(sh)

    if "dynamic-update-slice(" in rhs:
        # in-place write of the update slice (buffer aliased on TPU)
        upd = [_shape_nbytes(s) for s in operand_shapes[1:]
               if _shape_nbytes(s) > 4]
        return 2 * (min(upd) if upd else res_b)

    if "fusion(" in rhs:
        cm = _CALLS_RE.search(rhs)
        if cm and cm.group(1) in comps:
            called = comps[cm.group(1)]
            tagged = sum("vmem_resident" in ln for ln in called.lines)
            opl = sum(1 for ln in called.lines if _DEF_RE.match(ln))
            if opl and tagged / opl > 0.5:
                return 0
            return res_b + _fusion_param_traffic(called)

    return res_b + sum(_shape_nbytes(s) for s in operand_shapes)


def _fused_comp_names(comps: Dict[str, Computation]) -> set:
    """Computations inlined into a caller kernel (fusions, reducers): their
    internal ops do not individually touch HBM."""
    out = set()
    for name, comp in comps.items():
        for line in comp.lines:
            for cm in _CALLS_RE.finditer(line):
                out.add(cm.group(1))
    return out


def analyze(hlo: str) -> LoopAwareCost:
    mult, comps = computation_multipliers(hlo)
    fused = _fused_comp_names(comps)
    flops = 0.0
    flops_raw = 0.0
    bts = 0.0
    bts_raw = 0.0
    coll_ops: List[CollectiveOp] = []
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = max(mult.get(name, 0.0), 0.0)
        count_bytes = name not in fused
        for line in comp.lines:
            f = _dot_flops(comp, line)
            if f:
                flops += f * m
                flops_raw += f
            if count_bytes:
                b = _op_bytes(comp, line, comps)
                if b:
                    bts += b * m
                    bts_raw += b
            om = _OP_RE.search(line)
            if om and om.group(2) != "-done":
                eq = line.find("=")
                before = line[eq + 1: line.find(om.group(1), eq)]
                rb = sum(shape_bytes(sm.group(1), sm.group(2))
                         for sm in _SHAPE_RE.finditer(before))
                gm = _GROUPS_LIST_RE.search(line)
                if gm:
                    gs = len(gm.group(1).split(","))
                else:
                    gm2 = _GROUPS_IOTA_RE.search(line)
                    if gm2:
                        dims = [int(x) for x in gm2.group(1).split(",")]
                        gs = 1
                        for d in dims[1:]:
                            gs *= d
                        gs = max(gs, 1)
                    else:
                        gs = 1
                for _ in range(max(int(round(m)), 1)):
                    coll_ops.append(CollectiveOp(
                        kind=om.group(1), result_bytes=rb, group_size=gs,
                        line=line.strip()))
    return LoopAwareCost(flops=flops, flops_uncorrected=flops_raw,
                         bytes_accessed=bts, bytes_uncorrected=bts_raw,
                         collectives=CollectiveSummary(ops=coll_ops))
