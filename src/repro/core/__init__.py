"""Core: the paper's contribution — tail-effect modeling and elimination."""

from repro.core.hardware import (
    HardwareSpec, TPU_V5E, TPU_V4, TPU_V5P, TPU_LITE, get_hardware,
)
from repro.core.tail_model import (
    LayerShape, StairPoint, StairTable, ModelStairTable,
    WaveQuantizationModel, GridWaveModel, staircase_edges, ceil_div,
)
from repro.core.candidates import (
    analytic_candidates, profile_candidates, model_profile_candidates,
    realizable_candidates, snap_down, snap_up, snap_nearest,
)
from repro.core.tail_optimizer import (
    TailEffectOptimizer, TunableLayer, OptimizationResult, Move,
    discretize_pruning_space, tunable_from_profile,
)
from repro.core.table_cache import ProfileTableCache, hardware_fingerprint
from repro.core.plan_address import ModuleRef, plan_key, snap_heads
from repro.core.roofline import RooflineReport, build_report
from repro.core.hlo_analysis import (
    parse_collectives, CollectiveSummary, cost_summary, count_ops,
)

__all__ = [
    "HardwareSpec", "TPU_V5E", "TPU_V4", "TPU_V5P", "TPU_LITE",
    "get_hardware", "LayerShape", "StairPoint", "StairTable",
    "ModelStairTable", "WaveQuantizationModel",
    "GridWaveModel", "staircase_edges", "ceil_div", "analytic_candidates",
    "profile_candidates", "model_profile_candidates",
    "realizable_candidates", "snap_down", "snap_up", "snap_nearest",
    "TailEffectOptimizer", "TunableLayer", "OptimizationResult", "Move",
    "discretize_pruning_space", "tunable_from_profile",
    "ProfileTableCache", "hardware_fingerprint", "RooflineReport",
    "build_report", "ModuleRef", "plan_key", "snap_heads",
    "parse_collectives", "CollectiveSummary", "cost_summary", "count_ops",
]
