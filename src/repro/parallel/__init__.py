from repro.parallel.sharding import (
    LOGICAL_RULES, logical_to_pspec, shard, param_pspecs, param_shardings,
    activity, ShardingContext, current_mesh, set_mesh, batch_axes,
)

__all__ = [
    "LOGICAL_RULES", "logical_to_pspec", "shard", "param_pspecs",
    "param_shardings", "activity", "ShardingContext", "current_mesh",
    "set_mesh", "batch_axes",
]
