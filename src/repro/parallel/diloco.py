"""DiLoCo-style multi-pod training: each pod takes H independent inner
AdamW steps on its own data shard, then pods synchronize with an outer
Nesterov-momentum step over the *delta* — cutting cross-pod traffic by H
and shrinking it further with int8+EF compression (compression.py).

Representation: the per-pod replicas are a leading ``n_pods`` axis on every
param/optimizer leaf, sharded over the ``pod`` mesh axis; the inner step is
vmapped over that axis, so XLA partitions it with ZERO cross-pod
collectives (verified by tests/test_diloco.py parsing the compiled HLO).
The outer sync is the only cross-pod communication.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.parallel.compression import (
    compressed_psum_tree, zero_error_state,
)


@dataclasses.dataclass(frozen=True)
class DilocoConfig:
    inner_steps: int = 8          # H
    outer_lr: float = 0.7
    outer_momentum: float = 0.9   # Nesterov
    compress: bool = True         # int8+EF on the pod axis


def replicate_for_pods(tree, n_pods: int):
    """Stack a per-pod leading axis (all pods start from the anchor)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_pods, *x.shape)), tree)


def init_outer_state(anchor):
    return {
        "anchor": anchor,
        "momentum": jax.tree.map(lambda x: jnp.zeros_like(
            x, dtype=jnp.float32), anchor),
        "err": zero_error_state(anchor),
    }


def build_inner_steps(train_step: Callable, h: int) -> Callable:
    """H sequential inner steps, vmapped over the leading pod axis.

    batch: (n_pods, h, local_batch, ...) — per pod, per inner step.
    """

    def pod_inner(params, opt_state, batches, step0):
        def body(carry, i):
            params, opt_state = carry
            mb = jax.tree.map(lambda x: x[i], batches)
            params, opt_state, metrics = train_step(params, opt_state, mb,
                                                    step0 + i)
            return (params, opt_state), metrics["loss"]

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), jnp.arange(h))
        return params, opt_state, losses

    return jax.vmap(pod_inner, in_axes=(0, 0, 0, None))


def outer_step(pod_params, outer, dcfg: DilocoConfig, mesh: Mesh):
    """Average per-pod deltas (compressed over the pod axis), take an outer
    Nesterov step on the anchor, re-broadcast to all pods."""
    anchor = outer["anchor"]

    def f(pp, anc, mom, err):
        delta = jax.tree.map(
            lambda p, a: (a.astype(jnp.float32)
                          - p[0].astype(jnp.float32)), pp, anc)
        # p has a leading local pod axis of size 1 inside shard_map
        if dcfg.compress:
            delta, err = compressed_psum_tree(delta, err, "pod", mean=True)
        else:
            delta = jax.tree.map(lambda d: jax.lax.pmean(d, "pod"), delta)
        mom = jax.tree.map(
            lambda m, d: dcfg.outer_momentum * m + d.astype(jnp.float32),
            mom, delta)
        # Nesterov: step along momentum + current delta
        new_anchor = jax.tree.map(
            lambda a, m, d: (a.astype(jnp.float32)
                             - dcfg.outer_lr * (dcfg.outer_momentum * m
                                                + d.astype(jnp.float32))
                             ).astype(a.dtype),
            anc, mom, delta)
        n_pods_local = pp and 1
        new_pp = jax.tree.map(
            lambda a, p: jnp.broadcast_to(a[None], p.shape).astype(p.dtype),
            new_anchor, pp)
        return new_pp, new_anchor, mom, err

    if "pod" not in mesh.axis_names:
        raise ValueError("outer_step needs a 'pod' mesh axis")

    in_specs = (
        jax.tree.map(lambda _: P("pod"), pod_params),
        jax.tree.map(lambda _: P(), anchor),
        jax.tree.map(lambda _: P(), outer["momentum"]),
        jax.tree.map(lambda _: P(), outer["err"]),
    )
    out_specs = (
        jax.tree.map(lambda _: P("pod"), pod_params),
        jax.tree.map(lambda _: P(), anchor),
        jax.tree.map(lambda _: P(), outer["momentum"]),
        jax.tree.map(lambda _: P(), outer["err"]),
    )
    new_pp, new_anchor, mom, err = shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(pod_params, anchor, outer["momentum"], outer["err"])
    return new_pp, {"anchor": new_anchor, "momentum": mom, "err": err}
