"""Logical-axis sharding rules (MaxText-style) for the (pod, data, model) mesh.

Models annotate activations with *logical* axis names via ``shard(x, ...)``;
parameters get PartitionSpecs from path-based rules in ``param_pspecs``.
When no mesh is active (CPU smoke tests) everything is a no-op, so the same
model code runs on one device and on the 512-chip production mesh.

Physical axes:
  pod    — across pods (pure data parallelism; gradient all-reduce crosses DCI)
  data   — within-pod data parallelism + FSDP (params/optimizer sharded)
  model  — tensor parallelism (heads / d_ff / vocab / experts / decode-KV-seq)
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> physical mesh axes (None = replicate)
LOGICAL_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,          # activations keep d_model replicated under TP
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",      # EP: experts sharded over the TP axis
    "kv_seq": "model",      # decode KV cache: sequence-parallel
    "act_seq": None,        # residual-stream seq dim (Megatron-SP variant)
    "fsdp": "data",         # weight d_model dims sharded for ZeRO-3
    "conv_k": None,
    "state": None,
}

_state = threading.local()


def set_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    _state.mesh = mesh
    _state.rules = dict(LOGICAL_RULES, **(rules or {}))


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> dict:
    return getattr(_state, "rules", LOGICAL_RULES)


@contextlib.contextmanager
def activity(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh for shard() annotations within the block."""
    prev_mesh = current_mesh()
    prev_rules = getattr(_state, "rules", None)
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        _state.mesh = prev_mesh
        if prev_rules is not None:
            _state.rules = prev_rules


class ShardingContext:
    """Bound (mesh, rules) pair — handed to launch code."""

    def __init__(self, mesh: Mesh, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(LOGICAL_RULES, **(rules or {}))

    def pspec(self, *logical_axes) -> P:
        return logical_to_pspec(logical_axes, self.rules, self.mesh)

    def sharding(self, *logical_axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(*logical_axes))


def _filter_axes(axes, mesh: Optional[Mesh]):
    """Drop physical axes not present in the mesh (e.g. 'pod' on 2D mesh)."""
    if mesh is None:
        return axes
    names = set(mesh.axis_names)
    if isinstance(axes, tuple):
        kept = tuple(a for a in axes if a in names)
        return kept if kept else None
    return axes if axes in names else None


def logical_to_pspec(logical_axes, rules: Optional[dict] = None,
                     mesh: Optional[Mesh] = None) -> P:
    rules = rules or current_rules()
    mesh = mesh or current_mesh()
    phys = []
    for ax in logical_axes:
        if ax is None:
            phys.append(None)
        else:
            phys.append(_filter_axes(rules.get(ax), mesh))
    return P(*phys)


def batch_axes(mesh: Optional[Mesh] = None):
    """Physical axes carrying the batch dim (for data sharding / DP size)."""
    return _filter_axes(current_rules().get("batch"), mesh or current_mesh())


def shard(x: jax.Array, *logical_axes) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_to_pspec(logical_axes, current_rules(), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs, by leaf path.  Paths look like
# "decoder/blocks_0/attn/wq", "decoder/blocks_0/moe/experts/w_up", ...
# Order matters: first match wins.
# ---------------------------------------------------------------------------
_PARAM_RULES: list = [
    # embeddings
    (r"tok_emb$",            ("vocab", "fsdp")),
    (r"out_emb$",            ("fsdp", "vocab")),
    (r"pos_emb$",            (None, "fsdp")),
    # attention (kv_heads has its own rule: archs with n_kv < |model| or
    # n_heads % |model| != 0 replicate that axis — see input_specs)
    (r"attn/wq$",            ("fsdp", "heads", None)),   # (D, H, dh)
    (r"attn/w(k|v)$",        ("fsdp", "kv_heads", None)),
    (r"attn/wo$",            ("heads", None, "fsdp")),   # (H, dh, D)
    (r"attn/bq$",            ("heads", None)),
    (r"attn/b(k|v)$",        ("kv_heads", None)),
    (r"attn/bo$",            (None,)),
    (r"attn/(q|k)_norm$",    (None,)),
    # dense mlp
    (r"mlp/w_(gate|up)$",    ("fsdp", "mlp")),
    (r"mlp/w_down$",         ("mlp", "fsdp")),
    (r"mlp/b_(gate|up)$",    ("mlp",)),
    (r"mlp/b_down$",         (None,)),
    # MoE
    (r"moe/router$",         ("fsdp", None)),
    (r"moe/experts/w_(gate|up)$", ("expert", "fsdp", None)),
    (r"moe/experts/w_down$", ("expert", None, "fsdp")),
    (r"moe/shared/w_(gate|up)$",  ("fsdp", "mlp")),
    (r"moe/shared/w_down$",  ("mlp", "fsdp")),
    # RG-LRU (griffin recurrent block)
    (r"rglru/w_(x|gate)$",   ("fsdp", "mlp")),           # in-projections
    (r"rglru/w_out$",        ("mlp", "fsdp")),
    (r"rglru/conv_w$",       ("conv_k", "mlp")),
    (r"rglru/conv_b$",       ("mlp",)),
    (r"rglru/(a_param|in_gate_w|rec_gate_w)$", ("mlp", None, None)),
    (r"rglru/(in_gate_b|rec_gate_b)$",         ("mlp", None)),
    # RWKV6
    (r"rwkv/w_(r|k|v|g)$",   ("fsdp", "heads", None)),
    (r"rwkv/w_o$",           ("heads", None, "fsdp")),
    (r"rwkv/(decay_w|bonus_u)$", ("heads", None)),
    (r"rwkv/mix_.*$",        (None,)),
    (r"rwkv/decay_lora_(a)$", ("fsdp", None)),
    (r"rwkv/decay_lora_(b)$", (None, "heads", None)),
    (r"rwkv/ln_x/.*$",       (None,)),
    (r"cmix/w_in$",          ("fsdp", "mlp")),
    (r"cmix/w_out$",         ("mlp", "fsdp")),
    # norms & scalars
    (r"(norm|norm1|norm2|norm3|final_norm|ln)/(scale|bias)$", (None,)),
    (r".*(scale|bias)$",     (None,)),
]


def _spec_for_path(path: str, ndim: int, rules: dict,
                   mesh: Optional[Mesh]) -> P:
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            axes = logical[:ndim]
            # pad to ndim
            axes = tuple(axes) + (None,) * (ndim - len(axes))
            return logical_to_pspec(axes, rules, mesh)
    return P()   # replicate unknowns


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(params, rules: Optional[dict] = None,
                 mesh: Optional[Mesh] = None):
    """Tree of PartitionSpecs congruent with ``params``.

    Stacked-layer leaves (under a 'blocks'/'units' scan stack) have a
    leading layer dim — detected by path and given a leading None.
    """
    rules = dict(current_rules(), **(rules or {}))

    def _axis_size(axes) -> int:
        if axes is None or mesh is None:
            return 1
        n = 1
        for a in ((axes,) if isinstance(axes, str) else axes):
            n *= mesh.shape[a]
        return n

    def _guard(p: P, shape) -> P:
        """Replicate any dim a mesh axis does not evenly divide (e.g.
        vocab=49155 on TP=16) — the honest 'ragged shard' fallback; the
        perf pass shows the paper's pad-to-quantum fix instead."""
        out = []
        for i, axes in enumerate(p):
            n = _axis_size(axes)
            out.append(axes if (n <= 1 or shape[i] % n == 0) else None)
        return P(*out)

    def spec(path, leaf):
        ps = _path_str(path)
        ndim = leaf.ndim
        stacked = "/stack/" in f"/{ps}/"
        if stacked:
            inner = _spec_for_path(ps, ndim - 1, rules, mesh)
            return _guard(P(None, *inner), leaf.shape)
        return _guard(_spec_for_path(ps, ndim, rules, mesh), leaf.shape)

    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params, mesh: Mesh, rules: Optional[dict] = None):
    specs = param_pspecs(params, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
