"""Gradient compression for slow cross-pod links.

``compressed_psum`` quantizes a pytree to int8 (per-leaf scale shared
across the group via pmax) with error feedback, then all-reduces the int8
payload in int16 accumulation — 2x wire bytes vs fp32 even before EF, and
the EF buffer makes the quantization error telescoping instead of biased.
Used by the DiLoCo outer step (diloco.py) for the pod axis, where the
inter-pod DCI is ~10x slower than in-pod ICI.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_ef(x: jax.Array, err: jax.Array, axis_name: str):
    """Quantize (x + err) to int8 with a group-consistent scale.

    Returns (q int8, scale f32 scalar, new_err)."""
    xe = x.astype(jnp.float32) + err.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xe))
    absmax = jax.lax.pmax(absmax, axis_name)      # identical scale group-wide
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xe / scale), -127, 127).astype(jnp.int8)
    new_err = xe - q.astype(jnp.float32) * scale
    return q, scale, new_err


def compressed_psum_leaf(x, err, axis_name: str, mean: bool = True):
    """int8+EF psum of one leaf inside shard_map/pmap context."""
    q, scale, new_err = quantize_ef(x, err, axis_name)
    # int16 accumulation: exact for group sizes <= 256
    total = jax.lax.psum(q.astype(jnp.int16), axis_name)
    out = total.astype(jnp.float32) * scale
    if mean:
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        out = out / n.astype(jnp.float32)
    return out.astype(x.dtype), new_err


def compressed_psum_tree(tree, err_tree, axis_name: str, mean: bool = True):
    flat, tdef = jax.tree.flatten(tree)
    errs = tdef.flatten_up_to(err_tree)
    outs, new_errs = [], []
    for x, e in zip(flat, errs):
        o, ne = compressed_psum_leaf(x, e, axis_name, mean)
        outs.append(o)
        new_errs.append(ne)
    return tdef.unflatten(outs), tdef.unflatten(new_errs)


def zero_error_state(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def wire_bytes(tree, mode: str = "int8") -> int:
    """Bytes on the wire per reduction, for the roofline accounting."""
    per = {"int8": 1, "bf16": 2, "f32": 4}[mode]
    return sum(x.size * per for x in jax.tree.leaves(tree))
