"""Unified config-driven model: dense/GQA/MoE/RG-LRU/RWKV6/enc-dec.

Layers are grouped into repeating *pattern units* (e.g. recurrentgemma's
(rglru, rglru, local), llama4's (attn+dense, attn+moe)); units are stacked
and applied with ``lax.scan`` so the lowered HLO contains each unique layer
body exactly once regardless of depth.  Leftover layers (depth not divisible
by the cycle) are unrolled.

Public API (pure functions over a param pytree):
  init_params(key, cfg)
  forward(params, cfg, tokens=..., embeds=..., mode="train"|"prefill", ...)
  train_loss(params, batch, cfg)
  init_decode_state(cfg, batch, max_len)
  decode_step(params, cfg, tokens, pos, state, ...)
  count_params_analytic(cfg)
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models.layers import (
    COMPUTE_DTYPE, PARAM_DTYPE, apply_mlp, apply_mrope, apply_norm,
    apply_rope, cast, embed_tokens, init_embeddings, init_mlp, init_norm,
    unembed,
)
from repro.parallel.sharding import current_mesh, shard

ZERO_AUX = {"moe_lb_loss": jnp.zeros((), jnp.float32),
            "moe_z_loss": jnp.zeros((), jnp.float32)}

VOCAB_QUANTUM = 128   # lane quantum: embeddings padded to eliminate the
                      # vocab tail (ragged vocab can't shard over TP and
                      # pads every MXU tile — the paper's Eq. 8b move)


def padded_vocab(cfg: ModelConfig) -> int:
    v = cfg.vocab_size
    return ((v + VOCAB_QUANTUM - 1) // VOCAB_QUANTUM) * VOCAB_QUANTUM


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------
def layer_plan(cfg: ModelConfig, encoder: bool = False) -> list:
    """[(kind, mlp_kind)] per layer.  Encoder layers are always attn+dense."""
    n = cfg.encoder_layers if encoder else cfg.n_layers
    out = []
    for i in range(n):
        kind = "attn" if encoder else cfg.block_kind(i)
        if kind == "rwkv":
            mlp_kind = "cmix"
        elif (not encoder and cfg.moe
              and (i + 1) % max(cfg.moe_interleave, 1) == 0):
            mlp_kind = "moe"
        else:
            mlp_kind = "dense"
        out.append((kind, mlp_kind))
    return out


def unit_cycle(cfg: ModelConfig, encoder: bool = False) -> int:
    if encoder:
        return 1
    c = len(cfg.block_pattern)
    if cfg.moe:
        c = math.lcm(c, max(cfg.moe_interleave, 1))
    return c


def decoder_layer_refs(cfg: ModelConfig) -> list:
    """Pytree address of every decoder layer, in layer order.

    Each entry is a dict: ``kind``/``mlp_kind`` from :func:`layer_plan`,
    plus where the layer's params live under ``params["decoder"]``:
    ``group`` is ``"stack"`` (scanned units; ``key`` names the unit slot
    ``u{j}`` and ``index`` the position along the stacked leading axis)
    or ``"extra"`` (unrolled leftovers; ``key`` is ``x{j}``, ``index``
    None).  ``init_decode_state`` lays decode states out identically, so
    the same addresses locate a layer's KV cache.
    """
    plan = layer_plan(cfg, encoder=False)
    cycle = unit_cycle(cfg)
    n_units = len(plan) // cycle
    refs = []
    for i, (kind, mlpk) in enumerate(plan):
        u, j = divmod(i, cycle)
        if u < n_units:
            refs.append({"kind": kind, "mlp_kind": mlpk, "group": "stack",
                         "key": f"u{j}", "index": u})
        else:
            refs.append({"kind": kind, "mlp_kind": mlpk, "group": "extra",
                         "key": f"x{i - n_units * cycle}", "index": None})
    return refs


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------
def init_layer(key, cfg: ModelConfig, kind: str, mlp_kind: str,
               cross: bool) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {"norm1": init_norm(cfg.norm, cfg.d_model)}
    if kind in ("attn", "local"):
        p["attn"] = attn_lib.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            bias=cfg.qkv_bias)
    elif kind == "rglru":
        p["rglru"] = rec_lib.init_rglru(ks[0], cfg.d_model)
    elif kind == "rwkv":
        rw = rec_lib.init_rwkv(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.rwkv_head_dim, cfg.d_ff)
        p["rwkv"] = rw["rwkv"]
        p["cmix"] = rw["cmix"]
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
        return p
    else:
        raise ValueError(kind)

    if cross:
        p["norm_cross"] = init_norm(cfg.norm, cfg.d_model)
        p["cross"] = {"attn": attn_lib.init_attention(
            ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            bias=cfg.qkv_bias)}

    if not cfg.parallel_block:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
    if mlp_kind == "dense":
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    elif mlp_kind == "moe":
        p["moe"] = moe_lib.init_moe(ks[2], cfg.d_model, cfg.n_experts,
                                    cfg.moe_d_ff, cfg.shared_expert,
                                    cfg.d_ff)
    return p


def _rope(cfg: ModelConfig, q, k, positions):
    if cfg.rope_kind == "none":
        return q, k
    if cfg.rope_kind == "mrope":
        return (apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections),
                apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections))
    return (apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta))


def _default_positions(cfg: ModelConfig, b: int, s: int, offset=0):
    pos = offset + jnp.arange(s, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(pos[..., None], (b, s, 3))
    return pos


def _self_attention(p, x, cfg: ModelConfig, kind: str, mode: str,
                    cache, positions, pos, causal: bool):
    """Self-attention for train / prefill / decode.  Returns (y, cache)."""
    b = x.shape[0]
    if mode == "decode":
        # Ragged decode (continuous batching): `pos` may be a (B,) vector
        # of per-slot write positions — each slot of the batch sits at its
        # own sequence offset, so cache writes scatter per row and the
        # attention mask uses per-row valid lengths.
        ragged = jnp.ndim(pos) == 1
        q, k, v = attn_lib.qkv_proj(p, x)                 # (B,1,H,dh)
        rp = positions if positions is not None else (
            _default_positions(cfg, b, 1, pos[:, None] if ragged else pos))
        q, k = _rope(cfg, q, k, rp)
        mesh = current_mesh()
        if ragged:
            b_idx = jnp.arange(b)
            if kind == "local":
                w = cfg.window
                slot = pos % w
                kc = cache["k"].at[b_idx, slot].set(
                    k[:, 0].astype(cache["k"].dtype))
                vc = cache["v"].at[b_idx, slot].set(
                    v[:, 0].astype(cache["v"].dtype))
                valid = jnp.minimum(pos + 1, w)
            else:
                kc = cache["k"].at[b_idx, pos].set(
                    k[:, 0].astype(cache["k"].dtype))
                vc = cache["v"].at[b_idx, pos].set(
                    v[:, 0].astype(cache["v"].dtype))
                valid = pos + 1
            o = attn_lib.decode_attention(q[:, 0], kc, vc, valid)
            y = attn_lib.out_proj(p, o[:, None])
            return y, {"k": kc, "v": vc}
        if kind == "local":
            w = cfg.window
            slot = pos % w
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            valid = jnp.minimum(pos + 1, w)
            o = attn_lib.decode_attention(q[:, 0], kc, vc, valid)
        else:
            if mesh is not None and "model" in mesh.axis_names:
                kc = attn_lib.update_cache_sharded(cache["k"], k[:, 0], pos,
                                                   mesh)
                vc = attn_lib.update_cache_sharded(cache["v"], v[:, 0], pos,
                                                   mesh)
                o = attn_lib.flash_decode_sharded(q[:, 0], kc, vc, pos + 1,
                                                  mesh)
            else:
                kc = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
                o = attn_lib.decode_attention(q[:, 0], kc, vc, pos + 1)
        y = attn_lib.out_proj(p, o[:, None])
        return y, {"k": kc, "v": vc}

    if mode == "chunk":
        # Chunked prefill: x is a (B, C, d) chunk whose rows sit at
        # absolute positions pos..pos+C of a request already holding
        # `pos` committed rows in `cache`.  The chunk's K/V land in
        # cache rows [pos, pos+C) and every chunk row attends causally
        # over the full cache — so chunk-by-chunk prefill reproduces the
        # whole-prompt prefill exactly (global attention only: local
        # ring caches rotate by total length and cannot be grown
        # incrementally).
        if kind != "attn":
            raise ValueError(
                "chunked prefill requires global attention layers")
        s = x.shape[1]
        q, k, v = attn_lib.qkv_proj(p, x)
        rp = positions if positions is not None else _default_positions(
            cfg, b, s, pos)
        q, k = _rope(cfg, q, k, rp)
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        o = attn_lib.chunk_prefill_attention(q, kc, vc, pos)
        y = attn_lib.out_proj(p, o)
        return y, {"k": kc, "v": vc}

    # train / prefill
    s = x.shape[1]
    q, k, v = attn_lib.qkv_proj(p, x)
    rp = positions if positions is not None else _default_positions(cfg, b, s)
    q, k = _rope(cfg, q, k, rp)
    if kind == "local":
        o = attn_lib.local_attention_prefill(q, k, v, window=cfg.window)
    elif causal:
        # Routes through ops.flash_attention (autotuned wave-aligned
        # tiles) when a kernels.ops.kernel_context is active; plain
        # chunked_attention otherwise.
        o = attn_lib.prefill_attention(q, k, v, mask_kind="causal")
    else:
        o = attn_lib.chunked_attention(q, k, v, mask_kind="none")
    y = attn_lib.out_proj(p, o)
    new_cache = None
    if mode == "prefill":
        if kind == "local":
            w = cfg.window
            pad = max(w - s, 0)
            kw = k[:, -w:] if s >= w else jnp.pad(k, ((0, 0), (0, pad),
                                                      (0, 0), (0, 0)))
            vw = v[:, -w:] if s >= w else jnp.pad(v, ((0, 0), (0, pad),
                                                      (0, 0), (0, 0)))
            # ring-buffer order: rotate so slot (s % w) is next write
            if s >= w:
                shift = s % w
                kw = jnp.roll(kw, shift, axis=1)
                vw = jnp.roll(vw, shift, axis=1)
            new_cache = {"k": kw.astype(COMPUTE_DTYPE),
                         "v": vw.astype(COMPUTE_DTYPE)}
        else:
            # Reshard to the decode layout: KV sequence over `model`
            # (sequence-parallel cache).  Without this the returned caches
            # are only batch-sharded — 16x over HBM budget at 32k.
            new_cache = {
                "k": shard(k.astype(COMPUTE_DTYPE),
                           "batch", "kv_seq", None, None),
                "v": shard(v.astype(COMPUTE_DTYPE),
                           "batch", "kv_seq", None, None),
            }
    return y, new_cache


def _cross_attention(p, x, cfg: ModelConfig, mode: str, cache, enc_out):
    """Cross-attention onto encoder output (no rope)."""
    if mode == "decode":
        q = jnp.einsum("...d,dhk->...hk", x, cast(p["attn"]["wq"]))
        if "bq" in p["attn"]:
            q = q + cast(p["attn"]["bq"])
        mesh = current_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            o = attn_lib.flash_decode_sharded(q[:, 0], cache["ck"],
                                              cache["cv"], cache["clen"],
                                              mesh)
        else:
            o = attn_lib.decode_attention(q[:, 0], cache["ck"], cache["cv"],
                                          cache["clen"])
        return attn_lib.out_proj(p["attn"], o[:, None]), cache
    q = jnp.einsum("...d,dhk->...hk", x, cast(p["attn"]["wq"]))
    if "bq" in p["attn"]:
        q = q + cast(p["attn"]["bq"])
    k, v = attn_lib.kv_proj(p["attn"], enc_out)
    o = attn_lib.chunked_attention(q, k, v, mask_kind="none")
    y = attn_lib.out_proj(p["attn"], o)
    new_cache = None
    if mode == "prefill":
        new_cache = {"ck": k.astype(COMPUTE_DTYPE),
                     "cv": v.astype(COMPUTE_DTYPE),
                     "clen": jnp.asarray(enc_out.shape[1], jnp.int32)}
    return y, new_cache


def apply_layer(p: dict, x: jax.Array, cfg: ModelConfig, kind: str,
                mlp_kind: str, *, mode: str, state, enc_out, positions,
                pos, causal: bool, moe_strategy: str):
    """Returns (x, new_state, aux)."""
    aux = dict(ZERO_AUX)
    new_state: dict = {}

    if mode == "chunk" and kind != "attn":
        # recurrent layers carry a running state, not a cache: a chunk
        # cannot be replayed against them without decoding every token
        raise ValueError(
            f"chunked prefill supports global-attention layers only "
            f"(got {kind!r})")

    if kind == "rwkv":
        h = apply_norm(p["norm1"], x, cfg.norm)
        tm_state = ({"shift": state["shift"], "s": state["s"]}
                    if state else None)
        y, tm_new = rec_lib.apply_rwkv_timemix(
            p["rwkv"], h, state=tm_state, decode=(mode == "decode"))
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm)
        cm_state = state["cmix_shift"] if state else None
        y, cm_new = rec_lib.apply_rwkv_channelmix(p["cmix"], h, cm_state)
        x = x + y
        if mode != "train":
            new_state = {"shift": tm_new["shift"], "s": tm_new["s"],
                         "cmix_shift": cm_new}
        return x, new_state, aux

    h = apply_norm(p["norm1"], x, cfg.norm)

    if kind == "rglru":
        st = state if state else None
        y, rg_new = rec_lib.apply_rglru_block(p["rglru"], h, state=st,
                                              decode=(mode == "decode"))
        if mode != "train":
            new_state = rg_new
    else:
        sa_cache = ({"k": state["k"], "v": state["v"]} if state else None)
        y, sa_new = _self_attention(p["attn"], h, cfg, kind, mode, sa_cache,
                                    positions, pos, causal)
        if sa_new is not None:
            new_state.update(sa_new)

    if cfg.parallel_block and mlp_kind == "dense":
        # cohere: out = x + attn(norm(x)) + mlp(norm(x))
        y2 = apply_mlp(p["mlp"], h, cfg.mlp_gated)
        x = x + y + y2
        return x, new_state, aux

    x = x + y

    if "cross" in p:
        h = apply_norm(p["norm_cross"], x, cfg.norm)
        cr_cache = ({"ck": state["ck"], "cv": state["cv"],
                     "clen": state["clen"]} if state and "ck" in state
                    else None)
        y, cr_new = _cross_attention(p["cross"], h, cfg, mode, cr_cache,
                                     enc_out)
        x = x + y
        if cr_new is not None:
            new_state.update(cr_new)

    h = apply_norm(p["norm2"], x, cfg.norm)
    if mlp_kind == "dense":
        y = apply_mlp(p["mlp"], h, cfg.mlp_gated)
    elif mlp_kind == "moe":
        y, aux_m = moe_lib.apply_moe(p["moe"], h, cfg.experts_per_token,
                                     cfg.capacity_factor,
                                     strategy=moe_strategy,
                                     mesh=current_mesh())
        aux = {k: aux[k] + aux_m[k] for k in aux}
    else:
        raise ValueError(mlp_kind)
    x = x + y
    return x, new_state, aux


# ---------------------------------------------------------------------------
# stack init / apply
# ---------------------------------------------------------------------------
def _stack_trees(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _sqrt_divisor(n: int) -> int:
    """Divisor of n nearest to sqrt(n) (group size for sqrt remat)."""
    best, target = 1, math.sqrt(n)
    for d in range(1, n + 1):
        if n % d == 0 and abs(d - target) < abs(best - target):
            best = d
    return max(best, 1)


def init_stack(key, cfg: ModelConfig, encoder: bool, cross: bool) -> dict:
    plan = layer_plan(cfg, encoder)
    cycle = unit_cycle(cfg, encoder)
    n_units = len(plan) // cycle
    leftover = len(plan) % cycle

    units = []
    for u in range(n_units):
        unit = {}
        for j in range(cycle):
            i = u * cycle + j
            kind, mlpk = plan[i]
            unit[f"u{j}"] = init_layer(jax.random.fold_in(key, i), cfg,
                                       kind, mlpk, cross)
        units.append(unit)
    out: dict = {}
    if units:
        out["stack"] = _stack_trees(units)
    extra = {}
    for j in range(leftover):
        i = n_units * cycle + j
        kind, mlpk = plan[i]
        extra[f"x{j}"] = init_layer(jax.random.fold_in(key, i), cfg,
                                    kind, mlpk, cross)
    if extra:
        out["extra"] = extra
    return out


def apply_stack(stack_p: dict, x: jax.Array, cfg: ModelConfig, *,
                encoder: bool, mode: str, states: Optional[dict],
                enc_out, positions, pos, moe_strategy: str,
                remat: str = "none"):
    """Returns (x, new_states, aux_sum)."""
    plan = layer_plan(cfg, encoder)
    cycle = unit_cycle(cfg, encoder)
    n_units = len(plan) // cycle
    causal = not encoder
    unit_plan = plan[:cycle]

    def unit_body(x, uparams, ustates):
        new_states = {}
        aux_sum = dict(ZERO_AUX)
        for j, (kind, mlpk) in enumerate(unit_plan):
            st = ustates[f"u{j}"] if ustates is not None else None
            x, ns, aux = apply_layer(
                uparams[f"u{j}"], x, cfg, kind, mlpk, mode=mode, state=st,
                enc_out=enc_out, positions=positions, pos=pos, causal=causal,
                moe_strategy=moe_strategy)
            new_states[f"u{j}"] = ns
            aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        if cfg.seq_parallel_acts and mode != "decode":
            # Megatron-SP: park the residual stream sequence-sharded over
            # `model` between blocks — norms/elementwise run sharded and
            # the 16x-replicated (B, S, D) transients disappear.
            x = shard(x, "batch", "act_seq", "embed")
        return x, new_states, aux_sum

    if remat != "none":
        policy = None
        if remat == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        # 'sqrt' keeps the unit-level checkpoint AND adds a group-level one
        # below — nested checkpointing, live set O(n/g + g) unit carries.
        unit_body = jax.checkpoint(unit_body, policy=policy,
                                   static_argnums=())

    aux_total = dict(ZERO_AUX)
    new_states_out: dict = {}

    if n_units:
        has_states = states is not None and "stack" in states

        def scan_fn(carry, xs):
            x = carry
            uparams = xs[0]
            ustates = xs[1] if has_states else None
            x, ns, aux = unit_body(x, uparams, ustates)
            return x, (ns, aux)

        if remat == "sqrt" and not has_states and n_units >= 4:
            # sqrt-schedule checkpointing: outer scan over groups of g
            # units (group body rematted), inner scan over units.  Live
            # activations: n_units/g saved carries + g transient carries,
            # instead of n_units — the difference between fitting
            # command-r-plus on v5e HBM and not.
            g = _sqrt_divisor(n_units)
            grouped = jax.tree.map(
                lambda a: a.reshape(n_units // g, g, *a.shape[1:]),
                stack_p["stack"])

            @jax.checkpoint
            def group_body(x, gparams):
                x, (_, aux) = jax.lax.scan(
                    lambda c, xs: scan_fn(c, (xs,)), x, gparams)
                return x, aux

            def outer(x, gparams):
                return group_body(x, gparams)

            x, aux_stacked = jax.lax.scan(outer, x, grouped)
            aux_total = {k: aux_total[k] + jnp.sum(aux_stacked[k])
                         for k in aux_total}
        else:
            xs = (stack_p["stack"], states["stack"]) if has_states \
                else (stack_p["stack"],)
            x, (ns_stacked, aux_stacked) = jax.lax.scan(scan_fn, x, xs)
            if mode != "train":
                new_states_out["stack"] = ns_stacked
            aux_total = {k: aux_total[k] + jnp.sum(aux_stacked[k])
                         for k in aux_total}

    if "extra" in stack_p:
        leftover_plan = plan[n_units * cycle:]
        for j, (kind, mlpk) in enumerate(leftover_plan):
            st = (states["extra"][f"x{j}"]
                  if states is not None and "extra" in states else None)
            x, ns, aux = apply_layer(
                stack_p["extra"][f"x{j}"], x, cfg, kind, mlpk, mode=mode,
                state=st, enc_out=enc_out, positions=positions, pos=pos,
                causal=causal, moe_strategy=moe_strategy)
            if mode != "train":
                new_states_out.setdefault("extra", {})[f"x{j}"] = ns
            aux_total = {k: aux_total[k] + aux[k] for k in aux_total}

    return x, (new_states_out if mode != "train" else None), aux_total


# ---------------------------------------------------------------------------
# model API
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig) -> dict:
    k_embed, k_enc, k_dec = jax.random.split(key, 3)
    params = {
        "embed": init_embeddings(k_embed, padded_vocab(cfg), cfg.d_model,
                                 cfg.tie_embeddings),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
        "decoder": init_stack(k_dec, cfg, encoder=False,
                              cross=cfg.is_encdec),
    }
    if cfg.is_encdec:
        params["encoder"] = init_stack(k_enc, cfg, encoder=True, cross=False)
        params["enc_norm"] = init_norm(cfg.norm, cfg.d_model)
    return params


def encode(params, cfg: ModelConfig, src_embeds: jax.Array,
           moe_strategy: str = "auto", remat: str = "none") -> jax.Array:
    x = shard(src_embeds.astype(COMPUTE_DTYPE), "batch", "seq", "embed")
    x, _, _ = apply_stack(params["encoder"], x, cfg, encoder=True,
                          mode="train", states=None, enc_out=None,
                          positions=None, pos=None,
                          moe_strategy=moe_strategy, remat=remat)
    return apply_norm(params["enc_norm"], x, cfg.norm)


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            src_embeds=None, positions=None, mode: str = "train",
            states=None, moe_strategy: str = "auto", remat: str = "none"):
    """Full-sequence forward.  Returns (logits, new_states, aux)."""
    enc_out = None
    if cfg.is_encdec:
        assert src_embeds is not None
        enc_out = encode(params, cfg, src_embeds, moe_strategy, remat)
    if embeds is not None:
        x = shard(embeds.astype(COMPUTE_DTYPE), "batch", "seq", "embed")
    else:
        x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x, new_states, aux = apply_stack(
        params["decoder"], x, cfg, encoder=False, mode=mode, states=states,
        enc_out=enc_out, positions=positions, pos=None,
        moe_strategy=moe_strategy, remat=remat)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     cfg.logit_softcap)
    logits = _mask_vocab_pad(logits, cfg)
    return logits, new_states, aux


def _mask_vocab_pad(logits, cfg: ModelConfig):
    vp = padded_vocab(cfg)
    if vp == cfg.vocab_size:
        return logits
    idx = jnp.arange(vp)
    return jnp.where(idx < cfg.vocab_size, logits,
                     jnp.asarray(-1e9, logits.dtype))


def train_loss(params, batch: dict, cfg: ModelConfig,
               moe_strategy: str = "auto", remat: str = "none",
               aux_weight: float = 0.01, z_weight: float = 1e-3):
    logits, _, aux = forward(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        src_embeds=batch.get("src_embeds"),
        positions=batch.get("positions"),
        mode="train", moe_strategy=moe_strategy, remat=remat)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    loss = jnp.sum(nll) / denom
    total = loss + aux_weight * aux["moe_lb_loss"] \
        + z_weight * aux["moe_z_loss"]
    metrics = {"loss": loss, "moe_lb_loss": aux["moe_lb_loss"],
               "moe_z_loss": aux["moe_z_loss"],
               "logz_mean": jnp.mean(logz)}
    return total, metrics


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------
def _layer_state_shape(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                       enc_len: int, cross: bool) -> dict:
    st: dict = {}
    if kind in ("attn", "local"):
        s = min(cfg.window, max_len) if kind == "local" else max_len
        st["k"] = jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim),
                            COMPUTE_DTYPE)
        st["v"] = jnp.zeros((batch, s, cfg.n_kv_heads, cfg.head_dim),
                            COMPUTE_DTYPE)
        if cross:
            st["ck"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads,
                                  cfg.head_dim), COMPUTE_DTYPE)
            st["cv"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads,
                                  cfg.head_dim), COMPUTE_DTYPE)
            st["clen"] = jnp.zeros((), jnp.int32)
    elif kind == "rglru":
        st.update(rec_lib.rglru_init_state(batch, cfg.d_model))
    elif kind == "rwkv":
        st.update(rec_lib.rwkv_init_state(batch, cfg.d_model, cfg.n_heads,
                                          cfg.rwkv_head_dim))
    return st


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int = 0) -> dict:
    plan = layer_plan(cfg, encoder=False)
    cycle = unit_cycle(cfg)
    n_units = len(plan) // cycle
    cross = cfg.is_encdec
    out: dict = {}
    if n_units:
        units = []
        for u in range(n_units):
            unit = {}
            for j in range(cycle):
                kind, _ = plan[u * cycle + j]
                unit[f"u{j}"] = _layer_state_shape(cfg, kind, batch, max_len,
                                                   enc_len, cross)
            units.append(unit)
        out["stack"] = _stack_trees(units)
    leftover = len(plan) % cycle
    if leftover:
        extra = {}
        for j in range(leftover):
            kind, _ = plan[n_units * cycle + j]
            extra[f"x{j}"] = _layer_state_shape(cfg, kind, batch, max_len,
                                                enc_len, cross)
        out["extra"] = extra
    return out


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, pos: jax.Array,
                states: dict, positions=None, moe_strategy: str = "auto"):
    """One token: tokens (B,) int32, pos scalar int32.  Returns
    (logits (B, V), new_states)."""
    x = embed_tokens(params["embed"], tokens[:, None], cfg.d_model)
    x, new_states, _ = apply_stack(
        params["decoder"], x, cfg, encoder=False, mode="decode",
        states=states, enc_out=None, positions=positions, pos=pos,
        moe_strategy=moe_strategy)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     cfg.logit_softcap)
    logits = _mask_vocab_pad(logits, cfg)
    return logits[:, 0], new_states


def prefill_chunk(params, cfg: ModelConfig, tokens: jax.Array,
                  pos: jax.Array, states: dict, positions=None,
                  moe_strategy: str = "auto"):
    """One prefill chunk: tokens (B, C) int32 at absolute positions
    ``[pos, pos + C)``, written into (and attending over) the decode
    -state caches in ``states``.  Returns (logits (B, C, V), new_states).

    This is the incremental counterpart of ``mode="prefill"``: calling
    it chunk-by-chunk over a prompt leaves the caches and logits a
    whole-prompt prefill would produce, but no single call ever costs
    more than one chunk — the serving engine interleaves these calls
    with decode steps so a long prompt cannot stall the decode slots,
    and each committed chunk is a recovery checkpoint.  Decoder-only,
    pure global-attention dense stacks (same eligibility as prefill
    bucketing); ``pos`` may be traced, so one executable per chunk
    *shape* serves every chunk position."""
    if cfg.is_encdec:
        raise ValueError("chunked prefill supports decoder-only models")
    x = embed_tokens(params["embed"], tokens, cfg.d_model)
    x, new_states, _ = apply_stack(
        params["decoder"], x, cfg, encoder=False, mode="chunk",
        states=states, enc_out=None, positions=positions, pos=pos,
        moe_strategy=moe_strategy)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = unembed(params["embed"], x, cfg.tie_embeddings,
                     cfg.logit_softcap)
    logits = _mask_vocab_pad(logits, cfg)
    return logits, new_states


# ---------------------------------------------------------------------------
# analytic parameter counts
# ---------------------------------------------------------------------------
def count_params_analytic(cfg: ModelConfig, active_only: bool = False,
                          include_embeddings: bool = True) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    nrm = d if cfg.norm == "rmsnorm" else 2 * d   # layernorm has a bias
    total = 0
    if include_embeddings:
        total += v * d
        if not cfg.tie_embeddings:
            total += d * v

    def attn_params():
        p = d * h * dh + 2 * d * kv * dh + h * dh * d
        if cfg.qkv_bias:
            p += h * dh + 2 * kv * dh
        return p

    def mlp_params():
        return (3 if cfg.mlp_gated else 2) * d * f

    def moe_params(active: bool):
        k = cfg.experts_per_token
        e = k if active else cfg.n_experts
        p = d * cfg.n_experts + e * 3 * d * cfg.moe_d_ff
        if cfg.shared_expert:
            p += 3 * d * f
        return p

    def rglru_params():
        w = d
        return 2 * d * w + w * d + rec_lib.CONV_K * w + 6 * w

    def rwkv_params():
        lora = 64
        tm = 4 * d * h * cfg.rwkv_head_dim + h * cfg.rwkv_head_dim * d \
            + d * lora + lora * h * cfg.rwkv_head_dim \
            + 2 * h * cfg.rwkv_head_dim + 5 * d + 2 * d
        cm = d * f + f * d + d * d + 2 * d
        return tm + cm

    for encoder in ([True] if cfg.is_encdec else []) + [False]:
        for kind, mlpk in layer_plan(cfg, encoder):
            total += nrm  # norm1
            if kind in ("attn", "local"):
                total += attn_params()
            elif kind == "rglru":
                total += rglru_params()
            elif kind == "rwkv":
                total += rwkv_params() + nrm
                continue
            if not encoder and cfg.is_encdec:
                total += attn_params() + nrm      # cross + its norm
            if not cfg.parallel_block:
                total += nrm                      # norm2
            if mlpk == "dense":
                total += mlp_params()
            elif mlpk == "moe":
                total += moe_params(active_only)
    total += nrm  # final norm
    if cfg.is_encdec:
        total += nrm
    return int(total)
