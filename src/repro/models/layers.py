"""Basic building blocks: norms, dense/embedding, rotary (incl. M-RoPE).

All layers are pure functions over explicit param dicts.  Compute dtype is
bf16, master params fp32 (cast at use).  Activation sharding is annotated
with logical axes via ``repro.parallel.shard``.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis_size: Optional[int] = None):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(
        PARAM_DTYPE)


def embed_init(key, shape):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(
        PARAM_DTYPE)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(kind: str, d: int) -> dict:
    p = {"scale": jnp.ones((d,), PARAM_DTYPE)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), PARAM_DTYPE)
    return p


def apply_norm(p: dict, x: jax.Array, kind: str, eps: float = 1e-6
               ) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float
               ) -> jax.Array:
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]                             # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions3: (..., S, 3) int32 — (t, h, w) position per token; the
    frequency bands of the half-dim are split across the three sections.
    """
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)                       # (half,)
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=half)      # (half,)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_ids, positions3.shape[:-1] + (half,)).astype(
            jnp.int32),
        axis=-1)                                        # (..., S, half)
    ang = pos * freqs
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU or plain GeLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, gated: bool, bias: bool = False
             ) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff)),
         "w_down": dense_init(ks[1], (d_ff, d_model), in_axis_size=d_ff)}
    if gated:
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff))
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), PARAM_DTYPE)
        p["b_down"] = jnp.zeros((d_model,), PARAM_DTYPE)
    return p


def apply_mlp(p: dict, x: jax.Array, gated: bool) -> jax.Array:
    from repro.kernels import ops
    if ops.kernel_routing_active():
        return _apply_mlp_kernels(p, x, gated)
    up = jnp.einsum("...d,df->...f", x, cast(p["w_up"]))
    if "b_up" in p:
        up = up + cast(p["b_up"])
    if gated:
        g = jnp.einsum("...d,df->...f", x, cast(p["w_gate"]))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    h = shard(h, "batch", "seq", "mlp")
    out = jnp.einsum("...f,fd->...d", h, cast(p["w_down"]))
    if "b_down" in p:
        out = out + cast(p["b_down"])
    return shard(out, "batch", "seq", "embed")


def _apply_mlp_kernels(p: dict, x: jax.Array, gated: bool) -> jax.Array:
    """MLP on the tiled matmul kernel (ambient kernel context active):
    the token axes flatten to M so every projection runs on the
    autotuned wave-aligned (block_m, block_n, block_k) grid."""
    from repro.kernels import ops
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    up = ops.matmul(x2, cast(p["w_up"]))
    if "b_up" in p:
        up = up + cast(p["b_up"])
    if gated:
        g = ops.matmul(x2, cast(p["w_gate"]))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    out = ops.matmul(h.astype(x.dtype), cast(p["w_down"]))
    if "b_down" in p:
        out = out + cast(p["b_down"])
    return shard(out.reshape(*lead, out.shape[-1]),
                 "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------
def init_embeddings(key, vocab: int, d_model: int, tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok_emb": embed_init(k1, (vocab, d_model))}
    if not tie:
        p["out_emb"] = dense_init(k2, (d_model, vocab))
    return p


def embed_tokens(p: dict, tokens: jax.Array, d_model: int) -> jax.Array:
    x = cast(p["tok_emb"])[tokens]
    x = x * jnp.asarray(math.sqrt(d_model), COMPUTE_DTYPE)
    return shard(x, "batch", "seq", "embed")


def unembed(p: dict, x: jax.Array, tie: bool, softcap: float = 0.0
            ) -> jax.Array:
    if tie:
        logits = jnp.einsum("...d,vd->...v", x, cast(p["tok_emb"]))
    else:
        logits = jnp.einsum("...d,dv->...v", x, cast(p["out_emb"]))
    logits = shard(logits, "batch", "seq", "vocab")
    if softcap > 0.0:
        logits = (jnp.tanh(logits.astype(jnp.float32) / softcap) * softcap
                  ).astype(logits.dtype)
    return logits
