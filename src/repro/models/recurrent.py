"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and RWKV6 (Finch).

Both provide three execution forms:
  * parallel-in-time for train/prefill — associative scan (RG-LRU) or
    chunked per-channel-decay linear attention (RWKV6), with lax.scan over
    chunks so the lowered HLO stays small;
  * single-step for decode — O(1) carried state;
  * a pure sequential reference (tests assert the fast forms match it).

Conventions (the ref defines the semantics; the Pallas kernels must match):
  RG-LRU:  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
           a_t = exp(-c * softplus(L) * r_t),  c = 8
  RWKV6:   S_t = diag(w_t) S_{t-1} + k_t^T v_t
           o_t = r_t @ (diag(w_t) S_{t-1} + (u * k_t)^T v_t)
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (
    COMPUTE_DTYPE, PARAM_DTYPE, apply_norm, cast, dense_init, init_norm,
)
from repro.parallel.sharding import shard

RG_C = 8.0
CONV_K = 4


# ===========================================================================
# RG-LRU (Griffin recurrent block)
# ===========================================================================
def init_rglru(key, d_model: int, width: Optional[int] = None) -> dict:
    w = width or d_model
    ks = jax.random.split(key, 6)
    # a_param initialized so a^c in (0.9, 0.999) at r=1 (paper's Lambda init)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9**2, 0.999**2)
    a_param = jnp.log(jnp.exp(-jnp.log(u) / (2 * RG_C)) - 1.0)
    return {
        "w_x": dense_init(ks[1], (d_model, w)),
        "w_gate": dense_init(ks[2], (d_model, w)),
        "w_out": dense_init(ks[3], (w, d_model), in_axis_size=w),
        "conv_w": dense_init(ks[4], (CONV_K, w), in_axis_size=CONV_K),
        "conv_b": jnp.zeros((w,), PARAM_DTYPE),
        "a_param": a_param.astype(PARAM_DTYPE),
        "in_gate_w": dense_init(ks[5], (w,), in_axis_size=1),
        "in_gate_b": jnp.zeros((w,), PARAM_DTYPE),
        "rec_gate_w": dense_init(jax.random.fold_in(key, 7), (w,),
                                 in_axis_size=1),
        "rec_gate_b": jnp.zeros((w,), PARAM_DTYPE),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Width-CONV_K causal depthwise conv over time.  x: (B, T, W).

    Returns (y, new_state) where state is the trailing CONV_K-1 inputs.
    """
    btw = x
    if state is None:
        pad = jnp.zeros((x.shape[0], CONV_K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, btw], axis=1)              # (B, T+K-1, W)
    y = sum(xp[:, i:i + x.shape[1]] * cast(w[i]) for i in range(CONV_K))
    y = y + cast(b)
    new_state = xp[:, -(CONV_K - 1):]
    return y, new_state


def _rglru_gates(p: dict, x: jax.Array):
    """Per-channel input & recurrence gates and log-decay."""
    xf = x.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(xf * p["in_gate_w"] + p["in_gate_b"])
    r_gate = jax.nn.sigmoid(xf * p["rec_gate_w"] + p["rec_gate_b"])
    log_a = -RG_C * jax.nn.softplus(p["a_param"]) * r_gate   # <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    gated_x = i_gate * xf
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * gated_x


def rglru_scan(p: dict, x: jax.Array, h0: Optional[jax.Array] = None):
    """Parallel-in-time RG-LRU via associative scan.  x: (B, T, W) fp32 in.

    Returns (y (B,T,W), h_last (B,W)).
    """
    a, b = _rglru_gates(p, x)                              # (B,T,W) fp32
    if h0 is not None:
        # Fold the carried state in as a virtual step 0 contribution.
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        with jax.named_scope("vmem_resident_rglru"):
            a1, b1 = c1
            a2, b2 = c2
            return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(COMPUTE_DTYPE), h[:, -1]


def rglru_step(p: dict, x_t: jax.Array, h: jax.Array):
    """One decode step.  x_t: (B, W); h: (B, W) fp32 state."""
    a, b = _rglru_gates(p, x_t[:, None])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(COMPUTE_DTYPE), h_new


def rglru_ref(p: dict, x: jax.Array, h0: Optional[jax.Array] = None):
    """Sequential oracle (tests)."""
    b_, t, w = x.shape
    h = jnp.zeros((b_, w), jnp.float32) if h0 is None else h0

    def step(h, xt):
        y, h = rglru_step(p, xt, h)
        return h, y

    h, ys = jax.lax.scan(step, h, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), h


def apply_rglru_block(p: dict, x: jax.Array, *, state: Optional[dict] = None,
                      decode: bool = False):
    """Full Griffin recurrent block: x -> (in-proj, conv, RG-LRU) * gate.

    x: (B, T, D) (T=1 for decode).  state: {"h": (B,W), "conv": (B,K-1,W)}.
    Returns (out (B,T,D), new_state).
    """
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, cast(p["w_gate"])))
    xin = jnp.einsum("btd,dw->btw", x, cast(p["w_x"]))
    xin = shard(xin, "batch", "seq", "mlp")
    gate = shard(gate, "batch", "seq", "mlp")
    conv_state = state["conv"] if state is not None else None
    xc, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    if decode:
        h0 = state["h"]
        y, h = rglru_step(p, xc[:, 0], h0)
        y = y[:, None]
    else:
        h0 = state["h"] if state is not None else None
        y, h = rglru_scan(p, xc, h0)
    out = jnp.einsum("btw,wd->btd", y * gate, cast(p["w_out"]))
    return shard(out, "batch", "seq", "embed"), {"h": h, "conv": conv_state}


def rglru_init_state(batch: int, width: int) -> dict:
    return {"h": jnp.zeros((batch, width), jnp.float32),
            "conv": jnp.zeros((batch, CONV_K - 1, width), COMPUTE_DTYPE)}


# ===========================================================================
# RWKV6 time-mix + channel-mix
# ===========================================================================
def init_rwkv(key, d_model: int, n_heads: int, head_dim: int, d_ff: int
              ) -> dict:
    ks = jax.random.split(key, 12)
    lora = 64
    tm = {
        "w_r": dense_init(ks[0], (d_model, n_heads, head_dim),
                          in_axis_size=d_model),
        "w_k": dense_init(ks[1], (d_model, n_heads, head_dim),
                          in_axis_size=d_model),
        "w_v": dense_init(ks[2], (d_model, n_heads, head_dim),
                          in_axis_size=d_model),
        "w_g": dense_init(ks[3], (d_model, n_heads, head_dim),
                          in_axis_size=d_model),
        "w_o": dense_init(ks[4], (n_heads, head_dim, d_model),
                          in_axis_size=n_heads * head_dim),
        # base decay: softplus-ish negative so w = exp(-exp(.)) in (0, 1)
        "decay_w": jnp.full((n_heads, head_dim), -1.0, PARAM_DTYPE),
        "decay_lora_a": dense_init(ks[5], (d_model, lora)),
        "decay_lora_b": (jax.random.normal(ks[6], (lora, n_heads, head_dim),
                                           jnp.float32) * 0.01
                         ).astype(PARAM_DTYPE),
        "bonus_u": (jax.random.normal(ks[7], (n_heads, head_dim),
                                      jnp.float32) * 0.1).astype(PARAM_DTYPE),
        "mix_r": jnp.full((d_model,), 0.5, PARAM_DTYPE),
        "mix_k": jnp.full((d_model,), 0.5, PARAM_DTYPE),
        "mix_v": jnp.full((d_model,), 0.5, PARAM_DTYPE),
        "mix_g": jnp.full((d_model,), 0.5, PARAM_DTYPE),
        "mix_w": jnp.full((d_model,), 0.5, PARAM_DTYPE),
        "ln_x": init_norm("layernorm", n_heads * head_dim),
    }
    cm = {
        "w_in": dense_init(ks[8], (d_model, d_ff)),
        "w_out": dense_init(ks[9], (d_ff, d_model), in_axis_size=d_ff),
        "w_r": dense_init(ks[10], (d_model, d_model)),
        "mix_c": jnp.full((d_model,), 0.5, PARAM_DTYPE),
        "mix_rc": jnp.full((d_model,), 0.5, PARAM_DTYPE),
    }
    return {"rwkv": tm, "cmix": cm}


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """x: (B,T,D) -> value of the previous token (B,T,D), plus new carry."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    shifted = jnp.concatenate([prev, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def _mix(x, shifted, m):
    m = cast(m)
    return x * m + shifted * (1.0 - m)


def _rwkv_rkvwg(p: dict, x: jax.Array, shifted: jax.Array):
    xr = _mix(x, shifted, p["mix_r"])
    xk = _mix(x, shifted, p["mix_k"])
    xv = _mix(x, shifted, p["mix_v"])
    xg = _mix(x, shifted, p["mix_g"])
    xw = _mix(x, shifted, p["mix_w"])
    r = jnp.einsum("btd,dhk->bthk", xr, cast(p["w_r"]))
    k = jnp.einsum("btd,dhk->bthk", xk, cast(p["w_k"]))
    v = jnp.einsum("btd,dhk->bthk", xv, cast(p["w_v"]))
    g = jnp.einsum("btd,dhk->bthk", xg, cast(p["w_g"]))
    # data-dependent decay (fp32 for stability)
    dd = jnp.einsum("btd,dl->btl", xw.astype(jnp.float32),
                    p["decay_lora_a"])
    dd = jnp.einsum("btl,lhk->bthk", jnp.tanh(dd), p["decay_lora_b"])
    log_w = -jnp.exp(jnp.clip(p["decay_w"] + dd, -8.0, 4.0))  # < 0
    return r, k, v, g, log_w


def rwkv_ref(r, k, v, log_w, u, s0=None):
    """Sequential oracle.  r/k/v/log_w: (B,T,H,K); u: (H,K).

    Returns (o (B,T,H,K) fp32, S (B,H,K,K) fp32).
    """
    b, t, h, dk = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(log_w)
    s = jnp.zeros((b, h, dk, dk), jnp.float32) if s0 is None else s0

    def step(s, inp):
        rt, kt, vt, wt = inp                                # (B,H,K)
        s_dec = wt[..., None] * s
        o = jnp.einsum("bhk,bhkj->bhj", rt, s_dec)
        o = o + jnp.einsum("bhk,hk,bhk,bhj->bhj", rt, u, kt, vt)
        s_new = s_dec + jnp.einsum("bhk,bhj->bhkj", kt, vt)
        return s_new, o

    s, os_ = jax.lax.scan(
        step, s, (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
                  vf.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3)))
    return os_.transpose(1, 0, 2, 3), s


def rwkv_chunked(r, k, v, log_w, u, s0=None, chunk: int = 32):
    """Chunked parallel form; exact (matches rwkv_ref to fp32 tolerance).

    All pairwise decays are exp of non-positive numbers — numerically safe
    regardless of how small per-step decay gets.
    """
    b, t, h, dk = r.shape
    c = min(chunk, t)
    while t % c:
        c //= 2
    n = t // c
    rf = r.astype(jnp.float32).reshape(b, n, c, h, dk)
    kf = k.astype(jnp.float32).reshape(b, n, c, h, dk)
    vf = v.astype(jnp.float32).reshape(b, n, c, h, dk)
    lw = log_w.astype(jnp.float32).reshape(b, n, c, h, dk)
    uf = u.astype(jnp.float32)
    s = jnp.zeros((b, h, dk, dk), jnp.float32) if s0 is None else s0

    idx = jnp.arange(c)
    tri = idx[:, None] > idx[None, :]                       # strict lower

    def chunk_step(s, inp):
        from repro.models.attention import _vmem_scope
        return _vmem_scope("vmem_resident_rwkv", _chunk_step_inner)(s, inp)

    def _chunk_step_inner(s, inp):
        rc, kc, vc, lwc = inp                               # (B,C,H,K)
        le = jnp.cumsum(lwc, axis=1)                        # inclusive logs
        # pairwise decay exp(le_i - le_j) for j < i  (exp of <= 0)
        diff = le[:, :, None] - le[:, None, :]              # (B,C,C,H,K)
        A = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        intra = jnp.einsum("bihd,bjhd,bijhd->bhij", rc, kc, A)
        diag = jnp.einsum("bihd,hd,bihd->bhi", rc, uf, kc)
        intra = intra + diag[..., None] * jnp.eye(c)
        o = jnp.einsum("bhij,bjhd->bihd", intra, vc)
        # state contribution: r_i * e_i @ S
        o = o + jnp.einsum("bihd,bhdj->bihj", rc * jnp.exp(le), s)
        # state update
        le_c = le[:, -1]                                    # (B,H,K)
        k_scaled = kc * jnp.exp(le_c[:, None] - le)
        s_new = jnp.exp(le_c)[..., None] * s \
            + jnp.einsum("bihd,bihj->bhdj", k_scaled, vc)
        return s_new, o

    s, os_ = jax.lax.scan(
        chunk_step, s,
        (rf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
         vf.transpose(1, 0, 2, 3, 4), lw.transpose(1, 0, 2, 3, 4)))
    # (n, b, c, h, k) -> (b, t, h, k)
    return os_.transpose(1, 0, 2, 3, 4).reshape(b, t, h, dk), s


def apply_rwkv_timemix(p: dict, x: jax.Array, *, state: Optional[dict] = None,
                       decode: bool = False, chunk: int = 32):
    """x: (B,T,D).  state: {"shift": (B,1,D), "s": (B,H,K,K)}."""
    b, t, d = x.shape
    prev = state["shift"] if state is not None else None
    shifted, new_shift = _token_shift(x, prev)
    r, k, v, g, log_w = _rwkv_rkvwg(p, x, shifted)
    u = p["bonus_u"].astype(jnp.float32)
    s0 = state["s"] if state is not None else None
    if decode:
        o, s = rwkv_ref(r, k, v, log_w, u, s0)
    else:
        o, s = rwkv_chunked(r, k, v, log_w, u, s0, chunk=chunk)
    h, dk = o.shape[2], o.shape[3]
    o = apply_norm(p["ln_x"], o.reshape(b, t, h * dk).astype(COMPUTE_DTYPE),
                   "layernorm").reshape(b, t, h, dk)
    o = o * jax.nn.silu(g)
    out = jnp.einsum("bthk,hkd->btd", o, cast(p["w_o"]))
    return (shard(out, "batch", "seq", "embed"),
            {"shift": new_shift, "s": s})


def apply_rwkv_channelmix(p: dict, x: jax.Array,
                          state: Optional[jax.Array] = None):
    """RWKV channel-mix (squared-relu FFN with receptance gate).

    state: (B,1,D) carried previous token (decode).
    """
    shifted, new_shift = _token_shift(x, state)
    xk = _mix(x, shifted, p["mix_c"])
    xr = _mix(x, shifted, p["mix_rc"])
    hidden = jnp.einsum("btd,df->btf", xk, cast(p["w_in"]))
    hidden = jnp.square(jax.nn.relu(hidden))
    hidden = shard(hidden, "batch", "seq", "mlp")
    out = jnp.einsum("btf,fd->btd", hidden, cast(p["w_out"]))
    recept = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, cast(p["w_r"])))
    return shard(out * recept, "batch", "seq", "embed"), new_shift


def rwkv_init_state(batch: int, d_model: int, n_heads: int, head_dim: int
                    ) -> dict:
    return {
        "shift": jnp.zeros((batch, 1, d_model), COMPUTE_DTYPE),
        "s": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        "cmix_shift": jnp.zeros((batch, 1, d_model), COMPUTE_DTYPE),
    }
