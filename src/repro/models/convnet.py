"""Small VGG-style convnet — the paper's Table 2 testbed (VGG16/CIFAR10),
at reproducible scale.  Width-configurable per conv layer so the pruning
baselines (HRank/SOFT) and the tail-effect optimizer can resize it.

On TPU a conv lowers to an im2col matmul: (B*H*W, kh*kw*Cin) @ (.., Cout) —
so the wave-quantization LayerShape for conv layer i is
    tokens = B*H_i*W_i, d_in = kh*kw*Cin_i, width = Cout_i
which is exactly the mapping benchmarks/pruning_opt.py uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import PARAM_DTYPE, dense_init

# Conv widths straddle lane-tile (128) boundaries so the staircase has
# stairs to climb — mirroring VGG16's 64..512 filter range (paper Table 2).
DEFAULT_WIDTHS = (128, 192, 320, 448)


def conv_names(widths=None) -> list:
    widths = widths or DEFAULT_WIDTHS
    return [f"conv{i}" for i in range(len(widths))]


def init_convnet(key, widths=None, n_classes: int = 10,
                 in_channels: int = 3, image: int = 32) -> dict:
    widths = tuple(widths or DEFAULT_WIDTHS)
    params: dict = {}
    cin = in_channels
    for i, w in enumerate(widths):
        k = jax.random.fold_in(key, i)
        params[f"conv{i}"] = {
            "kernel": dense_init(k, (3, 3, cin, w),
                                 in_axis_size=9 * cin),
            "bias": jnp.zeros((w,), PARAM_DTYPE),
        }
        cin = w
    # spatial: pool /2 after every 2 convs
    n_pools = len(widths) // 2
    feat = image // (2 ** n_pools)
    params["head"] = {
        "w": dense_init(jax.random.fold_in(key, 99),
                        (feat * feat * cin, n_classes)),
        "b": jnp.zeros((n_classes,), PARAM_DTYPE),
    }
    return params


def forward_convnet(params: dict, x: jax.Array,
                    collect_acts: bool = False):
    """x: (B, H, W, C) float32.  Returns (logits, acts dict)."""
    acts = {}
    i = 0
    while f"conv{i}" in params:
        p = params[f"conv{i}"]
        x = jax.lax.conv_general_dilated(
            x, p["kernel"].astype(x.dtype), window_strides=(1, 1),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x + p["bias"].astype(x.dtype))
        if collect_acts:
            acts[f"conv{i}"] = x
        if i % 2 == 1:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1),
                "VALID")
        i += 1
    b = x.shape[0]
    x = x.reshape(b, -1)
    logits = x @ params["head"]["w"].astype(x.dtype) \
        + params["head"]["b"].astype(x.dtype)
    return logits, acts


def convnet_loss(params, batch):
    logits, _ = forward_convnet(params, batch["images"])
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, batch["labels"][:, None], 1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(lf, -1) == batch["labels"]).astype(
        jnp.float32))
    return loss, acc


def prune_convnet(params: dict, indices: dict) -> dict:
    """Structured prune: keep the given output-filter indices per layer,
    slicing the next layer's input channels to match."""
    out = {}
    prev_keep = None
    i = 0
    while f"conv{i}" in params:
        p = params[f"conv{i}"]
        kern = p["kernel"]
        if prev_keep is not None:
            kern = kern[:, :, prev_keep, :]
        keep = indices.get(f"conv{i}")
        if keep is not None:
            kern = kern[..., keep]
            bias = p["bias"][keep]
            prev_keep = np.asarray(keep)
        else:
            bias = p["bias"]
            prev_keep = None
        out[f"conv{i}"] = {"kernel": kern, "bias": bias}
        i += 1
    # head input: channels interleaved with spatial dims (feat*feat*C)
    head_w = params["head"]["w"]
    if prev_keep is not None:
        cin_old = params[f"conv{i-1}"]["kernel"].shape[-1]
        spatial = head_w.shape[0] // cin_old
        hw = head_w.reshape(spatial, cin_old, -1)[:, prev_keep]
        head_w = hw.reshape(spatial * len(prev_keep), -1)
    out["head"] = {"w": head_w, "b": params["head"]["b"]}
    return out


def synthetic_cifar(step: int, batch: int = 64, image: int = 32,
                    n_classes: int = 10, seed: int = 0):
    """Learnable synthetic image task: class k = base pattern k + noise."""
    rng = np.random.default_rng((seed, step))
    base = np.random.default_rng(1234).standard_normal(
        (n_classes, image, image, 3)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=(batch,))
    images = base[labels] + 0.8 * rng.standard_normal(
        (batch, image, image, 3)).astype(np.float32)
    return {"images": jnp.asarray(images),
            "labels": jnp.asarray(labels.astype(np.int32))}


def conv_layer_shapes(widths, batch: int = 64, image: int = 32,
                      in_channels: int = 3, shard: int = 1):
    """LayerShape list for the tail model (im2col mapping)."""
    from repro.core.tail_model import LayerShape
    out = []
    cin = in_channels
    hw = image
    for i, w in enumerate(widths):
        out.append(LayerShape(
            name=f"conv{i}", tokens=batch * hw * hw, d_in=9 * cin,
            width=w, shard_out=shard))
        cin = w
        if i % 2 == 1:
            hw //= 2
    return out


def count_conv_params(widths, in_channels: int = 3, image: int = 32,
                      n_classes: int = 10) -> int:
    total = 0
    cin = in_channels
    for i, w in enumerate(widths):
        total += 9 * cin * w + w
        cin = w
    feat = image // (2 ** (len(widths) // 2))
    total += feat * feat * cin * n_classes + n_classes
    return total


def count_conv_flops(widths, batch: int = 1, image: int = 32,
                     in_channels: int = 3) -> float:
    total = 0.0
    cin = in_channels
    hw = image
    for i, w in enumerate(widths):
        total += 2.0 * batch * hw * hw * 9 * cin * w
        cin = w
        if i % 2 == 1:
            hw //= 2
    return total
