"""Attention: GQA, causal/local/bidirectional/cross, prefill + decode.

Three execution paths:
  * ``chunked_attention`` — flash-style online-softmax over KV chunks in pure
    jnp (lax.scan).  Memory-safe at 32k context; the dry-run lowers this.
    On TPU runtime, ops.py dispatches to the Pallas flash kernel instead.
  * ``decode_attention`` — single-token attention against a full cache
    (single-device / replicated path).
  * ``flash_decode_sharded`` — sequence-parallel decode: the KV cache is
    sharded along *sequence* over the ``model`` mesh axis; each shard
    computes partial softmax stats over its chunk and the result is combined
    with pmax/psum (flash-decoding), inside ``shard_map``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import COMPUTE_DTYPE, PARAM_DTYPE, cast, dense_init
from repro.parallel.sharding import (
    shard, current_mesh, logical_to_pspec, batch_axes,
)

NEG_INF = -1e30


def _vmem_scope(name, fn):
    """Tag a region whose intermediates are VMEM-resident in the Pallas
    kernel (ops.py) — the loop-aware byte model skips their HBM traffic."""
    from functools import wraps

    @wraps(fn)
    def wrapped(*a, **k):
        with jax.named_scope(name):
            return fn(*a, **k)
    return wrapped


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   bias: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads, head_dim),
                         in_axis_size=d_model),
        "wk": dense_init(ks[1], (d_model, n_kv, head_dim),
                         in_axis_size=d_model),
        "wv": dense_init(ks[2], (d_model, n_kv, head_dim),
                         in_axis_size=d_model),
        "wo": dense_init(ks[3], (n_heads, head_dim, d_model),
                         in_axis_size=n_heads * head_dim),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), PARAM_DTYPE)
        p["bk"] = jnp.zeros((n_kv, head_dim), PARAM_DTYPE)
        p["bv"] = jnp.zeros((n_kv, head_dim), PARAM_DTYPE)
    return p


def qkv_proj(p: dict, x: jax.Array):
    q = jnp.einsum("...d,dhk->...hk", x, cast(p["wq"]))
    k = jnp.einsum("...d,dhk->...hk", x, cast(p["wk"]))
    v = jnp.einsum("...d,dhk->...hk", x, cast(p["wv"]))
    if "bq" in p:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def kv_proj(p: dict, x: jax.Array):
    k = jnp.einsum("...d,dhk->...hk", x, cast(p["wk"]))
    v = jnp.einsum("...d,dhk->...hk", x, cast(p["wv"]))
    if "bk" in p:
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    return k, v


def out_proj(p: dict, o: jax.Array) -> jax.Array:
    out = jnp.einsum("...hk,hkd->...d", o, cast(p["wo"]))
    return shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# chunked flash attention (reference path; lowered in the dry-run)
# ---------------------------------------------------------------------------
def _chunk_sizes(sq: int, skv: int, q_chunk: int, kv_chunk: int):
    qc = min(q_chunk, sq)
    while sq % qc:
        qc //= 2
    kc = min(kv_chunk, skv)
    while skv % kc:
        kc //= 2
    return max(qc, 1), max(kc, 1)


def chunked_attention(
    q: jax.Array,            # (B, Sq, H, dh)
    k: jax.Array,            # (B, Skv, KV, dh)
    v: jax.Array,            # (B, Skv, KV, dh)
    *,
    mask_kind: str = "causal",     # causal | local | none
    window: int = 0,
    q_offset: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    kv_len: Optional[jax.Array] = None,   # valid kv length (ragged masking)
) -> jax.Array:
    """Online-softmax attention over KV chunks; returns (B, Sq, H, dh)."""
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    qc, kc = _chunk_sizes(sq, skv, q_chunk, kv_chunk)
    nq, nk = sq // qc, skv // kc

    qr = q.reshape(b, nq, qc, kv, g, dh).astype(COMPUTE_DTYPE)
    kr = k.reshape(b, nk, kc, kv, dh).astype(COMPUTE_DTYPE)
    vr = v.reshape(b, nk, kc, kv, dh).astype(COMPUTE_DTYPE)

    q_pos_base = q_offset + jnp.arange(nq) * qc            # (nq,)
    k_pos_base = jnp.arange(nk) * kc                       # (nk,)

    @jax.checkpoint
    @partial(_vmem_scope, "vmem_resident_flash")
    def q_step(_, qi):
        # Rematted: the backward pass recomputes per-chunk probabilities
        # from the (tiny) chunk inputs instead of saving the (qc, kc)
        # score/probability blocks of every chunk pair — this is what makes
        # the pure-jnp path flash-like in memory, not just compute.
        qblk, qpos0 = qi                                   # (b,qc,kv,g,dh)
        qpos = qpos0 + jnp.arange(qc)                      # (qc,)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, kpos0 = ki
            kpos = kpos0 + jnp.arange(kc)                  # (kc,)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), bool)
            if mask_kind in ("causal", "local"):
                mask &= kpos[None, :] <= qpos[:, None]
            if mask_kind == "local" and window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            if kv_len is not None:
                mask &= kpos[None, :] < kv_len
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))    # (b,kv,g,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd",
                            p.astype(COMPUTE_DTYPE), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
             k_pos_base))
        o = acc / jnp.maximum(l, 1e-30)[..., None]         # (b,kv,g,qc,dh)
        return None, o

    _, outs = jax.lax.scan(q_step, None,
                           (qr.transpose(1, 0, 2, 3, 4, 5), q_pos_base))
    # outs: (nq, b, kv, g, qc, dh) -> (b, sq, h, dh)
    o = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, dh)
    return o.astype(COMPUTE_DTYPE)


def prefill_attention(q, k, v, *, mask_kind: str = "causal",
                      window: int = 0) -> jax.Array:
    """Prefill attention through the ambient kernel context.

    When a ``kernels.ops.kernel_context`` is installed and would reach a
    kernel backend (TPU or ``force='pallas_interpret'``), causal prefill
    routes through ``ops.flash_attention`` so it runs on the autotuned
    wave-aligned tiles of the context's hardware spec.  Otherwise — the
    historical CPU/ref path — this is exactly ``chunked_attention``."""
    from repro.kernels import ops
    if mask_kind == "causal" and ops.kernel_routing_active():
        return ops.flash_attention(q, k, v, mask_kind="causal",
                                   window=window)
    return chunked_attention(q, k, v, mask_kind=mask_kind, window=window)


def local_attention_prefill(q, k, v, *, window: int, q_offset: int = 0,
                            q_chunk: int = 1024) -> jax.Array:
    """Sliding-window attention that only touches the window's KV chunks.

    For each query chunk we slice a (window + q_chunk) KV strip — total work
    O(S * window) rather than O(S^2) — the sub-quadratic path that makes
    long_500k viable for recurrentgemma.
    """
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    qc, _ = _chunk_sizes(sq, skv, q_chunk, q_chunk)
    strip = min(skv, window + qc)
    if strip >= skv:
        return chunked_attention(q, k, v, mask_kind="local", window=window,
                                 q_offset=q_offset)
    nq = sq // qc
    qr = q.reshape(b, nq, qc, h, dh)

    @partial(_vmem_scope, "vmem_resident_flash_local")
    def q_step(_, qi):
        qblk, idx = qi
        qpos0 = q_offset + idx * qc
        start = jnp.clip(qpos0 + qc - strip, 0, skv - strip)
        ks = jax.lax.dynamic_slice_in_dim(k, start, strip, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, start, strip, axis=1)
        g = h // kv
        scale = 1.0 / math.sqrt(dh)
        s = jnp.einsum("bqkgd,bckd->bkgqc",
                       qblk.reshape(b, qc, kv, g, dh).astype(COMPUTE_DTYPE),
                       ks.astype(COMPUTE_DTYPE),
                       preferred_element_type=jnp.float32) * scale
        qpos = qpos0 + jnp.arange(qc)[:, None]
        kpos = start + jnp.arange(strip)[None, :]
        mask = (kpos <= qpos) & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(COMPUTE_DTYPE), vs,
                       preferred_element_type=jnp.float32)
        return None, o.reshape(b, qc, h, dh).astype(COMPUTE_DTYPE)

    _, outs = jax.lax.scan(q_step, None,
                           (qr.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, cache_len) -> jax.Array:
    """Single-token attention, replicated cache.  q: (B, H, dh).

    ``cache_len`` is the valid cache length — a scalar (lockstep decode)
    or a (B,) vector (ragged decode: each slot of a continuous batch at
    its own position)."""
    b, h, dh = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bkgd,bskd->bkgs",
                   q.reshape(b, kv, g, dh).astype(COMPUTE_DTYPE),
                   k_cache.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 1:                # per-slot valid lengths
        cache_len = cache_len[:, None, None, None]
    s = jnp.where(pos[None, None, None, :] < cache_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(COMPUTE_DTYPE),
                   v_cache.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, h, dh).astype(COMPUTE_DTYPE)


def chunk_prefill_attention(q, k_cache, v_cache, offset) -> jax.Array:
    """Chunked-prefill attention: a (B, C, H, dh) query chunk whose rows
    sit at absolute positions ``offset .. offset + C`` attends causally
    over a full-capacity cache (B, S, KV, dh) that already holds every
    previously committed chunk's K/V *and* this chunk's own rows
    (written at ``[offset, offset + C)`` before the call).

    Row ``i`` of the chunk sees exactly keys ``0 .. offset + i`` — the
    same key set a whole-prompt causal prefill gives it — so chunked and
    whole-prompt prefill agree.  Rows past the real chunk length (a
    pow2-bucketed final chunk) compute garbage that the caller never
    commits, exactly like bucketed prefill pad rows.  ``offset`` may be
    a traced scalar: one executable serves every chunk position."""
    b, c, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    sc = jnp.einsum("bqkgd,bskd->bkgqs",
                    q.reshape(b, c, kv, g, dh).astype(COMPUTE_DTYPE),
                    k_cache.astype(COMPUTE_DTYPE),
                    preferred_element_type=jnp.float32) * scale
    qpos = offset + jnp.arange(c)
    kpos = jnp.arange(s)
    mask = kpos[None, :] <= qpos[:, None]              # (c, s)
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(COMPUTE_DTYPE),
                   v_cache.astype(COMPUTE_DTYPE),
                   preferred_element_type=jnp.float32)
    return o.reshape(b, c, h, dh).astype(COMPUTE_DTYPE)


def _dp_axes(mesh: Mesh):
    return batch_axes(mesh)


def flash_decode_sharded(q, k_cache, v_cache, cache_len, mesh: Mesh,
                         seq_axis: str = "model") -> jax.Array:
    """Sequence-parallel decode attention (flash-decoding on the mesh).

    q:        (B, H, dh)      — batch over data axes, replicated over model
    caches:   (B, S, KV, dh)  — batch over data axes, S sharded over `model`
    Each model-shard computes partial (m, l, o) over its local S chunk; the
    global softmax is reconstructed with pmax/psum.
    """
    if seq_axis not in mesh.axis_names:
        return decode_attention(q, k_cache, v_cache, cache_len)
    n_shards = mesh.shape[seq_axis]
    s_total = k_cache.shape[1]
    s_loc = s_total // n_shards
    dp = _dp_axes(mesh)

    def f(qb, kb, vb, clen):
        b, h, dh = qb.shape
        kv = kb.shape[2]
        g = h // kv
        scale = 1.0 / math.sqrt(dh)
        off = jax.lax.axis_index(seq_axis) * s_loc
        pos = off + jnp.arange(s_loc)
        valid = pos < clen
        s = jnp.einsum("bkgd,bskd->bkgs",
                       qb.reshape(b, kv, g, dh).astype(COMPUTE_DTYPE),
                       kb.astype(COMPUTE_DTYPE),
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        m_loc = jnp.maximum(jnp.max(s, axis=-1), NEG_INF)   # (b,kv,g)
        p = jnp.exp(s - m_loc[..., None])
        p = jnp.where(valid[None, None, None, :], p, 0.0)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bkgs,bskd->bkgd", p.astype(COMPUTE_DTYPE),
                           vb.astype(COMPUTE_DTYPE),
                           preferred_element_type=jnp.float32)
        m_glob = jax.lax.pmax(m_loc, seq_axis)
        corr = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * corr, seq_axis)
        o = jax.lax.psum(o_loc * corr[..., None], seq_axis)
        o = o / jnp.maximum(l_glob, 1e-30)[..., None]
        return o.reshape(b, h, dh).astype(COMPUTE_DTYPE)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, seq_axis, None, None),
                  P(dp, seq_axis, None, None), P()),
        out_specs=P(dp, None, None),
    )(q, k_cache, v_cache, cache_len)


def update_cache_sharded(cache, new, pos, mesh: Optional[Mesh],
                         seq_axis: str = "model"):
    """Write (B, KV, dh) `new` at sequence position `pos` of a seq-sharded
    cache (B, S, KV, dh).  Only the owning shard commits the write."""
    if mesh is None or seq_axis not in mesh.axis_names:
        return jax.lax.dynamic_update_slice(
            cache, new[:, None].astype(cache.dtype), (0, pos, 0, 0))
    n_shards = mesh.shape[seq_axis]
    s_loc = cache.shape[1] // n_shards
    dp = _dp_axes(mesh)

    def f(c, n, p):
        off = jax.lax.axis_index(seq_axis) * s_loc
        i = p - off
        inb = (i >= 0) & (i < s_loc)
        i_c = jnp.clip(i, 0, s_loc - 1)
        upd = jax.lax.dynamic_update_slice(
            c, n[:, None].astype(c.dtype), (0, i_c, 0, 0))
        return jnp.where(inb, upd, c)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(dp, seq_axis, None, None), P(dp, None, None), P()),
        out_specs=P(dp, seq_axis, None, None),
    )(cache, new, pos)
