"""Mixture-of-Experts: top-k routing with capacity, EP over the model axis.

Two execution strategies:
  * ``dense``    — every expert computes every token, gated combine.  Exact,
    used for tiny smoke configs and as the routing oracle in tests.
  * ``capacity`` — sort-based dispatch to per-expert capacity buffers
    (grouped GEMM), token dropping beyond capacity.  Inside ``shard_map``
    the experts are sharded over the ``model`` axis (expert parallelism) and
    the expert weights' d_model dim is sharded over ``data`` (FSDP) and
    all-gathered in bf16 at use; outputs psum over the model axis.

Routing semantics (both paths): softmax router in fp32, top-k, gate
renormalization over the selected experts, Switch-style load-balance aux
loss + router z-loss.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import COMPUTE_DTYPE, PARAM_DTYPE, cast, dense_init
from repro.parallel.sharding import shard, batch_axes


def init_moe(key, d_model: int, n_experts: int, moe_d_ff: int,
             shared: bool, d_ff_shared: int) -> dict:
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d_model, n_experts)),
        "experts": {
            "w_gate": dense_init(ks[1], (n_experts, d_model, moe_d_ff),
                                 in_axis_size=d_model),
            "w_up": dense_init(ks[2], (n_experts, d_model, moe_d_ff),
                               in_axis_size=d_model),
            "w_down": dense_init(ks[3], (n_experts, moe_d_ff, d_model),
                                 in_axis_size=moe_d_ff),
        },
    }
    if shared:
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d_model, d_ff_shared)),
            "w_up": dense_init(ks[5], (d_model, d_ff_shared)),
            "w_down": dense_init(jax.random.fold_in(key, 9),
                                 (d_ff_shared, d_model),
                                 in_axis_size=d_ff_shared),
        }
    return p


def route(p: dict, x: jax.Array, k: int):
    """Router: returns (gates (..., k) fp32, ids (..., k) int32, aux dict)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    # Switch load-balance loss: E * sum_e f_e * p_e
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    assign = jax.nn.one_hot(ids.reshape(-1, k), e, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(assign, axis=1), axis=0) / k
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gates, ids, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}


def _expert_ffn(w, h_in):
    """h_in: (E, C, D); w: expert weight dict -> (E, C, D)."""
    g = jnp.einsum("ecd,edf->ecf", h_in, w["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", h_in, w["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w["w_down"])


def _shared_ffn(p, x):
    g = jnp.einsum("...d,df->...f", x, cast(p["w_gate"]))
    u = jnp.einsum("...d,df->...f", x, cast(p["w_up"]))
    h = jax.nn.silu(g) * u
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("...f,fd->...d", h, cast(p["w_down"]))


# ---------------------------------------------------------------------------
# dense strategy (oracle / tiny configs)
# ---------------------------------------------------------------------------
def apply_moe_dense(p: dict, x: jax.Array, k: int):
    gates, ids, aux = route(p, x, k)
    e = p["router"].shape[-1]
    w = p["experts"]
    g_ = jnp.einsum("...d,edf->...ef", x, cast(w["w_gate"]))
    u_ = jnp.einsum("...d,edf->...ef", x, cast(w["w_up"]))
    h = jax.nn.silu(g_) * u_
    y_all = jnp.einsum("...ef,efd->...ed", h, cast(w["w_down"]))
    combine = jnp.sum(
        jax.nn.one_hot(ids, e, dtype=jnp.float32) * gates[..., None], axis=-2)
    y = jnp.einsum("...ed,...e->...d", y_all.astype(jnp.float32), combine)
    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + _shared_ffn(p["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# capacity strategy (production; optional EP via shard_map)
# ---------------------------------------------------------------------------
def _capacity(tokens: int, k: int, n_experts: int, cf: float) -> int:
    c = int(tokens * k * cf / n_experts) + 1
    return max(c, 1)


def _dispatch_compute_combine(x_flat, ids, gates, w_gate, w_up, w_down,
                              e_lo: int, e_local: int, n_experts: int,
                              capacity: int):
    """Sort-based capacity dispatch for experts [e_lo, e_lo + e_local).

    x_flat: (T, D); ids/gates: (T, k).  Returns (T, D) contribution of the
    local experts only (tokens routed elsewhere contribute zero).
    """
    t, d = x_flat.shape
    k = ids.shape[-1]
    tk = t * k
    flat_ids = ids.reshape(tk)
    flat_gates = gates.reshape(tk)
    local = (flat_ids >= e_lo) & (flat_ids < e_lo + e_local)
    local_ids = jnp.where(local, flat_ids - e_lo, e_local)   # e_local = trash
    perm = jnp.argsort(local_ids, stable=True)
    sorted_ids = local_ids[perm]
    # position within expert: index in sorted order minus the expert's start
    counts = jnp.bincount(sorted_ids, length=e_local + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)])[:-1]
    pos_in_e = jnp.arange(tk) - starts[sorted_ids]
    keep = (sorted_ids < e_local) & (pos_in_e < capacity)
    dest = jnp.where(keep, sorted_ids * capacity + pos_in_e,
                     e_local * capacity)                      # trash row
    src_token = perm // k
    buf = jnp.zeros((e_local * capacity + 1, d), x_flat.dtype)
    buf = buf.at[dest].set(x_flat[src_token], mode="drop")
    h_in = buf[:-1].reshape(e_local, capacity, d)
    h_out = _expert_ffn({"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
                        h_in)
    out_flat = jnp.concatenate(
        [h_out.reshape(e_local * capacity, d),
         jnp.zeros((1, d), h_out.dtype)], axis=0)
    y_sorted = out_flat[dest] * flat_gates[perm][:, None].astype(h_out.dtype)
    # unsort and combine over k
    y_tk = jnp.zeros((tk, d), h_out.dtype).at[perm].set(y_sorted)
    return jnp.sum(y_tk.reshape(t, k, d), axis=1)


def apply_moe_capacity(p: dict, x: jax.Array, k: int, capacity_factor: float,
                       mesh: Optional[Mesh] = None, ep_axis: str = "model"):
    """Capacity-dispatch MoE.  x: (B, S, D).  EP over `ep_axis` if a mesh
    with that axis is supplied (experts already sharded there by the param
    specs); FSDP all-gather of expert weights over 'data' happens inside."""
    b, s, d = x.shape
    n_experts = p["router"].shape[-1]
    gates, ids, aux = route(p, x, k)
    x_flat = x.reshape(b * s, d)
    ids_f = ids.reshape(b * s, k)
    gates_f = gates.reshape(b * s, k).astype(COMPUTE_DTYPE)

    w = p["experts"]

    use_ep = (mesh is not None and ep_axis in mesh.axis_names
              and n_experts % mesh.shape[ep_axis] == 0)
    if not use_ep:
        cap = _capacity(b * s, k, n_experts, capacity_factor)
        y = _dispatch_compute_combine(
            x_flat, ids_f, gates_f, cast(w["w_gate"]), cast(w["w_up"]),
            cast(w["w_down"]), 0, n_experts, n_experts, cap)
        y = y.reshape(b, s, d)
    else:
        ep = mesh.shape[ep_axis]
        e_local = n_experts // ep
        dp = batch_axes(mesh)
        dp_n = 1
        for a in ((dp,) if isinstance(dp, str) else (dp or ())):
            dp_n *= mesh.shape[a]
        # capacity is per-expert over the tokens each shard actually sees
        cap = _capacity(max(b * s // dp_n, 1), k, n_experts,
                        capacity_factor)
        fsdp = "data" if "data" in mesh.axis_names else None

        def f(xb, idb, gb, wg, wu, wd):
            # xb: (T_loc, D) — local batch shard, replicated over model.
            # wg/wu/wd: local experts, d_model sharded over data -> gather.
            if fsdp is not None:
                wg = jax.lax.all_gather(wg.astype(COMPUTE_DTYPE), fsdp,
                                        axis=1, tiled=True)
                wu = jax.lax.all_gather(wu.astype(COMPUTE_DTYPE), fsdp,
                                        axis=1, tiled=True)
                wd = jax.lax.all_gather(wd.astype(COMPUTE_DTYPE), fsdp,
                                        axis=2, tiled=True)
            else:
                wg, wu, wd = (a.astype(COMPUTE_DTYPE) for a in (wg, wu, wd))
            e_lo = jax.lax.axis_index(ep_axis) * e_local
            y = _dispatch_compute_combine(xb, idb, gb, wg, wu, wd,
                                          e_lo, e_local, n_experts, cap)
            return jax.lax.psum(y, ep_axis)

        y = shard_map(
            f, mesh=mesh,
            in_specs=(P(dp, None), P(dp, None), P(dp, None),
                      P(ep_axis, fsdp, None), P(ep_axis, fsdp, None),
                      P(ep_axis, None, fsdp)),
            out_specs=P(dp, None),
        )(x_flat, ids_f, gates_f, w["w_gate"], w["w_up"], w["w_down"])
        y = y.reshape(b, s, d)

    y = y.astype(x.dtype)
    if "shared" in p:
        y = y + _shared_ffn(p["shared"], x)
    return shard(y, "batch", "seq", "embed"), aux


def apply_moe(p: dict, x: jax.Array, k: int, capacity_factor: float,
              strategy: str = "auto", mesh: Optional[Mesh] = None):
    if strategy == "dense":
        return apply_moe_dense(p, x, k)
    if strategy == "capacity" or (strategy == "auto" and mesh is not None):
        return apply_moe_capacity(p, x, k, capacity_factor, mesh)
    return apply_moe_dense(p, x, k)
