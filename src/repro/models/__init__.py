from repro.models.transformer import (
    init_params, forward, train_loss, decode_step, init_decode_state,
    encode, count_params_analytic, layer_plan, unit_cycle,
    decoder_layer_refs,
)

__all__ = [
    "init_params", "forward", "train_loss", "decode_step",
    "init_decode_state", "encode", "count_params_analytic", "layer_plan",
    "unit_cycle", "decoder_layer_refs",
]
