"""Pure-jnp oracles for every Pallas kernel (the contract the kernels must
match; tests sweep shapes/dtypes and assert_allclose against these)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, mask_kind: str = "causal",
                  window: int = 0) -> jax.Array:
    """Exact softmax attention.  q: (B,Sq,H,dh); k/v: (B,Skv,KV,dh)."""
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    qf = q.reshape(b, sq, kv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    iq = jnp.arange(sq)[:, None]
    jk = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if mask_kind in ("causal", "local"):
        mask &= jk <= iq
    if mask_kind == "local" and window > 0:
        mask &= jk > iq - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)


def rglru_ref(a, b, h0):
    """Sequential y_t = a_t*h_{t-1} + b_t.  a/b: (B,T,W) f32; h0: (B,W)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    h, ys = jax.lax.scan(step, h0, (a.transpose(1, 0, 2),
                                    b.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), h


def rwkv6_ref(r, k, v, log_w, u):
    """Sequential RWKV6 core (see models/recurrent.rwkv_ref)."""
    from repro.models.recurrent import rwkv_ref
    return rwkv_ref(r, k, v, log_w, u)[0]


def moe_gmm_ref(x, w):
    """(E,C,D) @ (E,D,F) -> (E,C,F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
