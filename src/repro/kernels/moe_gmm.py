"""Grouped (per-expert) matmul Pallas kernel for capacity-dispatched MoE.

Computes  out[e] = x[e] @ w[e]  for E experts with capacity-C token buffers:
x: (E, C, D), w: (E, D, F) -> (E, C, F).  Grid: (E, C/bc, F/bf, D/bd) with
the contraction dim innermost and an fp32 VMEM accumulator.

The capacity buffer is the MoE incarnation of the paper's tail: C is padded
to the sublane quantum and E to the EP shard count, so the grid is exactly
full — tokens beyond capacity were dropped at dispatch (routing jitter), and
slack rows below capacity are the idle tail the capacity_factor trades
against drop rate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(di == n_d - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_pallas(x: jax.Array, w: jax.Array, *, block_c: int = 128,
                   block_f: int = 256, block_d: int = 256,
                   interpret: bool = False) -> jax.Array:
    """x: (E, C, D) @ w: (E, D, F) -> (E, C, F)."""
    e, c, d = x.shape
    e2, d2, f = w.shape
    assert e == e2 and d == d2, (x.shape, w.shape)
    bc, bf, bd = min(block_c, c), min(block_f, f), min(block_d, d)
    if c % bc or f % bf or d % bd:
        raise ValueError(
            f"moe_gmm_pallas needs block-divisible dims: (C, F, D)="
            f"({c}, {f}, {d}) is not divisible by blocks ({bc}, {bf}, {bd})"
            f" (requested ({block_c}, {block_f}, {block_d}), clamped to the"
            f" dims). Pad C/F/D up to block multiples and slice the output"
            f" — ops.moe_gmm does this automatically.")
    grid = (e, c // bc, f // bf, d // bd)

    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_d=d // bd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
