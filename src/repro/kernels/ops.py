"""Jit'd dispatch wrappers: Pallas kernel on TPU, reference path elsewhere.

The model code calls these; on the CPU dry-run they lower the memory-safe
jnp reference (real HLO, real cost analysis), on TPU runtime they hit the
Pallas kernels, and with ``force='pallas_interpret'`` they execute the
kernel bodies in Python for correctness tests.

Tile selection: every kernel wrapper takes either explicit block args or
``hw=`` (a ``HardwareSpec``), in which case blocks come from the
tail-aware autotuner (``repro.kernels.autotune`` — roofline + Eq. 3
grid-wave scoring, memoized per hardware/shape and optionally persisted
via ``cache=``).  With neither, the historical fixed defaults apply.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_lib
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul_tiled import matmul_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.rglru import rglru_pallas
from repro.kernels.rwkv6 import rwkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: Optional[str]) -> str:
    if force:
        return force
    return "pallas" if _on_tpu() else "ref"


@dataclasses.dataclass(frozen=True)
class KernelContext:
    """Ambient tile-selection state for model code that cannot thread
    ``hw=``/``cache=``/``force=`` through every call site (e.g. the
    transformer forward traced inside a serving jit).  Installed with
    :func:`kernel_context`; the dispatch wrappers below fall back to it
    whenever their own hw/cache/force arguments are left unset."""

    hw: Any = None
    cache: Any = None
    force: Optional[str] = None


_KERNEL_CTX: Optional[KernelContext] = None


def get_kernel_context() -> Optional[KernelContext]:
    return _KERNEL_CTX


def kernel_routing_active() -> bool:
    """True when an installed kernel context would actually reach a
    kernel backend.  In ref mode the wrappers route to the jnp reference
    paths, whose numerics differ from the models' native einsum code —
    callers must keep their historical path then, so a context on a
    CPU-only run is inert by construction."""
    ctx = _KERNEL_CTX
    return ctx is not None and _mode(ctx.force) != "ref"


@contextlib.contextmanager
def kernel_context(hw=None, cache=None, force: Optional[str] = None):
    """Install a :class:`KernelContext` for the duration of the block.
    Trace-time scoping: model code traced under this context bakes the
    context's tile choices into the jaxpr, so an AOT-compiled executable
    keeps its autotuned blocks forever."""
    global _KERNEL_CTX
    prev = _KERNEL_CTX
    _KERNEL_CTX = KernelContext(hw=hw, cache=cache, force=force)
    try:
        yield _KERNEL_CTX
    finally:
        _KERNEL_CTX = prev


def _ctx_fallback(hw, cache, force):
    """Fill unset hw/cache/force from the ambient context, if any."""
    ctx = _KERNEL_CTX
    if ctx is None:
        return hw, cache, force
    return (hw if hw is not None else ctx.hw,
            cache if cache is not None else ctx.cache,
            force if force is not None else ctx.force)


def _dtype_bits(x) -> int:
    return jnp.asarray(x).dtype.itemsize * 8


def matmul(x, w, *, block_m: Optional[int] = None,
           block_n: Optional[int] = None, block_k: Optional[int] = None,
           hw=None, cache=None, force: Optional[str] = None):
    """Tile-quantized matmul.  Pads M/N/K up to block multiples — the pad
    FLOPs are the tail the width optimizer removes by resizing N."""
    hw, cache, force = _ctx_fallback(hw, cache, force)
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.matmul_ref(x, w)
    m, k = x.shape
    _, n = w.shape
    if hw is not None and block_m is None and block_n is None \
            and block_k is None:
        from repro.kernels.autotune import autotune_matmul
        cfg = autotune_matmul(hw, m, n, k, dtype_bits=_dtype_bits(x),
                              cache=cache)
        block_m, block_n, block_k = cfg.blocks
    block_m = 256 if block_m is None else block_m
    block_n = 256 if block_n is None else block_n
    block_k = 512 if block_k is None else block_k
    pad = lambda d, b: (-d) % b
    pm = pad(m, min(block_m, m))
    pn = pad(n, min(block_n, n))
    pk = pad(k, min(block_k, k))
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    out = matmul_pallas(xp, wp, block_m=block_m, block_n=block_n,
                        block_k=block_k,
                        interpret=(mode == "pallas_interpret"))
    return out[:m, :n]


def flash_attention(q, k, v, *, mask_kind: str = "causal", window: int = 0,
                    block_q: Optional[int] = None,
                    block_kv: Optional[int] = None,
                    hw=None, cache=None, force: Optional[str] = None):
    """Flash attention.  Non-divisible sequences are zero-padded for
    causal/local masks (trailing padded kv positions are masked out by
    position, padded q rows are sliced off — exact); an unmasked
    attention cannot pad kv, so non-divisible Skv raises there."""
    hw, cache, force = _ctx_fallback(hw, cache, force)
    mode = _mode(force)
    if mode == "ref":
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, mask_kind=mask_kind,
                                 window=window)
    b, sq, h, dh = q.shape
    _, skv, kv_heads, _ = k.shape
    if hw is not None and block_q is None and block_kv is None:
        from repro.kernels.autotune import autotune_flash_attention
        cfg = autotune_flash_attention(hw, b, sq, skv, h, kv_heads, dh,
                                       dtype_bits=_dtype_bits(q),
                                       cache=cache)
        block_q, block_kv = cfg.blocks
    block_q = 512 if block_q is None else block_q
    block_kv = 512 if block_kv is None else block_kv
    bq, bkv = min(block_q, sq), min(block_kv, skv)
    pq, pkv = (-sq) % bq, (-skv) % bkv
    if pq or pkv:
        if pkv and mask_kind not in ("causal", "local"):
            raise ValueError(
                f"flash_attention: Skv={skv} is not divisible by "
                f"block_kv={bkv} and mask_kind={mask_kind!r} attends all "
                f"positions, so kv padding would change the output. Use a "
                f"divisor block_kv (hw= autotuning picks one) or pad kv "
                f"yourself with an explicit mask.")
        if pkv and skv < sq:
            raise ValueError(
                f"flash_attention: cannot pad kv for Skv={skv} < Sq={sq} "
                f"— padded kv positions would be attendable by trailing "
                f"query rows under mask_kind={mask_kind!r}.")
        qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        out = flash_attention_pallas(
            qp, kp, vp, mask_kind=mask_kind, window=window, block_q=bq,
            block_kv=bkv, interpret=(mode == "pallas_interpret"))
        return out[:, :sq]
    return flash_attention_pallas(
        q, k, v, mask_kind=mask_kind, window=window, block_q=block_q,
        block_kv=block_kv, interpret=(mode == "pallas_interpret"))


def rglru_scan(a, b, h0, *, force: Optional[str] = None):
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.rglru_ref(a, b, h0)
    return rglru_pallas(a, b, h0,
                        interpret=(mode == "pallas_interpret"))


def rwkv6(r, k, v, log_w, u, *, chunk: int = 32,
          force: Optional[str] = None):
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.rwkv6_ref(r, k, v, log_w, u)
    return rwkv6_pallas(r, k, v, log_w, u, chunk=chunk,
                        interpret=(mode == "pallas_interpret"))


def moe_gmm(x, w, *, block_c: Optional[int] = None,
            block_f: Optional[int] = None, block_d: Optional[int] = None,
            hw=None, cache=None, force: Optional[str] = None):
    """Grouped expert matmul.  Pads C/F/D up to block multiples (padded
    rows/cols are sliced off; padded D lanes contribute exact zeros)."""
    hw, cache, force = _ctx_fallback(hw, cache, force)
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.moe_gmm_ref(x, w)
    e, c, d = x.shape
    _, _, f = w.shape
    if hw is not None and block_c is None and block_f is None \
            and block_d is None:
        from repro.kernels.autotune import autotune_moe_gmm
        cfg = autotune_moe_gmm(hw, e, c, d, f, dtype_bits=_dtype_bits(x),
                               cache=cache)
        block_c, block_f, block_d = cfg.blocks
    block_c = 128 if block_c is None else block_c
    block_f = 256 if block_f is None else block_f
    block_d = 256 if block_d is None else block_d
    pad = lambda dim, blk: (-dim) % min(blk, dim)
    pc, pf, pd = pad(c, block_c), pad(f, block_f), pad(d, block_d)
    if pc or pf or pd:
        xp = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
        wp = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
        out = moe_gmm_pallas(xp, wp, block_c=block_c, block_f=block_f,
                             block_d=block_d,
                             interpret=(mode == "pallas_interpret"))
        return out[:, :c, :f]
    return moe_gmm_pallas(x, w, block_c=block_c, block_f=block_f,
                          block_d=block_d,
                          interpret=(mode == "pallas_interpret"))


def staircase_latency(widths, shard_out, ca, mb, mc, *, lane: int,
                      force: Optional[str] = None):
    """Fused staircase sweep (see ``kernels.staircase_fused``): a (L, C)
    width matrix + per-row affine coefficients -> (latency, waves,
    occupancy).  Pallas kernel on TPU (or under ``pallas_interpret``),
    fp64 NumPy fused reference elsewhere."""
    from repro.kernels.staircase_fused import (
        fused_staircase_reference, staircase_fused_pallas)
    mode = _mode(force)
    if mode == "ref":
        return fused_staircase_reference(widths, shard_out, ca, mb, mc,
                                         lane=lane)
    return staircase_fused_pallas(widths, shard_out, ca, mb, mc, lane=lane,
                                  interpret=(mode == "pallas_interpret"))
