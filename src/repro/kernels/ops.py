"""Jit'd dispatch wrappers: Pallas kernel on TPU, reference path elsewhere.

The model code calls these; on the CPU dry-run they lower the memory-safe
jnp reference (real HLO, real cost analysis), on TPU runtime they hit the
Pallas kernels, and with ``force='pallas_interpret'`` they execute the
kernel bodies in Python for correctness tests.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_lib
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul_tiled import matmul_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.rglru import rglru_pallas
from repro.kernels.rwkv6 import rwkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: Optional[str]) -> str:
    if force:
        return force
    return "pallas" if _on_tpu() else "ref"


def matmul(x, w, *, block_m: int = 256, block_n: int = 256,
           block_k: int = 512, force: Optional[str] = None):
    """Tile-quantized matmul.  Pads M/N/K up to block multiples — the pad
    FLOPs are the tail the width optimizer removes by resizing N."""
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.matmul_ref(x, w)
    m, k = x.shape
    _, n = w.shape
    pad = lambda d, b: (-d) % b
    pm, pn, pk = pad(m, block_m), pad(n, block_n), pad(k, block_k)
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    out = matmul_pallas(xp, wp, block_m=block_m, block_n=block_n,
                        block_k=block_k,
                        interpret=(mode == "pallas_interpret"))
    return out[:m, :n]


def flash_attention(q, k, v, *, mask_kind: str = "causal", window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    force: Optional[str] = None):
    mode = _mode(force)
    if mode == "ref":
        from repro.models.attention import chunked_attention
        return chunked_attention(q, k, v, mask_kind=mask_kind,
                                 window=window)
    return flash_attention_pallas(
        q, k, v, mask_kind=mask_kind, window=window, block_q=block_q,
        block_kv=block_kv, interpret=(mode == "pallas_interpret"))


def rglru_scan(a, b, h0, *, force: Optional[str] = None):
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.rglru_ref(a, b, h0)
    return rglru_pallas(a, b, h0,
                        interpret=(mode == "pallas_interpret"))


def rwkv6(r, k, v, log_w, u, *, chunk: int = 32,
          force: Optional[str] = None):
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.rwkv6_ref(r, k, v, log_w, u)
    return rwkv6_pallas(r, k, v, log_w, u, chunk=chunk,
                        interpret=(mode == "pallas_interpret"))


def moe_gmm(x, w, *, force: Optional[str] = None):
    mode = _mode(force)
    if mode == "ref":
        return ref_lib.moe_gmm_ref(x, w)
    return moe_gmm_pallas(x, w, interpret=(mode == "pallas_interpret"))
