"""Fused staircase sweep — the full-candidate table build as ONE kernel.

The accuracy-mode table build (``tail_optimizer._build_tables(full=True)``)
evaluates the Eq. 3 staircase for every layer x every candidate width.  The
NumPy engine makes ~10 elementwise passes over the (layers, candidates)
matrix — wave count, tile padding, padded FLOPs, byte counts, the
compute/memory roofline combine — so at 1024x1024 it is ALU/memory-pass
bound, not math bound.  This module collapses the whole sweep into one
fused evaluation of an affine-in-waves form.

The algebra: for a fixed layer, every staircase quantity is a function of
the wave count alone,

    n_waves    = ceil(ceil(width / shard_out) / lane)          (Eq. 3 ceil)
    compute_s  = ca * n_waves        ca = 2 * m_pad * k_pad * fm * lane / peak
    memory_s   = mb * n_waves + mc   mb = (k_pad + m_pad) * bytes/elem * lane / bw
                                     mc = m_pad * k_pad * bytes/elem / bw
    latency    = max(compute_s, memory_s)

so the per-layer constants fold into three coefficient columns (``ca``,
``mb``, ``mc``) and the sweep is: one ceil-div, two multiplies, one add,
one max — a single fused pass instead of ten.  ``fused_coeffs`` derives
the columns, ``fused_latency`` is the NumPy evaluation (float64, within a
few ulp of the reference ``WaveQuantizationModel`` math — the rounding
order differs by the factoring), and ``staircase_fused_pallas`` is the
same body as a Pallas TPU kernel (float32 on hardware; interpret mode
executes it anywhere, which is what the differential tests in
``tests/test_staircase_fused.py`` run).  ``kernels.ops.staircase_latency``
dispatches between them.

This module stays importable without jax: the Pallas path imports jax
lazily, so ``core.tail_model``'s ``backend="fused"`` NumPy path adds no
jax dependency to the optimizer's table build.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.hardware import HardwareSpec

__all__ = [
    "fused_coeffs", "fused_columns", "fused_latency",
    "fused_staircase_reference", "staircase_fused_pallas",
]


def fused_coeffs(hw: HardwareSpec, *, two_mk, mk, k_plus_m, fm, bits):
    """Per-layer staircase constants -> affine-in-waves coefficients.

    Accepts scalars or broadcastable arrays (e.g. the (L, 1) columns of
    ``tail_model._LayerColumns``).  ``bits`` must be byte-aligned — the
    exact integer ``elems * bits // 8`` of the reference path only
    factors per-element when ``bits % 8 == 0``.
    """
    bpe = bits // 8
    ca = (two_mk * fm / hw.peak_flops_bf16) * hw.lane
    mb = (k_plus_m * bpe / hw.hbm_bandwidth) * hw.lane
    mc = (mk * bpe) / hw.hbm_bandwidth
    return ca, mb, mc


def fused_columns(hw: HardwareSpec, layers):
    """(shard_out, ca, mb, mc) as (L, 1) columns for a list of
    ``LayerShape``-like objects (tokens / d_in / shard_in / shard_out /
    dtype_bits / flop_multiplier attributes)."""
    def col(vals, dtype):
        return np.asarray(vals, dtype=dtype)[:, None]

    tokens = col([l.tokens for l in layers], np.int64)
    d_in = col([l.d_in for l in layers], np.int64)
    shard_in = col([l.shard_in for l in layers], np.int64)
    shard_out = col([l.shard_out for l in layers], np.int64)
    bits = col([l.dtype_bits for l in layers], np.int64)
    fm = col([l.flop_multiplier for l in layers], np.float64)
    sub = np.where(bits >= 32, hw.sublane_fp32, hw.sublane_bf16)
    m_pad = -(-tokens // sub) * sub
    k_pad = -(-(-(-d_in // shard_in)) // hw.lane) * hw.lane
    ca, mb, mc = fused_coeffs(hw, two_mk=(2.0 * m_pad) * k_pad,
                              mk=m_pad * k_pad, k_plus_m=k_pad + m_pad,
                              fm=fm, bits=bits)
    return shard_out, ca, mb, mc


def _scratch_buf(scratch, key, shape, dtype):
    if scratch is None:
        return np.empty(shape, dtype)
    buf = scratch.get(key)
    if buf is None or buf.shape != shape:
        buf = scratch[key] = np.empty(shape, dtype)
    return buf


def fused_latency(w, shard_out, ca, mb, mc, *, lane: int,
                  all_so1: bool = False, out=None, scratch=None,
                  need_waves: bool = True):
    """One fused pass: latency + wave counts over a width array.

    ``w`` is int64 (any shape); ``shard_out``/``ca``/``mb``/``mc`` are
    scalars or columns broadcastable against it.  Widths must be
    nonnegative (callers with signed sweeps use the reference path).
    Returns ``(latency, n_waves)``; ``out`` receives the latency when
    given (one fewer copy in the chunked table build).

    ``scratch`` (a dict) reuses the integer/float work buffers across
    same-shaped calls — the chunked table build allocates twice per
    BUILD instead of twice per chunk.  The returned ``n_waves`` aliases
    scratch memory, so only pass ``scratch`` when it does not outlive
    the next call.

    ``need_waves=False`` lets latency-only callers skip the integer
    wave array entirely (``n_waves`` comes back None): for unsharded
    stacks on a power-of-two lane, ``ceil(w / lane)`` is computed in
    float64 directly — the division is exact (power-of-two divisor,
    ``w < 2**53``), so the latencies are bit-identical to the integer
    route at two fewer memory passes.
    """
    if (not need_waves and all_so1 and lane & (lane - 1) == 0
            and int(w.max()) < 2 ** 53):
        nwf = _scratch_buf(scratch, "nwf", w.shape, np.float64)
        np.multiply(w, 1.0 / lane, out=nwf)
        np.ceil(nwf, out=nwf)
        nw = None
    else:
        nw = _scratch_buf(scratch, "nw", w.shape, np.int64)
        if all_so1:
            np.add(w, lane - 1, out=nw)
        else:
            np.negative(w, out=nw)           # ceil_div, nonneg
            np.floor_divide(nw, shard_out, out=nw)
            np.negative(nw, out=nw)
            nw += lane - 1
        if lane & (lane - 1) == 0:
            np.right_shift(nw, lane.bit_length() - 1, out=nw)
        else:
            np.floor_divide(nw, lane, out=nw)
        # one int64 -> float64 conversion shared by both affine terms
        # (the naive ``ca * nw`` / ``mb * nw`` pair converts twice)
        nwf = _scratch_buf(scratch, "nwf", nw.shape, np.float64)
        np.copyto(nwf, nw)
    if out is None:
        out = np.empty(nwf.shape, np.float64)
    np.multiply(ca, nwf, out=out)
    nwf *= mb
    nwf += mc
    lat = np.maximum(out, nwf, out=out)
    return lat, nw


def fused_staircase_reference(widths, shard_out, ca, mb, mc, *, lane: int):
    """NumPy float64 reference for the Pallas kernel: (latency, waves,
    tail occupancy) over a (rows, C) width matrix with (rows, 1)
    coefficient columns.  Occupancy is the fraction of the last wave's
    lanes doing useful work: ``per_dev / (n_waves * lane)``."""
    w = np.asarray(widths, dtype=np.int64)
    so = np.asarray(shard_out, dtype=np.int64)
    per_dev = -(-w // so)
    n_waves = -(-per_dev // lane)
    latency = np.maximum(ca * n_waves, mb * n_waves + mc)
    occupancy = per_dev / (n_waves * lane)
    return latency, n_waves, occupancy


@functools.lru_cache(maxsize=8)
def _pallas_fn(lane: int, block_r: int, block_c: int, interpret: bool):
    """Build (and cache) the jit'd pallas_call for one (lane, block)
    configuration.  jax is imported here, not at module scope."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(w_ref, so_ref, ca_ref, mb_ref, mc_ref,
               lat_ref, wv_ref, occ_ref):
        w = w_ref[...]
        so = so_ref[...]
        per_dev = -(-w // so)
        nw = -(-per_dev // lane)
        nwf = nw.astype(jnp.float32)
        lat_ref[...] = jnp.maximum(ca_ref[...] * nwf,
                                   mb_ref[...] * nwf + mc_ref[...])
        wv_ref[...] = nw
        occ_ref[...] = per_dev.astype(jnp.float32) / (nwf * lane)

    @jax.jit
    def call(w, so, ca, mb, mc):
        rows, cols = w.shape
        grid = (rows // block_r, cols // block_c)
        row_spec = pl.BlockSpec((block_r, 1), lambda i, j: (i, 0))
        full_spec = pl.BlockSpec((block_r, block_c), lambda i, j: (i, j))
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[full_spec, row_spec, row_spec, row_spec, row_spec],
            out_specs=[full_spec, full_spec, full_spec],
            out_shape=[
                jax.ShapeDtypeStruct((rows, cols), jnp.float32),
                jax.ShapeDtypeStruct((rows, cols), jnp.int32),
                jax.ShapeDtypeStruct((rows, cols), jnp.float32),
            ],
            interpret=interpret,
        )(w, so, ca, mb, mc)

    return call


def staircase_fused_pallas(widths, shard_out, ca, mb, mc, *, lane: int,
                           block_r: int = 8, block_c: int = 128,
                           interpret: bool = False):
    """The fused staircase sweep as a single Pallas kernel.

    ``widths``: (L, C) nonnegative ints; ``shard_out``/``ca``/``mb``/
    ``mc``: (L, 1) columns.  Inputs are padded up to block multiples
    (pad cells evaluate a harmless width-1/shard-1 staircase and are
    sliced off).  Returns float32/int32/float32 NumPy arrays
    (latency, waves, occupancy) — fp32 is what the TPU VPU computes;
    the fp64 ground truth is ``fused_staircase_reference``.
    """
    import numpy as _np

    w = _np.asarray(widths, dtype=_np.int32)
    if w.ndim != 2:
        raise ValueError(f"widths must be 2-D (layers, candidates), "
                         f"got shape {w.shape}")
    rows, cols = w.shape
    so = _np.broadcast_to(_np.asarray(shard_out, dtype=_np.int32),
                          (rows, 1))
    ca32 = _np.broadcast_to(_np.asarray(ca, dtype=_np.float32), (rows, 1))
    mb32 = _np.broadcast_to(_np.asarray(mb, dtype=_np.float32), (rows, 1))
    mc32 = _np.broadcast_to(_np.asarray(mc, dtype=_np.float32), (rows, 1))

    pr = (-rows) % block_r
    pc = (-cols) % block_c
    if pr or pc:
        w = _np.pad(w, ((0, pr), (0, pc)), constant_values=1)
        so = _np.pad(so, ((0, pr), (0, 0)), constant_values=1)
        ca32 = _np.pad(ca32, ((0, pr), (0, 0)))
        mb32 = _np.pad(mb32, ((0, pr), (0, 0)))
        mc32 = _np.pad(mc32, ((0, pr), (0, 0)))

    call = _pallas_fn(int(lane), block_r, block_c, interpret)
    lat, waves, occ = call(w, so, ca32, mb32, mc32)
    lat = _np.asarray(lat)[:rows, :cols]
    waves = _np.asarray(waves)[:rows, :cols]
    occ = _np.asarray(occ)[:rows, :cols]
    return lat, waves, occ
