"""Flash attention (causal / local / full) as a Pallas TPU kernel.

Grid: (batch*kv_heads*q_per_kv, q_blocks, kv_blocks) with kv innermost; the
online-softmax stats (m, l) and the output accumulator live in VMEM scratch
and persist across the kv-block iterations of one q block (TPU pallas grids
execute sequentially per core, so scratch carries state).

VMEM working set per cell: (bq, dh) q + (bkv, dh) k,v + (bq, bkv) scores +
(bq, dh) acc — with bq=bkv=512, dh=128 that is ~1.5 MiB << VMEM.

The kv grid dimension is NOT truncated for causal masking (every kv block is
visited, fully-masked ones contribute zeros) — this mirrors the XLA
reference path and keeps the kernel simple; the block-triangle skip is a
recorded perf iteration (EXPERIMENTS.md section Perf).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, n_kv: int, bq: int, bkv: int,
                  mask_kind: str, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)          # (bkv, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if mask_kind in ("causal", "local"):
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = kpos <= qpos
        if mask_kind == "local" and window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           mask_kind: str = "causal", window: int = 0,
                           block_q: int = 512, block_kv: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, dh); k/v: (B, Skv, KV, dh) with H % KV == 0.

    Returns (B, Sq, H, dh).  Sq % block_q == 0 and Skv % block_kv == 0.
    """
    b, sq, h, dh = q.shape
    _, skv, kv, _ = k.shape
    g = h // kv
    scale = 1.0 / math.sqrt(dh)
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    if sq % bq or skv % bkv:
        raise ValueError(
            f"flash_attention_pallas needs block-divisible sequences: "
            f"(Sq, Skv)=({sq}, {skv}) is not divisible by blocks "
            f"({bq}, {bkv}) (requested ({block_q}, {block_kv}), clamped to"
            f" the dims). Pad the sequences up to block multiples — "
            f"ops.flash_attention pads causal/local shapes automatically.")

    # layout: fold heads into batch; kv heads repeat via index mapping
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kv, skv, dh)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kv, skv, dh)

    grid = (b * h, sq // bq, skv // bkv)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, n_kv=skv // bkv,
                          bq=bq, bkv=bkv, mask_kind=mask_kind,
                          window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bkv, dh), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, bkv, dh), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
