"""RG-LRU linear recurrence as a Pallas TPU kernel.

Grid: (batch, width_blocks, time_chunks), time innermost; the hidden state
h (1, bw) persists in VMEM scratch across time chunks (sequential grid).
Within a chunk the recurrence h_t = a_t h_{t-1} + b_t is unrolled over the
chunk's CT steps on the VPU — per-channel elementwise work, lane-aligned
blocks of bw channels.

Inputs are the precomputed per-step (a, b) arrays (gates are cheap dense
ops best left to the MXU outside the kernel); this kernel is the memory-
bound sequential core that XLA cannot parallelize well on its own.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_ref, *, ct: int,
                  n_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _():
        h_ref[...] = h0_ref[...]

    h = h_ref[...]                       # (1, bw)
    a = a_ref[0]                         # (ct, bw)
    b = b_ref[0]
    ys = []
    for t in range(ct):
        h = a[t][None, :] * h + b[t][None, :]
        ys.append(h)
    y_ref[0] = jnp.concatenate(ys, axis=0)
    h_ref[...] = h


def rglru_pallas(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                 chunk_t: int = 8, block_w: int = 128,
                 interpret: bool = False):
    """a, b: (B, T, W) fp32; h0: (B, W).  Returns (y (B,T,W), h_last (B,W)).

    y_t = a_t * h_{t-1} + b_t  (h_{-1} = h0).
    """
    bsz, t, w = a.shape
    ct = min(chunk_t, t)
    bw = min(block_w, w)
    assert t % ct == 0 and w % bw == 0, (t, w, ct, bw)
    grid = (bsz, w // bw, t // ct)

    y = pl.pallas_call(
        functools.partial(_rglru_kernel, ct=ct, n_t=t // ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, ct, bw), lambda bi, wi, ti: (bi, ti, wi)),
            pl.BlockSpec((1, bw), lambda bi, wi, ti: (bi, wi)),
        ],
        out_specs=pl.BlockSpec((1, ct, bw), lambda bi, wi, ti: (bi, ti, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, w), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, y[:, -1]
