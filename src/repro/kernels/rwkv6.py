"""RWKV6 chunked linear-attention core as a Pallas TPU kernel.

Grid: (batch*heads, time_chunks), time innermost; the (dh, dh) state matrix
persists in VMEM scratch across chunks.  Each cell computes the exact
chunked form (identical math to models/recurrent.rwkv_chunked):

  o = (tril(r e (k/e)^T) + diag(r u k)) v  +  (r * e) S
  S' = e_C * S + ((e_C / e) k)^T v

with all pairwise decays exp(<=0) — numerically safe.  Intra-chunk work is
two (C, C) @ (C, dh) MXU matmuls per (head, chunk); the state update is a
(dh, C) @ (C, dh) matmul — MXU-aligned when C and dh are multiples of the
tile size (dh=64: half-tile, still efficient with packing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                 c: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)      # (C, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)    # log decay, < 0
    u = u_ref[0].astype(jnp.float32)      # (1, dh) bonus

    le = jnp.cumsum(lw, axis=0)           # (C, dh) inclusive
    # strict lower-triangular pairwise decay factors applied channelwise:
    # scores[i,j] = sum_d r[i,d] k[j,d] exp(le[i,d]-le[j,d]),  j < i
    ri = r * jnp.exp(le)                  # bounded: le <= 0
    kj = k * jnp.exp(-le)                 # grows, but pairs with ri below
    # exact pairwise form to avoid overflow: compute in two halves with
    # the max-subtracted trick per column block is unnecessary at C<=64
    # because exp(le_i - le_j) <= 1 is applied as a (C,C) product of the
    # two factors ONLY under the causal mask (j<i => le_i - le_j <= 0).
    idx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    tri = idx > jdx
    scores = jnp.dot(ri, kj.T, preferred_element_type=jnp.float32)
    scores = jnp.where(tri, scores, 0.0)
    diag = jnp.sum(r * u * k, axis=-1)    # (C,)
    o = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    o = o + diag[:, None] * v
    o = o + jnp.dot(ri, s_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = o.astype(o_ref.dtype)

    le_c = le[-1][None, :]                # (1, dh)
    k_scaled = k * jnp.exp(le_c - le)     # exp(<=0), safe
    s_ref[...] = jnp.exp(le_c).T * s_ref[...] + jnp.dot(
        k_scaled.T, v, preferred_element_type=jnp.float32)


def rwkv6_pallas(r, k, v, log_w, u, *, chunk: int = 32,
                 interpret: bool = False):
    """r/k/v/log_w: (B, T, H, dh); u: (H, dh).  Returns o (B, T, H, dh) f32.

    NOTE: the factored (ri @ kj^T) intra-chunk product is exact only under
    the mask; with chunk <= 32 and log_w clipped to [-8, 0] (as the model
    does) the masked-out overflow region stays finite in fp32.
    """
    b, t, h, dh = r.shape
    c = min(chunk, t)
    assert t % c == 0, (t, c)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, t, dh)

    rf, kf, vf, lwf = (fold(x.astype(jnp.float32))
                       for x in (r, k, v, log_w))
    uf = jnp.broadcast_to(u.astype(jnp.float32)[None], (b, h, dh)) \
        .reshape(b * h, 1, dh)

    grid = (b * h, t // c)
    o = pl.pallas_call(
        functools.partial(_rwkv_kernel, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, dh), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, c, dh), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, c, dh), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, c, dh), lambda bh, ti: (bh, ti, 0)),
            pl.BlockSpec((1, 1, dh), lambda bh, ti: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, dh), lambda bh, ti: (bh, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    return o.reshape(b, h, t, dh).transpose(0, 2, 1, 3)
