"""Tail-aware tile autotuning: pick kernel blocks from the staircase model.

The analytic staircase (``core.tail_model``) assumes ideal wave packing,
while the Pallas kernels in this package run whatever fixed tiles their
callers pass — so a width the optimizer put on a full-wave boundary of
the *model* can still land mid-wave on the *kernel's* grid.  This module
closes that gap: block sizes for ``matmul_tiled`` / ``flash_attention`` /
``moe_gmm`` are chosen by evaluating each candidate tiling through the
roofline and paper Eq. 3's grid-wave model (``GridWaveModel``), so the
realized grid lands on full-wave boundaries whenever one exists within
the VMEM budget.

Selection rule
--------------
For each candidate block tuple the cost model computes

    B         = grid cells     (matmul: ceil(M/bm) * ceil(N/bn) * ceil(K/bk)
                                — ``matmul_tiled.grid_blocks``)
    W         = ceil(B / S)    (Eq. 3 waves, S = hw.cores_per_chip)
    compute_s = dL * W         (dL = per-cell FLOPs / peak — Eq. 3's
                                L = dL * ceil(B / S))
    memory_s  = padded HBM traffic / bandwidth   (roofline)
    latency_s = max(compute_s, memory_s)
    tail_free = every dim divides its block  AND  B % S == 0

i.e. no padded tile lanes and no partial last wave.  Candidates that
exceed the VMEM budget (operand blocks double-buffered + fp32
accumulator + output block) are discarded.  Among survivors, tail-free
configs are preferred when any exist; ties break by (latency_s,
padded_flops, grid_blocks, blocks) — a pure function of (hardware,
shape, dtype), so selection is deterministic per ``HardwareSpec``.

Worked Eq. 3 example (TPU_LITE, S = cores_per_chip for the example's
sake; take S = 4): a (512, 512, 512) matmul at the fixed default blocks
(256, 256, 512) has B = 2*2*1 = 4 cells -> W = ceil(4/4) = 1 full wave,
tail-free.  The same matmul at (256, 256, 256) has B = 2*2*2 = 8 ->
W = 2, still tail-free; but at (192, 256, 512) B = ceil(512/192)*2*1 =
6 -> W = ceil(6/4) = 2 waves with the second wave only half occupied
AND 64 padded rows per m-tile — the tail the autotuner rejects: its
latency is 2*dL with dL inflated by padding, versus 1*dL for the
(256, 256, 512) choice.

Configs are memoized in-process per (hardware fingerprint, kernel,
shape, dtype) and optionally persisted through ``ProfileTableCache``
(``get_tiles``/``put_tiles``), so a serving process re-resolves tiles
from disk instead of re-enumerating candidates.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.hardware import HardwareSpec
from repro.core.tail_model import GridWaveModel, ceil_div
from repro.core.table_cache import ProfileTableCache, hardware_fingerprint

__all__ = [
    "TileConfig", "autotune_matmul", "autotune_flash_attention",
    "autotune_moe_gmm", "clear_memo", "memo_stats",
]

# Candidate block edges. Multiples of the MXU/VPU tiles (8 sublanes x 128
# lanes); the selection cost model prunes what VMEM can't hold.
_M_EDGES = (8, 16, 32, 64, 128, 256, 512, 1024)
_LANE_EDGES = (128, 256, 512, 1024)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One scored tiling of one kernel invocation shape."""

    kernel: str                 # "matmul" | "flash_attention" | "moe_gmm"
    blocks: tuple[int, ...]     # kernel block args, kernel-specific order
    grid: tuple[int, ...]       # resulting pallas grid
    grid_blocks: int            # B of Eq. 3 (product of grid)
    waves: int                  # W = ceil(B / cores_per_chip)
    tail_free: bool             # no padded lanes, no partial last wave
    latency_s: float            # max(Eq. 3 compute, roofline memory)
    padded_flops: float         # FLOPs actually executed incl. padding
    vmem_bytes: int             # per-core working set of this tiling


# In-process memo: (hw fingerprint, kernel, shape, dtype_bits) -> TileConfig.
_MEMO: dict = {}


def clear_memo() -> None:
    _MEMO.clear()


def memo_stats() -> dict:
    """Observability for the in-process memo: entry counts per kernel
    and how many memoized grids are tail-free.  The serving layer (width
    planner tail-preference, compile-cache smoke) reports these to show
    the autotuner is being consulted, not re-run."""
    per_kernel: dict[str, int] = {}
    tail_free = 0
    for (_, kernel, _, _), cfg in _MEMO.items():
        per_kernel[kernel] = per_kernel.get(kernel, 0) + 1
        tail_free += bool(cfg.tail_free)
    return {"entries": len(_MEMO), "tail_free": tail_free,
            "per_kernel": per_kernel}


def _select(cands: Sequence[TileConfig]) -> TileConfig:
    """Prefer tail-free tilings when any exist; break ties
    deterministically (latency, padded work, grid size, block tuple)."""
    pool = [c for c in cands if c.tail_free] or list(cands)
    return min(pool, key=lambda c: (c.latency_s, c.padded_flops,
                                    c.grid_blocks, c.blocks))


def _edge_candidates(dim: int, edges: Sequence[int]) -> list[int]:
    """Block candidates for one padded dim: every edge not uselessly
    larger than the dim (one block covering the dim is kept once)."""
    out = [e for e in edges if e < 2 * dim or e == edges[0]]
    return out or [edges[0]]


def _divisor_candidates(dim: int, edges: Sequence[int],
                        cap: int) -> list[int]:
    """Block candidates for a dim the kernel requires to divide evenly:
    the edges that divide ``dim``, plus ``dim`` itself when small."""
    out = [e for e in edges if dim % e == 0]
    if dim <= cap and dim not in out:
        out.append(dim)
    return out


# ---- per-kernel cost models ---------------------------------------------

def _matmul_config(hw: HardwareSpec, m: int, n: int, k: int,
                   bm: int, bn: int, bk: int,
                   dtype_bits: int) -> Optional[TileConfig]:
    bpe = dtype_bits // 8
    vmem = 2 * (bm * bk + bk * bn) * bpe + bm * bn * (4 + bpe)
    if vmem > hw.vmem_bytes:
        return None
    gm, gn, gk = ceil_div(m, bm), ceil_div(n, bn), ceil_div(k, bk)
    blocks = gm * gn * gk
    cell_flops = 2.0 * bm * bn * bk
    wave = GridWaveModel(hw, cell_flops).evaluate(blocks)
    # Padded HBM traffic: each x tile is read once per n-block, each w
    # tile once per m-block, the output written once.
    total_bytes = ((gm * bm) * (gk * bk) * gn
                   + (gk * bk) * (gn * bn) * gm
                   + (gm * bm) * (gn * bn)) * bpe
    latency = max(wave.latency_s, total_bytes / hw.hbm_bandwidth)
    tail_free = (m % bm == 0 and n % bn == 0 and k % bk == 0
                 and blocks % hw.cores_per_chip == 0)
    return TileConfig(
        kernel="matmul", blocks=(bm, bn, bk), grid=(gm, gn, gk),
        grid_blocks=blocks, waves=wave.waves, tail_free=tail_free,
        latency_s=latency, padded_flops=cell_flops * blocks,
        vmem_bytes=vmem)


def _matmul_candidates(hw: HardwareSpec, shape, dtype_bits: int):
    m, n, k = shape
    out = []
    for bm in _edge_candidates(m, _M_EDGES):
        for bn in _edge_candidates(n, _LANE_EDGES):
            for bk in _edge_candidates(k, _LANE_EDGES):
                cfg = _matmul_config(hw, m, n, k, bm, bn, bk, dtype_bits)
                if cfg is not None:
                    out.append(cfg)
    if not out:
        out.append(_force_config(
            _matmul_config, hw, (m, n, k),
            (min(256, m), min(256, n), min(512, k)), dtype_bits))
    return out


def _flash_config(hw: HardwareSpec, b: int, sq: int, skv: int, h: int,
                  kv_heads: int, dh: int, bq: int, bkv: int,
                  dtype_bits: int) -> Optional[TileConfig]:
    bpe = dtype_bits // 8
    # q block + double-buffered k/v blocks + fp32 scores, stats and
    # accumulator scratch + output block.
    vmem = (bq * dh * bpe + 2 * 2 * (bkv * dh) * bpe
            + bq * bkv * 4 + bq * dh * 4 + 2 * bq * 4 + bq * dh * bpe)
    if vmem > hw.vmem_bytes:
        return None
    gq, gkv = ceil_div(sq, bq), ceil_div(skv, bkv)
    blocks = b * h * gq * gkv
    cell_flops = 4.0 * bq * bkv * dh
    wave = GridWaveModel(hw, cell_flops).evaluate(blocks)
    # q and the output move once; k/v blocks are re-fetched per q block
    # (the kernel's kv index map changes every innermost step).
    total_bytes = (2 * b * h * sq * dh + 2 * b * h * gq * skv * dh) * bpe
    latency = max(wave.latency_s, total_bytes / hw.hbm_bandwidth)
    tail_free = (sq % bq == 0 and skv % bkv == 0
                 and blocks % hw.cores_per_chip == 0)
    return TileConfig(
        kernel="flash_attention", blocks=(bq, bkv),
        grid=(b * h, gq, gkv), grid_blocks=blocks, waves=wave.waves,
        tail_free=tail_free, latency_s=latency,
        padded_flops=cell_flops * blocks, vmem_bytes=vmem)


def _flash_candidates(hw: HardwareSpec, shape, dtype_bits: int):
    b, sq, skv, h, kv_heads, dh = shape
    out = []
    # The kernel requires divisibility, so only divisor blocks are legal
    # without padding (ops.flash_attention pads otherwise).
    for bq in _divisor_candidates(sq, (16, 32, 64, 128, 256, 512, 1024),
                                  cap=2048):
        for bkv in _divisor_candidates(skv,
                                       (128, 256, 512, 1024), cap=2048):
            cfg = _flash_config(hw, b, sq, skv, h, kv_heads, dh,
                                bq, bkv, dtype_bits)
            if cfg is not None:
                out.append(cfg)
    if not out:
        out.append(_force_config(
            _flash_config, hw, (b, sq, skv, h, kv_heads, dh),
            (min(512, sq), min(512, skv)), dtype_bits))
    return out


def _moe_config(hw: HardwareSpec, e: int, c: int, d: int, f: int,
                bc: int, bf: int, bd: int,
                dtype_bits: int) -> Optional[TileConfig]:
    bpe = dtype_bits // 8
    vmem = 2 * (bc * bd + bd * bf) * bpe + bc * bf * (4 + bpe)
    if vmem > hw.vmem_bytes:
        return None
    gc, gf, gd = ceil_div(c, bc), ceil_div(f, bf), ceil_div(d, bd)
    blocks = e * gc * gf * gd
    cell_flops = 2.0 * bc * bf * bd
    wave = GridWaveModel(hw, cell_flops).evaluate(blocks)
    total_bytes = e * ((gc * bc) * (gd * bd) * gf
                       + (gd * bd) * (gf * bf) * gc
                       + (gc * bc) * (gf * bf)) * bpe
    latency = max(wave.latency_s, total_bytes / hw.hbm_bandwidth)
    tail_free = (c % bc == 0 and f % bf == 0 and d % bd == 0
                 and blocks % hw.cores_per_chip == 0)
    return TileConfig(
        kernel="moe_gmm", blocks=(bc, bf, bd), grid=(e, gc, gf, gd),
        grid_blocks=blocks, waves=wave.waves, tail_free=tail_free,
        latency_s=latency, padded_flops=cell_flops * blocks,
        vmem_bytes=vmem)


def _moe_candidates(hw: HardwareSpec, shape, dtype_bits: int):
    e, c, d, f = shape
    out = []
    for bc in _edge_candidates(c, _M_EDGES):
        for bf in _edge_candidates(f, _LANE_EDGES):
            for bd in _edge_candidates(d, _LANE_EDGES):
                cfg = _moe_config(hw, e, c, d, f, bc, bf, bd, dtype_bits)
                if cfg is not None:
                    out.append(cfg)
    if not out:
        out.append(_force_config(
            _moe_config, hw, (e, c, d, f),
            (min(128, c), min(256, f), min(256, d)), dtype_bits))
    return out


def _force_config(config_fn, hw, shape, blocks, dtype_bits) -> TileConfig:
    """Build the clamped-defaults config ignoring the VMEM filter — the
    last resort when no candidate fits (degenerate HardwareSpecs)."""
    big = dataclasses.replace(hw, vmem_bytes=1 << 62)
    return config_fn(big, *shape, *blocks, dtype_bits)


_KERNELS = {
    "matmul": _matmul_candidates,
    "flash_attention": _flash_candidates,
    "moe_gmm": _moe_candidates,
}


def _autotune(kernel: str, hw: HardwareSpec, shape: tuple[int, ...],
              dtype_bits: int,
              cache: Optional[ProfileTableCache]) -> TileConfig:
    key = (hardware_fingerprint(hw), kernel, shape, dtype_bits)
    cfg = _MEMO.get(key)
    if cfg is not None:
        return cfg
    if cache is not None:
        blocks = cache.get_tiles(hw, kernel, shape + (dtype_bits,))
        if blocks is not None:
            # Re-score the persisted blocks (cheap) so the returned
            # TileConfig carries fresh grid/latency fields.
            cfg = _score_blocks(kernel, hw, shape, tuple(blocks),
                                dtype_bits)
            _MEMO[key] = cfg
            return cfg
    cfg = _select(_KERNELS[kernel](hw, shape, dtype_bits))
    _MEMO[key] = cfg
    if cache is not None:
        cache.put_tiles(hw, kernel, shape + (dtype_bits,), cfg.blocks)
    return cfg


def _score_blocks(kernel: str, hw: HardwareSpec, shape, blocks,
                  dtype_bits: int) -> TileConfig:
    fn = {"matmul": _matmul_config, "flash_attention": _flash_config,
          "moe_gmm": _moe_config}[kernel]
    cfg = fn(hw, *shape, *blocks, dtype_bits)
    if cfg is None:   # persisted under a larger-VMEM spec: rebuild fresh
        return _select(_KERNELS[kernel](hw, shape, dtype_bits))
    return cfg


# ---- public entry points ------------------------------------------------

def autotune_matmul(hw: HardwareSpec, m: int, n: int, k: int, *,
                    dtype_bits: int = 16,
                    cache: Optional[ProfileTableCache] = None) -> TileConfig:
    """Tiles for ``matmul_tiled.matmul_pallas`` on an (M, K) @ (K, N)."""
    return _autotune("matmul", hw, (m, n, k), dtype_bits, cache)


def autotune_flash_attention(hw: HardwareSpec, b: int, sq: int, skv: int,
                             h: int, kv_heads: int, dh: int, *,
                             dtype_bits: int = 16,
                             cache: Optional[ProfileTableCache] = None,
                             ) -> TileConfig:
    """(block_q, block_kv) for ``flash_attention_pallas``."""
    return _autotune("flash_attention", hw, (b, sq, skv, h, kv_heads, dh),
                     dtype_bits, cache)


def autotune_moe_gmm(hw: HardwareSpec, e: int, c: int, d: int, f: int, *,
                     dtype_bits: int = 16,
                     cache: Optional[ProfileTableCache] = None) -> TileConfig:
    """(block_c, block_f, block_d) for ``moe_gmm_pallas``."""
    return _autotune("moe_gmm", hw, (e, c, d, f), dtype_bits, cache)
