"""Tile-quantized matmul Pallas kernel — the paper's mechanism made visible.

The grid is ceil(M/bm) x ceil(N/bn) "thread blocks" (paper Fig. 4); each cell
runs a bk-stepped VMEM-resident accumulation on the MXU.  The cell count is
exactly the ``B`` of paper Eq. 3 — ``GridWaveModel`` predicts latency from it
and ``benchmarks/wave_verification.py`` checks the staircase against this
kernel's grid.

Block shapes are BlockSpec'd to VMEM: (bm, bk) + (bk, bn) + (bm, bn) tiles
must fit the ~128 MiB VMEM budget; defaults are MXU-aligned (multiples of
128) — a deliberately misaligned N exposes the tail as padded lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; grid = (gm, gn, gk), k innermost."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(x: jax.Array, w: jax.Array, *, block_m: int = 256,
                  block_n: int = 256, block_k: int = 512,
                  interpret: bool = False) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).  Requires dims divisible by blocks
    (callers pad — that padding IS the tail effect; see ops.py)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"matmul_pallas needs block-divisible dims: (M, N, K)="
            f"({m}, {n}, {k}) is not divisible by blocks ({bm}, {bn}, {bk})"
            f" (requested ({block_m}, {block_n}, {block_k}), clamped to the"
            f" dims). Pad M/N/K up to block multiples and slice the output"
            f" — ops.matmul does this automatically.")
    gm, gn, gk = m // bm, n // bn, k // bk

    return pl.pallas_call(
        functools.partial(matmul_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def grid_blocks(m: int, n: int, k: int, block_m: int = 256,
                block_n: int = 256, block_k: int = 512) -> int:
    """B of paper Eq. 3 for this kernel (used by the wave benchmarks)."""
    ceil = lambda a, b: -(-a // b)
    return ceil(m, block_m) * ceil(n, block_n) * ceil(k, block_k)
