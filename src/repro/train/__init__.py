from repro.train.optim import (
    AdamState, AdamWConfig, adamw_init, adamw_update, cosine_schedule,
    clip_by_global_norm, global_norm,
)
from repro.train.step import TrainConfig, build_train_step, build_eval_step
from repro.train.data import DataConfig, SyntheticLM, MemmapLM, make_source, \
    augment_for_arch
from repro.train import checkpoint

__all__ = [
    "AdamState", "AdamWConfig", "adamw_init", "adamw_update",
    "cosine_schedule", "clip_by_global_norm", "global_norm", "TrainConfig",
    "build_train_step", "build_eval_step", "DataConfig", "SyntheticLM",
    "MemmapLM", "make_source", "augment_for_arch", "checkpoint",
]
