"""Fault-tolerant checkpointing: atomic manifests, async saves, elastic
restore onto any mesh.

Layout:  <dir>/step_<N>/arr_<i>.npy + manifest.json, committed by writing
``manifest.json`` last and then atomically renaming the step directory from
``.tmp``.  A crash mid-save leaves only a ``.tmp`` dir which is ignored (and
garbage-collected on the next save) — restart always sees the last *complete*
step.  Restore device_puts each leaf under the *current* mesh's shardings,
so a checkpoint taken on 512 chips restores onto 256 or 1 (elastic scaling /
CPU debugging).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _leaf_paths(tree) -> list:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         blocking: bool = True) -> threading.Thread | None:
    """Save ``tree`` at ``step``.  Non-blocking mode copies to host
    synchronously (cheap) and writes files on a daemon thread."""
    leaves, treedef = jax.tree.flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    struct = jax.tree.map(lambda x: None, tree)

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, arr in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest = {
            "step": step,
            "n_arrays": len(host_leaves),
            "treedef": str(treedef),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)
    for name in os.listdir(ckpt_dir):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def list_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of ``like``; device_put under
    ``shardings`` (a congruent tree of NamedShardings) if given —
    this is the elastic-rescale path."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["n_arrays"] == len(leaves), \
        f"checkpoint has {manifest['n_arrays']} arrays, model needs " \
        f"{len(leaves)}"
    host = [np.load(os.path.join(d, f"arr_{i}.npy"))
            for i in range(len(leaves))]
    for h, l in zip(host, leaves):
        assert h.shape == tuple(l.shape), (h.shape, l.shape)
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        out = [jax.device_put(h.astype(l.dtype), s)
               for h, l, s in zip(host, leaves, sh_leaves)]
    else:
        out = [jax.numpy.asarray(h.astype(l.dtype)) for h, l in
               zip(host, leaves)]
    return treedef.unflatten(out)
