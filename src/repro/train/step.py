"""Train-step builder: loss + grad + AdamW, with microbatched gradient
accumulation (lax.scan) so arbitrarily large global batches fit HBM."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.train.optim import AdamState, AdamWConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    remat: str = "full"       # none | full | dots | sqrt
    moe_strategy: str = "auto"
    aux_weight: float = 0.01
    z_weight: float = 1e-3
    accum_dtype: str = "f32"  # grad-accumulation dtype (bf16 with kahan)


def _split_micro(batch: dict, n: int) -> dict:
    from repro.parallel.sharding import shard

    def sp(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        y = x.reshape(n, b // n, *x.shape[1:])
        # Re-anchor the batch sharding after the reshape: without this the
        # SPMD partitioner falls back to "involuntary full rematerialization"
        # (replicate-then-reshard) when slicing microbatches.
        return shard(y, None, "batch", *([None] * (x.ndim - 1)))
    return jax.tree.map(sp, batch)


def loss_fn(params, batch, cfg: ModelConfig, tc: TrainConfig):
    return tfm.train_loss(params, batch, cfg,
                          moe_strategy=tc.moe_strategy, remat=tc.remat,
                          aux_weight=tc.aux_weight, z_weight=tc.z_weight)


def grads_fn(params, batch, cfg: ModelConfig, tc: TrainConfig):
    """Value-and-grad with microbatch accumulation."""
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    if tc.microbatches <= 1:
        (loss, metrics), grads = vg(params, batch, cfg, tc)
        return loss, metrics, grads

    micro = _split_micro(batch, tc.microbatches)

    acc_dt = jnp.bfloat16 if tc.accum_dtype == "bf16" else jnp.float32

    def body(carry, mb):
        g_acc, l_acc = carry
        (loss, metrics), g = vg(params, mb, cfg, tc)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt), g_acc, g)
        return (g_acc, l_acc + loss), metrics

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    (g_sum, l_sum), ms = jax.lax.scan(body, (g0, jnp.zeros(())), micro)
    inv = 1.0 / tc.microbatches
    grads = jax.tree.map(lambda g: g * inv, g_sum)
    metrics = jax.tree.map(lambda m: jnp.mean(m), ms)
    return l_sum * inv, metrics, grads


def build_train_step(cfg: ModelConfig, tc: TrainConfig,
                     lr_schedule: Callable) -> Callable:
    """Returns step(params, opt_state, batch, step_idx) ->
    (params, opt_state, metrics)."""

    def train_step(params, opt_state: AdamState, batch: dict,
                   step_idx: jax.Array):
        loss, metrics, grads = grads_fn(params, batch, cfg, tc)
        lr = lr_schedule(step_idx)
        params, opt_state, om = adamw_update(grads, opt_state, params, lr,
                                             tc.adamw)
        metrics = dict(metrics, **om, lr=lr, total_loss=loss)
        return params, opt_state, metrics

    return train_step


def build_eval_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch, cfg, tc)
        return dict(metrics, total_loss=loss)
    return eval_step
