"""AdamW, gradient clipping and LR schedules (no external deps).

Optimizer state mirrors the parameter tree, so it inherits the params'
PartitionSpecs (ZeRO: moments are sharded exactly like the weights).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    """int8 tensor + per-row fp32 scale (8-bit Adam moments).

    398B-param MoE optimizer state at fp32 moments is 2x8 bytes/param —
    19 GB/chip on a 256-chip pod even fully sharded.  int8 moments cut that
    to ~2 bytes/param and fit.
    """
    q: jax.Array       # int8, same shape as the param
    scale: jax.Array   # f32, shape[:-1] + (1,)


def quantize_q8(x: jax.Array) -> Quantized:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return Quantized(q=q, scale=scale.astype(jnp.float32))


def dequantize_q8(z: Quantized) -> jax.Array:
    return z.q.astype(jnp.float32) * z.scale


def quantize_q8_sqrt(x: jax.Array) -> Quantized:
    """sqrt-domain int8 for the (non-negative) second moment: a linear grid
    on v zeroes small entries and 1/sqrt(v~0) explodes the step; the sqrt
    domain halves the dynamic range (8-bit-Adam-style dynamic quant)."""
    return quantize_q8(jnp.sqrt(jnp.maximum(x, 0.0)))


def dequantize_q8_sqrt(z: Quantized) -> jax.Array:
    r = dequantize_q8(z)
    return jnp.square(r)


class AdamState(NamedTuple):
    count: jax.Array
    mu: dict
    nu: dict
    comp: object = None    # bf16 Kahan compensation (bf16_kahan master)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize_moments: bool = False   # 8-bit Adam (int8 mu/nu + row scales)
    # 'f32' keeps fp32 master weights; 'bf16_kahan' stores bf16 master +
    # bf16 Kahan compensation (DeepSpeed BF16Optimizer-style) — needed when
    # params/chip exceed what fp32 master + fp32 grads can fit (llama4
    # maverick: 1.55B params/chip on a 256-chip pod).
    master_dtype: str = "f32"


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamState:
    if cfg.quantize_moments:
        zeros = lambda t: jax.tree.map(
            lambda p: quantize_q8(jnp.zeros(p.shape, jnp.float32)), t)
    else:
        zeros = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
    comp = None
    if cfg.master_dtype == "bf16_kahan":
        comp = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                            params)
    return AdamState(count=jnp.zeros((), jnp.int32), mu=zeros(params),
                     nu=zeros(params), comp=comp)


def global_norm(tree) -> jax.Array:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


_CHUNK_BYTES = 512 * 1024 * 1024


def adamw_update(grads, state: AdamState, params, lr: jax.Array,
                 cfg: AdamWConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf
    kahan = cfg.master_dtype == "bf16_kahan"

    def upd(g, m, v, p, c):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(gf)
        step = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        delta = -lr * step
        pf = p.astype(jnp.float32)
        if c is not None:
            # Kahan-compensated bf16 master update: the compensation buffer
            # carries the bits lost by the bf16 store.
            y = delta - c.astype(jnp.float32)
            p_new = (pf + y).astype(p.dtype)
            c_new = ((p_new.astype(jnp.float32) - pf) - y
                     ).astype(jnp.bfloat16)
            return p_new, m_new, v_new, c_new
        return (pf + delta).astype(p.dtype), m_new, v_new, None

    is_q = lambda x: isinstance(x, Quantized)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state.mu, is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state.nu, is_leaf=is_q)[0]
    flat_c = (tdef.flatten_up_to(state.comp) if kahan
              else [None] * len(flat_p))

    def one_leaf(g, m, v, p, c):
        quantized = is_q(m)
        if quantized:
            m, v = dequantize_q8(m), dequantize_q8_sqrt(v)
        pn, mn, vn, cn = upd(g, m, v, p, c)
        if quantized:
            mn, vn = quantize_q8(mn), quantize_q8_sqrt(vn)
        return pn, mn, vn, cn

    new_p, new_m, new_v, new_c = [], [], [], []
    for g, m, v, p, c in zip(flat_g, flat_m, flat_v, flat_p, flat_c):
        pn, mn, vn, cn = one_leaf(g, m, v, p, c)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
        new_c.append(cn)
    mdef = jax.tree.structure(state.mu, is_leaf=is_q)
    comp_new = tdef.unflatten(new_c) if kahan else None
    return (tdef.unflatten(new_p),
            AdamState(count=count, mu=mdef.unflatten(new_m),
                      nu=mdef.unflatten(new_v), comp=comp_new),
            {"grad_norm": gnorm})


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(
            jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return lr
