"""Deterministic, resumable, shardable synthetic/memmap data pipeline.

Every batch is a pure function of (seed, step) — a restarted worker regains
the exact stream position from the checkpointed step (fault tolerance), and
per-host sharding is just a slice of the global batch (the launch layer
device_puts each host's slice under the batch sharding).

Two sources:
  * ``SyntheticLM`` — Zipf-ish token stream with enough structure (bigram
    template) that a model measurably learns; used by examples and tests.
  * ``MemmapLM``    — packed uint16/uint32 token file, deterministic strided
    windows (production path; any tokenized corpus dropped on disk works).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None       # memmap token file
    dtype: str = "uint16"


class SyntheticLM:
    """Structured synthetic LM stream: x_{t+1} = (a*x_t + b) % V with noise.

    Learnable (a next-token rule exists) but non-trivial; loss decreasing on
    this stream is a real end-to-end training signal.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        v = c.vocab_size
        b, s = c.global_batch, c.seq_len
        a, off = 31, 17
        x0 = rng.integers(0, v, size=(b, 1))
        toks = [x0]
        for _ in range(s):
            nxt = (toks[-1] * a + off) % v
            noise = rng.integers(0, v, size=(b, 1))
            flip = rng.random((b, 1)) < 0.1
            toks.append(np.where(flip, noise, nxt))
        seq = np.concatenate(toks, axis=1).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}


class MemmapLM:
    def __init__(self, cfg: DataConfig):
        assert cfg.path and os.path.exists(cfg.path), cfg.path
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.dtype(cfg.dtype), mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        idx = rng.integers(0, self.n_windows, size=(c.global_batch,))
        starts = idx * c.seq_len
        rows = np.stack([self.data[s:s + c.seq_len + 1] for s in starts])
        rows = rows.astype(np.int32) % c.vocab_size
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_source(cfg: DataConfig):
    return MemmapLM(cfg) if cfg.path else SyntheticLM(cfg)


def augment_for_arch(batch: dict, mcfg: ModelConfig, seq_len: int,
                     step: int = 0) -> dict:
    """Add modality-stub inputs required by the arch (audio frames,
    M-RoPE positions)."""
    b = batch["tokens"].shape[0]
    if mcfg.is_encdec:
        rng = np.random.default_rng((7, step))
        batch = dict(batch, src_embeds=rng.standard_normal(
            (b, seq_len, mcfg.d_model)).astype(np.float32) * 0.02)
    if mcfg.rope_kind == "mrope":
        pos = np.broadcast_to(
            np.arange(seq_len, dtype=np.int32)[None, :, None],
            (b, seq_len, 3)).copy()
        batch = dict(batch, positions=pos)
    return batch
