"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns everything ``dryrun.py`` needs to lower a cell
without allocating a single real array: the step kind, the abstract args
(with NamedShardings attached), and metadata for the roofline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.models.layers import COMPUTE_DTYPE
from repro.parallel import sharding as shlib
from repro.train.optim import AdamState


def _ax(mesh: Mesh, axes):
    return shlib._filter_axes(axes, mesh)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def cell_rules(mesh: Mesh, cfg: ModelConfig, global_batch: int) -> dict:
    """Logical-rule overrides for one cell.

    * batch replicated when it can't shard evenly (long_500k, batch=1);
    * heads / kv_heads replicated over `model` when the head count is not
      divisible by the axis size — the honest baseline for e.g. yi-34b's 56
      heads on TP=16.  (The paper's scale-up move — padding the head count
      to the quantum — is evaluated separately in the perf pass.)
    """
    rules: dict = {}
    axes = _ax(mesh, ("pod", "data"))
    dp = 1
    if axes:
        if isinstance(axes, str):
            axes = (axes,)
        for a in axes:
            dp *= mesh.shape[a]
    if global_batch % max(dp, 1) != 0:
        rules["batch"] = None
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if cfg.n_heads % tp != 0:
        rules["heads"] = None
    if cfg.n_kv_heads % tp != 0:
        rules["kv_heads"] = None
    if cfg.moe and cfg.n_experts % tp != 0:
        rules["expert"] = None
    from repro.models.transformer import padded_vocab
    if padded_vocab(cfg) % tp != 0:
        rules["vocab"] = None
    if cfg.seq_parallel_acts:
        rules["act_seq"] = "model"
    if cfg.d_ff % tp != 0:
        rules["mlp"] = None
    # ZeRO across pods for >=200B params: one 256-chip pod cannot hold the
    # optimizer state of llama4-maverick even at int8 moments + bf16
    # master; the multi-pod mesh extends the FSDP axis over the DCI.
    from repro.models.transformer import count_params_analytic
    if ("pod" in mesh.axis_names
            and count_params_analytic(cfg) > 200e9):
        rules["fsdp"] = ("data", "pod")
    return rules


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# param / state spec trees
# ---------------------------------------------------------------------------
def abstract_params(cfg: ModelConfig, mesh: Mesh, dtype=None):
    """Abstract param tree with shardings (no allocation)."""
    shapes = jax.eval_shape(lambda k: tfm.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shlib.param_pspecs(shapes, mesh=mesh)
    def mk(s, sp):
        dt = dtype or s.dtype
        return _sds(s.shape, dt, mesh, sp)
    return jax.tree.map(mk, shapes, specs,
                        is_leaf=lambda x: isinstance(x, P)), specs


def abstract_opt_state(abs_params, mesh: Mesh, quantized: bool = False,
                       kahan: bool = False):
    from repro.train.optim import Quantized

    def moment(p):
        if not quantized:
            return jax.ShapeDtypeStruct(p.shape, jnp.float32,
                                        sharding=p.sharding)
        spec = p.sharding.spec
        spec = tuple(spec) + (None,) * (len(p.shape) - len(spec))
        scale_spec = P(*spec[:-1], None) if len(p.shape) else P()
        scale_shape = p.shape[:-1] + (1,) if len(p.shape) else (1,)
        return Quantized(
            q=_sds(p.shape, jnp.int8, mesh, P(*spec)),
            scale=_sds(scale_shape, jnp.float32, mesh, scale_spec),
        )

    comp = None
    if kahan:
        comp = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16,
                                           sharding=p.sharding), abs_params)
    return AdamState(
        count=_sds((), jnp.int32, mesh, P()),
        mu=jax.tree.map(moment, abs_params),
        nu=jax.tree.map(moment, abs_params),
        comp=comp,
    )


def decode_state_pspecs(cfg: ModelConfig, mesh: Mesh, batch_ax) -> dict:
    """Spec tree congruent with tfm.init_decode_state output."""
    plan = tfm.layer_plan(cfg, encoder=False)
    cycle = tfm.unit_cycle(cfg)
    n_units = len(plan) // cycle
    cross = cfg.is_encdec
    model_ax = _ax(mesh, "model")

    def layer_specs(kind: str) -> dict:
        st = {}
        if kind == "attn":
            st["k"] = P(batch_ax, model_ax, None, None)
            st["v"] = P(batch_ax, model_ax, None, None)
            if cross:
                st["ck"] = P(batch_ax, model_ax, None, None)
                st["cv"] = P(batch_ax, model_ax, None, None)
                st["clen"] = P()
        elif kind == "local":
            st["k"] = P(batch_ax, None, None, None)
            st["v"] = P(batch_ax, None, None, None)
            if cross:
                st["ck"] = P(batch_ax, model_ax, None, None)
                st["cv"] = P(batch_ax, model_ax, None, None)
                st["clen"] = P()
        elif kind == "rglru":
            st["h"] = P(batch_ax, model_ax)
            st["conv"] = P(batch_ax, None, model_ax)
        elif kind == "rwkv":
            st["shift"] = P(batch_ax, None, None)
            st["s"] = P(batch_ax, model_ax, None, None)
            st["cmix_shift"] = P(batch_ax, None, None)
        return st

    out: dict = {}
    if n_units:
        unit = {f"u{j}": layer_specs(plan[j][0]) for j in range(cycle)}
        # stacked leading layer dim
        out["stack"] = jax.tree.map(
            lambda p: P(None, *p), unit, is_leaf=lambda x: isinstance(x, P))
    leftover = len(plan) % cycle
    if leftover:
        out["extra"] = {f"x{j}": layer_specs(plan[n_units * cycle + j][0])
                        for j in range(leftover)}
    return out


def abstract_decode_state(cfg: ModelConfig, mesh: Mesh, batch: int,
                          max_len: int, enc_len: int, batch_ax):
    shapes = jax.eval_shape(
        lambda: tfm.init_decode_state(cfg, batch, max_len, enc_len))
    specs = decode_state_pspecs(cfg, mesh, batch_ax)
    return jax.tree.map(lambda s, sp: _sds(s.shape, s.dtype, mesh, sp),
                        shapes, specs,
                        is_leaf=lambda x: isinstance(x, P)), specs


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------
def apply_variant(cfg: ModelConfig, variant: str, mesh: Mesh
                  ) -> ModelConfig:
    """Optimizer-produced config variants for the perf pass.

    'padded_heads': paper Eq. 8b scale-up — pad n_heads / n_kv_heads to the
    TP quantum so attention shards instead of replicating (yi-34b: 56 -> 64
    heads; the +params are the PG the paper trades for latency).
    """
    if variant in ("", "none"):
        return cfg
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if variant == "padded_heads":
        nh = -(-cfg.n_heads // tp) * tp
        nkv = -(-cfg.n_kv_heads // tp) * tp
        return dataclasses.replace(
            cfg, name=cfg.name + "+padheads", n_heads=nh, n_kv_heads=nkv)
    if variant == "seq_parallel":
        return dataclasses.replace(
            cfg, name=cfg.name + "+seqpar", seq_parallel_acts=True)
    raise ValueError(variant)


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str                  # train | prefill | decode
    fn: Callable               # function to lower
    args: tuple                # abstract args
    donate: tuple = ()
    rules: dict = dataclasses.field(default_factory=dict)
    model_flops: float = 0.0   # 6*N*D (train) / 2*N_active*D (inference)
    note: str = ""


def microbatches_for(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                     budget_bytes: float = 5e9) -> int:
    """Grad-accum factor so per-microbatch activations fit the HBM budget.

    Accounts for the three dominant per-token live terms: the residual
    stream saved per layer under remat, the (vocab-sharded) logits in the
    loss (bf16 + fp32 temps), and MoE dispatch/combine buffers.
    """
    from repro.models.transformer import padded_vocab
    dp = dp_size(mesh)
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    seqs_per_dev = max(shape.global_batch // max(dp, 1), 1)
    resid = cfg.d_model * 2 * cfg.n_layers * (2 if cfg.is_encdec else 1)
    vshard = padded_vocab(cfg)
    if vshard % tp == 0:
        vshard //= tp
    logits = vshard * 6                       # bf16 logits + fp32 temps
    moe = (cfg.experts_per_token * cfg.d_model * 12) if cfg.moe else 0
    per_seq = shape.seq_len * (resid + logits + moe)
    total = per_seq * seqs_per_dev
    mb = 1
    while total / mb > budget_bytes and mb < seqs_per_dev:
        mb *= 2
    return min(mb, seqs_per_dev)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                tc=None) -> CellSpec:
    """Build the abstract call for one (arch x shape x mesh) cell."""
    from repro.train.step import TrainConfig, build_train_step
    from repro.train.optim import cosine_schedule

    rules = cell_rules(mesh, cfg, shape.global_batch)
    with shlib.activity(mesh, rules):
        return _input_specs_inner(cfg, shape, mesh, tc, rules)


def _input_specs_inner(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       tc, rules: dict) -> CellSpec:
    from repro.train.step import TrainConfig, build_train_step
    from repro.train.optim import cosine_schedule

    batch_ax = shlib.batch_axes(mesh)
    b, s = shape.global_batch, shape.seq_len
    n_params = tfm.count_params_analytic(cfg)
    n_active = tfm.count_params_analytic(cfg, active_only=True)

    if shape.kind == "train":
        if tc is None:
            from repro.train.optim import AdamWConfig
            # 8-bit Adam moments above ~30B params (yi, command-r, llama4);
            # bf16+Kahan master weights above ~200B (llama4): fp32 master +
            # fp32 grads alone exceed 16 GiB/chip at 1.55B params/chip.
            q8 = n_params > 30e9
            kahan = n_params > 200e9
            tc = TrainConfig(
                adamw=AdamWConfig(
                    quantize_moments=q8,
                    master_dtype="bf16_kahan" if kahan else "f32"),
                microbatches=microbatches_for(cfg, mesh, shape),
                remat="sqrt", moe_strategy="auto",
                accum_dtype="bf16" if kahan else "f32")
        kahan = tc.adamw.master_dtype == "bf16_kahan"
        abs_params, _ = abstract_params(
            cfg, mesh, dtype=COMPUTE_DTYPE if kahan else None)
        abs_opt = abstract_opt_state(abs_params, mesh,
                                     quantized=tc.adamw.quantize_moments,
                                     kahan=kahan)
        batch = {
            "tokens": _sds((b, s), jnp.int32, mesh, P(batch_ax, None)),
            "labels": _sds((b, s), jnp.int32, mesh, P(batch_ax, None)),
        }
        if cfg.is_encdec:
            batch["src_embeds"] = _sds((b, s, cfg.d_model), COMPUTE_DTYPE,
                                       mesh, P(batch_ax, None, None))
        if cfg.rope_kind == "mrope":
            batch["positions"] = _sds((b, s, 3), jnp.int32, mesh,
                                      P(batch_ax, None, None))
        step_idx = _sds((), jnp.int32, mesh, P())
        lr = cosine_schedule(3e-4, 100, 10000)
        fn = build_train_step(cfg, tc, lr)
        return CellSpec(
            arch=cfg.name, shape=shape.name, kind="train", fn=fn,
            args=(abs_params, abs_opt, batch, step_idx),
            donate=(0, 1), rules=rules,
            model_flops=6.0 * n_active * b * s,
            note=f"microbatches={tc.microbatches} remat={tc.remat} "
                 f"adam8bit={tc.adamw.quantize_moments}")

    if shape.kind == "prefill":
        abs_params, _ = abstract_params(cfg, mesh, dtype=COMPUTE_DTYPE)
        kw = {}
        if cfg.is_encdec:
            kw["src_embeds"] = _sds((b, s, cfg.d_model), COMPUTE_DTYPE,
                                    mesh, P(batch_ax, None, None))
        if cfg.rope_kind == "mrope":
            kw["positions"] = _sds((b, s, 3), jnp.int32, mesh,
                                   P(batch_ax, None, None))
        tokens = _sds((b, s), jnp.int32, mesh, P(batch_ax, None))
        kw_keys = sorted(kw)

        def prefill_fn(params, tokens, *extras):
            kwargs = dict(zip(kw_keys, extras))
            logits, states, _ = tfm.forward(
                params, cfg, tokens=tokens, mode="prefill",
                moe_strategy="auto", **kwargs)
            return logits[:, -1], states

        return CellSpec(
            arch=cfg.name, shape=shape.name, kind="prefill", fn=prefill_fn,
            args=(abs_params, tokens) + tuple(kw[k] for k in kw_keys),
            rules=rules,
            model_flops=2.0 * n_active * b * s,
            note="returns (last_logits, kv_caches)")

    # decode
    abs_params, _ = abstract_params(cfg, mesh, dtype=COMPUTE_DTYPE)
    enc_len = s if cfg.is_encdec else 0
    abs_state, _ = abstract_decode_state(cfg, mesh, b, s, enc_len, batch_ax)
    tokens = _sds((b,), jnp.int32, mesh, P(batch_ax))
    pos = _sds((), jnp.int32, mesh, P())
    kw_pos = None
    if cfg.rope_kind == "mrope":
        kw_pos = _sds((b, 1, 3), jnp.int32, mesh, P(batch_ax, None, None))

    def serve_fn(params, tokens, pos, states, positions=None):
        return tfm.decode_step(params, cfg, tokens, pos, states,
                               positions=positions, moe_strategy="auto")

    args = (abs_params, tokens, pos, abs_state)
    if kw_pos is not None:
        args = args + (kw_pos,)
    return CellSpec(
        arch=cfg.name, shape=shape.name, kind="decode", fn=serve_fn,
        args=args, donate=(3,), rules=rules,
        model_flops=2.0 * n_active * b,
        note=f"one new token against a {s}-token cache")
