"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets XLA_FLAGS before any jax
import and then calls these.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist, as a 1x1(xN) data mesh — CPU smoke path."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def describe(mesh) -> str:
    return " x ".join(f"{a}={s}" for a, s in
                      zip(mesh.axis_names, mesh.devices.shape)) \
        + f" ({mesh.devices.size} chips)"
