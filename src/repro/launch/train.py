"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance in practice:
  * checkpoint every --ckpt-every steps (atomic manifest, async write);
  * on start, resumes from the latest complete checkpoint automatically;
  * the data pipeline is a pure function of step, so a restarted run
    consumes exactly the batches it would have seen (kill -9 mid-run and
    relaunch — the loss curve continues; tests/test_train.py does this).
"""

from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, reduced_config
from repro.launch.mesh import describe, make_host_mesh
from repro.models import init_params
from repro.parallel import sharding as shlib
from repro.train import (
    AdamWConfig, DataConfig, TrainConfig, adamw_init, build_train_step,
    checkpoint, cosine_schedule, make_source, augment_for_arch,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving tiny config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "full", "dots", "sqrt"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-async", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-path", default="",
                    help="memmapped token file (synthetic stream if unset)")
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--n-layers", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg, n_layers=args.n_layers,
                             d_model=args.d_model)
    mesh = make_host_mesh()
    print(f"mesh: {describe(mesh)}  arch: {cfg.name}")

    tc = TrainConfig(adamw=AdamWConfig(), microbatches=args.microbatches,
                     remat=args.remat, moe_strategy="dense")
    lr = cosine_schedule(args.lr, max(args.steps // 20, 1), args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed,
                          path=args.data_path or None)
    source = make_source(data_cfg)

    with shlib.activity(mesh, {}):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        opt_state = adamw_init(params, tc.adamw)
        step_fn = jax.jit(build_train_step(cfg, tc, lr),
                          donate_argnums=(0, 1))

        start = 0
        if args.ckpt_dir:
            latest = checkpoint.latest_step(args.ckpt_dir)
            if latest is not None:
                params, opt_state = checkpoint.restore(
                    args.ckpt_dir, latest, (params, opt_state))
                start = latest
                print(f"resumed from step {latest}")

        # Preemption handling: on SIGTERM (maintenance events send this
        # before killing the VM) finish the current step, checkpoint, and
        # exit cleanly — the relaunch resumes with zero lost steps.
        preempted = {"flag": False}

        def _on_sigterm(signum, frame):
            preempted["flag"] = True

        prev_handler = signal.signal(signal.SIGTERM, _on_sigterm)

        losses = []
        t0 = time.time()
        pending = None
        for step in range(start, args.steps):
            batch = source.batch(step)
            batch = augment_for_arch(batch, cfg, args.seq, step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {loss:7.4f} "
                      f"grad_norm {float(metrics['grad_norm']):8.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:5.1f}s)",
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                if pending is not None:
                    pending.join()
                pending = checkpoint.save(
                    args.ckpt_dir, step + 1, (params, opt_state),
                    blocking=not args.ckpt_async)
            if preempted["flag"]:
                if args.ckpt_dir:
                    if pending is not None:
                        pending.join()
                    checkpoint.save(args.ckpt_dir, step + 1,
                                    (params, opt_state))
                print(f"preempted at step {step + 1}: checkpointed, "
                      f"exiting cleanly", flush=True)
                signal.signal(signal.SIGTERM, prev_handler)
                return losses
        if pending is not None:
            pending.join()
        signal.signal(signal.SIGTERM, prev_handler)
        if args.ckpt_dir:
            checkpoint.save(args.ckpt_dir, args.steps, (params, opt_state))
        print(f"final loss {losses[-1]:.4f} "
              f"(first {losses[0]:.4f}, "
              f"best {min(losses):.4f})")
        return losses


if __name__ == "__main__":
    main()
