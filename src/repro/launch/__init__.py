from repro.launch.mesh import (
    describe, make_host_mesh, make_mesh, make_production_mesh,
)

__all__ = ["describe", "make_host_mesh", "make_mesh",
           "make_production_mesh"]
