import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
# production mesh (16x16 single pod / 2x16x16 multi-pod) and extract the
# memory / cost / collective analysis that feeds EXPERIMENTS.md.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
#       --shape train_4k --mesh single --out results/
#
# The two os.environ lines above MUST stay the first statements — jax locks
# the device count on first init.

import argparse     # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro.configs import SHAPES, all_cells, get_config, list_archs  # noqa: E402
from repro.core import TPU_V5E, build_report, cost_summary, \
    parse_collectives  # noqa: E402
from repro.launch.mesh import describe, make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.parallel import sharding as shlib  # noqa: E402


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             save_hlo: bool = False, variant: str = "none") -> dict:
    from repro.launch.specs import apply_variant
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = apply_variant(cfg, variant, mesh)
    t0 = time.time()
    with shlib.activity(mesh, {}):
        cell = input_specs(cfg, shape, mesh)
        with shlib.activity(mesh, cell.rules):
            jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_summary(compiled)
    hlo_text = compiled.as_text()

    # XLA's cost_analysis counts while-loop bodies once; correct FLOPs and
    # collective bytes with loop-trip multipliers (hlo_loop_analysis), and
    # scale bytes-accessed by the same correction ratio.
    from repro.core.hlo_loop_analysis import analyze as loop_analyze
    lcost = loop_analyze(hlo_text)
    corr = lcost.flops / max(lcost.flops_uncorrected, 1.0)
    cost_raw = dict(cost)
    cost = {
        "flops": lcost.flops,
        "bytes_accessed": lcost.bytes_accessed,
    }
    coll = lcost.collectives

    per_dev_bytes = None
    if mem is not None:
        try:
            per_dev_bytes = (mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - getattr(mem, "alias_size_in_bytes", 0))
        except Exception:
            per_dev_bytes = None

    # XLA CPU lowers bf16 dots by converting operands to f32 and hoists
    # whole-stack conversions out of the layer loop; the TPU MXU consumes
    # bf16 natively, so those f32 copies of big bf16 inputs do not exist on
    # the target.  Estimate that artifact so the HBM verdict reflects TPU.
    artifact = 0
    shape_counts: dict = {}
    for leaf in jax.tree.leaves(cell.args):
        if (getattr(leaf, "dtype", None) is not None
                and str(leaf.dtype) == "bfloat16"
                and leaf.size * 2 > 200e6):
            sh = leaf.sharding.shard_shape(leaf.shape)
            dims = ",".join(str(d) for d in sh)
            shape_counts[dims] = shape_counts.get(dims, 0) + 1
    import re as _re
    for dims, n in shape_counts.items():
        if _re.search(rf"f32\[{_re.escape(dims)}\]", hlo_text):
            elems = 1
            for d in dims.split(","):
                elems *= int(d)
            artifact += n * elems * 4

    report = build_report(
        arch=cfg.name, shape=shape_name, mesh=mesh_kind,
        chips=mesh.devices.size, cost=cost, collectives=coll,
        model_flops_total=cell.model_flops, hw=TPU_V5E,
        memory_per_device_bytes=per_dev_bytes)

    adjusted = (per_dev_bytes - artifact) if per_dev_bytes else None
    rec = report.to_dict()
    rec.update({
        "kind": cell.kind, "note": cell.note,
        "loop_correction": corr,
        "flops_uncorrected": cost_raw["flops"],
        "bytes_uncorrected": cost_raw["bytes_accessed"],
        "mesh_desc": describe(mesh),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": str(mem),
        "cpu_bf16_dot_artifact_bytes": artifact,
        "memory_per_device_adjusted": adjusted,
        "hbm_ok": (adjusted is None or adjusted <= TPU_V5E.hbm_bytes),
    })
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "" if variant in ("", "none") else f"__{variant}"
        fn = os.path.join(out_dir,
                          f"{arch}__{shape_name}__{mesh_kind}{tag}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=2)
        if save_hlo:
            with open(fn.replace(".json", ".hlo.txt"), "w") as f:
                f.write(hlo_text)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape, valid in all_cells(cfg):
            if args.shape != "all" and shape.name != args.shape:
                continue
            for mesh_kind in meshes:
                tag = f"{arch} x {shape.name} x {mesh_kind}"
                if not valid:
                    print(f"[skip] {tag}: long_500k needs sub-quadratic "
                          f"attention (see DESIGN.md)", flush=True)
                    continue
                out_f = os.path.join(
                    args.out, f"{arch}__{shape.name}__{mesh_kind}.json")
                if args.skip_existing and os.path.exists(out_f):
                    print(f"[cached] {tag}", flush=True)
                    continue
                try:
                    rec = run_cell(arch, shape.name, mesh_kind, args.out,
                                   args.save_hlo)
                    print(f"[ok] {tag}: compute={rec['compute_s']:.3e}s "
                          f"memory={rec['memory_s']:.3e}s "
                          f"coll={rec['collective_s']:.3e}s "
                          f"dom={rec['dominant']} "
                          f"hbm_ok={rec['hbm_ok']} "
                          f"(compile {rec['compile_s']:.0f}s)", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", flush=True)
                    traceback.print_exc()

    print(f"\n{len(failures)} failures")
    for tag, err in failures:
        print(f"  {tag}: {err[:200]}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
