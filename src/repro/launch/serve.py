"""Serving driver: batched generation with a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 8 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs, reduced_config
from repro.models import init_params
from repro.serving.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if cfg.is_encdec or cfg.rope_kind == "mrope":
        raise SystemExit(f"{cfg.name}: serve CLI demo covers decoder-only "
                         f"text archs; see tests for enc-dec decode")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(params, cfg,
                         max_len=args.prompt_len + args.new_tokens,
                         batch_slots=args.batch_slots)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=(args.prompt_len,)).astype(
                        np.int32),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"{len(reqs)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    for i, r in enumerate(results[:4]):
        print(f"  req{i}: {r.tokens[:12].tolist()}...")
    return results


if __name__ == "__main__":
    main()
