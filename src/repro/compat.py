"""Version shims for JAX APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to
top-level ``jax.shard_map``, renaming ``check_rep`` to ``check_vma``
along the way.  Every caller in this repo wants the replication check
off (outputs deliberately mix replicated and sharded specs), so the
shim bakes that in and callers pass only ``mesh``/``in_specs``/
``out_specs``.
"""

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
