"""Paper Tables 4/5: the same optimizer across platforms.

The paper shows Titan-V / P6000 / Jetson Nano need *different* optimal
configs (hardware diversity, section 4.1-ii).  Here: v5e / v4 / v5p / lite
have different sublane quanta and peak ratios, so both the candidate sets
and the chosen widths differ per platform — no one-fit-all config.
"""

from __future__ import annotations

import time

from repro.core import (
    LayerShape, TailEffectOptimizer, TunableLayer, WaveQuantizationModel,
    analytic_candidates, get_hardware,
)

PLATFORMS = ("tpu_v5e", "tpu_v4", "tpu_v5p", "tpu_lite")
WIDTHS = (11008, 13824, 9000, 5500)     # deliberately misaligned layers


def run(csv_rows: list, verbose: bool = True):
    t0 = time.time()
    out = {}
    for name in PLATFORMS:
        hw = get_hardware(name)
        # shard only where the platform has TP peers; lite is one chip
        shard = 1 if name == "tpu_lite" else 16
        model = WaveQuantizationModel(hw)
        opt = TailEffectOptimizer(model)
        tls = []
        for i, w in enumerate(WIDTHS):
            layer = LayerShape(f"L{i}", tokens=4096, d_in=4096, width=w,
                               shard_out=shard)
            tls.append(TunableLayer(
                layer=layer,
                candidates=analytic_candidates(hw, layer,
                                               max_width=int(w * 1.5)),
                params_per_unit=4096))
        total_p = sum(tl.params(tl.layer.width) for tl in tls)
        res = opt.optimize_latency(tls, tau=0.1 * total_p, delta=0.95)
        out[name] = res
        if verbose:
            print(f"  {name:>9}: q={model.width_quantum(shard):>5} "
                  f"latency {res.latency_old_s*1e6:8.2f} -> "
                  f"{res.latency_new_s*1e6:8.2f}us "
                  f"({res.latency_reduction*100:+5.1f}%) widths="
                  f"{[res.new_widths[f'L{i}'] for i in range(len(WIDTHS))]}")
    # platforms must disagree on at least one chosen width (no one-fit-all)
    configs = {n: tuple(sorted(r.new_widths.items()))
               for n, r in out.items()}
    distinct = len(set(configs.values()))
    dt_us = (time.time() - t0) * 1e6 / len(PLATFORMS)
    reds = ";".join(f"{n}:-{out[n].latency_reduction*100:.1f}%"
                    for n in PLATFORMS)
    csv_rows.append(("platform_generality_tables4_5", f"{dt_us:.1f}",
                     f"distinct_configs={distinct};{reds}"))
    return out
