"""Optimizer scaling: table-driven Algorithm 2 vs the seed scalar path.

Production-size configs (hundreds of tunable layers x thousands of candidate
widths x up to 8 tau-loosening rounds) made the seed implementation's
per-point ``evaluate()`` calls the wall-time bottleneck.  This benchmark
pins the win: a synthetic 64-layer x 1024-candidate transformer scenario
run through both engines —

  * ``scalar``  — ``repro.core.scalar_ref``: the frozen seed implementation
    (per-width evaluate calls, sorted-list queues, O(layers) PG rescans);
  * ``batched`` — ``repro.core.tail_optimizer``: one ``evaluate_batch``
    table per layer, heap queues, O(1) running PG.

Both must return identical widths/moves (asserted here and property-tested
in tests/test_batched_equivalence.py).  Results go to
``BENCH_tail_optimizer.json`` — wall time per phase, evaluate-call counts,
and the speedup — seeding the repo's perf trajectory.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import (
    LayerShape, TPU_V5E, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates,
)
from repro.core.scalar_ref import ScalarTailEffectOptimizer, ScalarWaveModel

HW = TPU_V5E
N_LAYERS = 64
N_CANDIDATES = 1024
REPEATS = 3


def scenario(n_layers: int = N_LAYERS,
             n_candidates: int = N_CANDIDATES) -> list[TunableLayer]:
    """Synthetic transformer: ``n_layers`` unsharded FFN-like layers with
    deliberately misaligned widths, each with ``n_candidates`` wave-edge
    candidates (quantum q=128, max width n_candidates*q)."""
    q = HW.lane  # shard_out=1
    max_width = n_candidates * q
    layers = []
    for i in range(n_layers):
        # widths spread over the candidate range, never wave-aligned
        width = q * (n_candidates // 4 + (i * 7) % (n_candidates // 2)) + 37
        layer = LayerShape(f"ffn{i}", tokens=8192, d_in=8192, width=width,
                           shard_out=1)
        cands = analytic_candidates(HW, layer, max_width=max_width)
        layers.append(TunableLayer(layer=layer, candidates=cands,
                                   params_per_unit=8192))
    return layers


def _time_best_of(fn, repeats: int = REPEATS):
    best, result = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def run(csv_rows: list, verbose: bool = True,
        out_path: str = "BENCH_tail_optimizer.json"):
    layers = scenario()
    total_p = sum(tl.params(tl.layer.width) for tl in layers)
    tau = 0.02 * total_p
    slack = 0.05

    scalar_model = ScalarWaveModel(HW)
    scalar_opt = ScalarTailEffectOptimizer(scalar_model)
    batched_model = WaveQuantizationModel(HW)
    batched_opt = TailEffectOptimizer(batched_model)

    phases = {}
    results = {}
    for phase, scalar_fn, batched_fn in (
        ("optimize_latency",
         lambda: scalar_opt.optimize_latency(layers, tau=tau, delta=0.5),
         lambda: batched_opt.optimize_latency(layers, tau=tau, delta=0.5)),
        ("optimize_accuracy",
         lambda: scalar_opt.optimize_accuracy(layers, latency_slack=slack),
         lambda: batched_opt.optimize_accuracy(layers, latency_slack=slack)),
    ):
        scalar_model.eval_calls = scalar_model.eval_points = 0
        batched_model.eval_calls = batched_model.eval_points = 0
        t_scalar, res_s = _time_best_of(scalar_fn)
        s_calls, s_pts = scalar_model.eval_calls, scalar_model.eval_points
        t_batched, res_b = _time_best_of(batched_fn)
        b_calls, b_pts = batched_model.eval_calls, batched_model.eval_points

        # the refactor is only a refactor if the answers are identical
        assert res_s.new_widths == res_b.new_widths, phase
        assert res_s.moves == res_b.moves, phase

        speedup = t_scalar / t_batched if t_batched > 0 else float("inf")
        phases[phase] = {
            "scalar_wall_s": t_scalar,
            "batched_wall_s": t_batched,
            "speedup": speedup,
            # counts are per single run (REPEATS runs were timed)
            "scalar_eval_calls": s_calls // REPEATS,
            "scalar_eval_points": s_pts // REPEATS,
            "batched_eval_calls": b_calls // REPEATS,
            "batched_eval_points": b_pts // REPEATS,
        }
        results[phase] = res_b
        if verbose:
            print(f"  {phase:>18}: scalar {t_scalar*1e3:8.2f}ms "
                  f"({s_pts // REPEATS} evals) -> batched "
                  f"{t_batched*1e3:8.2f}ms "
                  f"({b_calls // REPEATS} batch calls, "
                  f"{b_pts // REPEATS} pts)  {speedup:6.1f}x")

    report = {
        "benchmark": "optimizer_scale",
        "scenario": {
            "n_layers": N_LAYERS,
            "n_candidates": N_CANDIDATES,
            "hardware": HW.name,
            "tau_frac": 0.02,
            "latency_slack": slack,
            "repeats": REPEATS,
        },
        "phases": phases,
        "latency_reduction": results["optimize_latency"].latency_reduction,
        "accuracy_param_gain_frac":
            results["optimize_accuracy"].param_gain / total_p,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    if verbose:
        print(f"  wrote {out_path}")

    lat = phases["optimize_latency"]
    csv_rows.append(("optimizer_scale_64x1024",
                     f"{lat['batched_wall_s'] * 1e6:.0f}",
                     f"speedup={lat['speedup']:.1f}x;"
                     f"acc_speedup={phases['optimize_accuracy']['speedup']:.1f}x;"
                     f"scalar_evals={lat['scalar_eval_points']};"
                     f"batched_pts={lat['batched_eval_points']}"))
    return report


if __name__ == "__main__":
    # PYTHONPATH=src python benchmarks/optimizer_scale.py
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
