"""Optimizer scaling: table-driven Algorithm 2 vs the seed scalar path.

Production-size configs (hundreds of tunable layers x thousands of candidate
widths x up to 8 tau-loosening rounds) made the seed implementation's
per-point ``evaluate()`` calls the wall-time bottleneck.  This benchmark
pins the win: a synthetic 64-layer x 1024-candidate transformer scenario
run through both engines —

  * ``scalar``  — ``repro.core.scalar_ref``: the frozen seed implementation
    (per-width evaluate calls, sorted-list queues, O(layers) PG rescans);
  * ``batched`` — ``repro.core.tail_optimizer``: one ``evaluate_batch``
    table per layer, heap queues, O(1) running PG.

Both must return identical widths/moves (asserted here and property-tested
in tests/test_batched_equivalence.py).  Two further phases pin the
model-level engine on a 1024-layer x 1024-candidate heterogeneous stack
(every layer a distinct shape -> the historical per-group loop degenerates
to one dispatch per layer):

  * ``table_build_1024x1024`` — ``_build_tables`` stacked vs per-group
    loop, in latency mode (the ``optimize_latency`` hot path) and full
    mode (the accuracy-walk table); the headline ``full_speedup`` is
    grouped-numpy vs the ``backend="fused"`` staircase build
    (``kernels/staircase_fused.py``: one affine-in-waves pass instead of
    the multi-array staircase), parity-checked against the numpy tables;
  * ``table_cache_1024x1024`` — ``optimize_latency`` cold (sweep + write
    npz tables) vs warm (every table served from disk; the warm run makes
    ZERO model sweeps, asserted here).

A fourth phase pins the serving-side swap cost on a real (reduced)
transformer pytree:

  * ``width_swap`` — 32 batch boundaries all selecting the same plan,
    re-materialized from scratch every boundary (naive) vs served from
    the ``WidthSwapper`` plan cache (one cold materialize + 31
    allocation-free hits).  The gated ``speedup`` is the naive/cached
    wall ratio — dominated by materialization cost on both sides, so it
    stays stable on shared machines.

A ``tile_autotune`` phase pins the wave-aware tile selector
(``kernels/autotune.py``): its gated ``modeled_speedup`` is the
deterministic cost-model ratio of the historical fixed blocks over the
autotuned tail-free tiles on the bench shapes, alongside cold-enumeration
vs warm ``ProfileTableCache`` wall times.

A fifth phase pins the resilience layer's payoff under overload:

  * ``bursty_serving`` — a 4x open-loop burst on a virtual clock with
    modeled batch costs and seeded fault injection: tight deadlines
    (shed / deadline-miss counts, downshift + rollback telemetry) and a
    relaxed full-width-vs-degraded comparison whose gated
    ``p99_speedup`` is deterministic down to the float.

A ``boundary_swap_latency`` phase pins the AOT width-variant executable
cache (``serving/compile_cache.py``): the wall a width-boundary crossing
pays when the realized shape set must be traced + XLA-compiled on the
spot (cold, min over fresh caches) vs dispatched from the warm AOT
table (min of repeats).  The gated ``warm_speedup`` is that ratio; a
warmed mixed-burst continuous-serving run is asserted to perform ZERO
jit traces end-to-end.

Results go to ``BENCH_tail_optimizer.json`` — wall time per phase,
evaluate-call counts, and the speedup — extending the repo's perf
trajectory.  ``benchmarks/run.py --check`` reruns this file and fails when
any committed phase speedup regresses by more than 30%.
"""

from __future__ import annotations

import json
import tempfile
import time

import numpy as np

from repro.core import (
    LayerShape, ProfileTableCache, TPU_V5E, TailEffectOptimizer,
    TunableLayer, WaveQuantizationModel, analytic_candidates,
)
from repro.core.scalar_ref import ScalarTailEffectOptimizer, ScalarWaveModel

HW = TPU_V5E
N_LAYERS = 64
N_CANDIDATES = 1024
STACK_LAYERS = 1024     # the model-level stacked-sweep scenario
REPEATS = 3


def scenario(n_layers: int = N_LAYERS,
             n_candidates: int = N_CANDIDATES) -> list[TunableLayer]:
    """Synthetic transformer: ``n_layers`` unsharded FFN-like layers with
    deliberately misaligned widths, each with ``n_candidates`` wave-edge
    candidates (quantum q=128, max width n_candidates*q)."""
    q = HW.lane  # shard_out=1
    max_width = n_candidates * q
    layers = []
    for i in range(n_layers):
        # widths spread over the candidate range, never wave-aligned
        width = q * (n_candidates // 4 + (i * 7) % (n_candidates // 2)) + 37
        layer = LayerShape(f"ffn{i}", tokens=8192, d_in=8192, width=width,
                           shard_out=1)
        cands = analytic_candidates(HW, layer, max_width=max_width)
        layers.append(TunableLayer(layer=layer, candidates=cands,
                                   params_per_unit=8192))
    return layers


def stacked_scenario(n_layers: int = STACK_LAYERS,
                     n_candidates: int = N_CANDIDATES) -> list[TunableLayer]:
    """NAS-supernet-style stack: every layer a DISTINCT shape (d_in grows
    through the stack, widths never wave-aligned) sharing one candidate
    grid.  Distinct shapes put the historical per-group loop on its worst
    case — one NumPy dispatch per layer — which is exactly the 1000+-layer
    regime the stacked engine exists for."""
    q = HW.lane  # shard_out=1
    ref = LayerShape("ref", tokens=8192, d_in=8192, width=1, shard_out=1)
    cands = analytic_candidates(HW, ref, max_width=n_candidates * q)
    layers = []
    for i in range(n_layers):
        width = q * (n_candidates // 4 + (i * 7) % (n_candidates // 2)) + 37
        layer = LayerShape(f"ffn{i}", tokens=8192, d_in=2048 + 8 * i,
                           width=width, shard_out=1)
        layers.append(TunableLayer(layer=layer, candidates=cands,
                                   params_per_unit=float(layer.d_in)))
    return layers


def _time_best_of(fn, repeats: int = REPEATS):
    best, result = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


def _time_interleaved(fns, repeats: int):
    """Best-of timings with the candidates interleaved per repeat, so an
    ambient load spike on a shared machine hits every candidate instead
    of skewing whichever happened to run during it — the resulting
    RATIOS are far more stable than sequential best-of runs."""
    best = [float("inf")] * len(fns)
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


SWAP_BOUNDARIES = 32


def _width_swap_phase(verbose: bool) -> dict:
    """Live width-swap cost on a real reduced-transformer pytree: naive
    re-materialization every batch boundary vs the WidthSwapper plan
    cache (jax imported lazily — the optimizer phases stay NumPy-only)."""
    import jax
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serving import (
        TrafficClass, WidthPlan, WidthSwapper, serving_templates,
    )

    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=256,
                         n_layers=8, n_heads=8, d_ff=1024)
    params = init_params(jax.random.PRNGKey(0), cfg)
    _, modules = serving_templates(cfg, HW, sites=("mlp", "attn"))
    widths = {}
    for name, ref in modules.items():
        if ref.site == "mlp":
            widths[name] = (cfg.d_ff // 2 if ref.layer % 2
                            else 3 * cfg.d_ff // 4)
        else:
            widths[name] = (cfg.n_heads - 2 * (ref.layer % 2)) \
                * cfg.head_dim
    plan = WidthPlan(traffic=TrafficClass("decode", 2048), widths=widths,
                     latency_s=1.0, baseline_latency_s=2.0,
                     satisfied=True, modules=modules)
    sw = WidthSwapper(params, cfg)
    warm_p, _ = sw.apply(plan)   # compile the slicing kernels once
    jax.block_until_ready(jax.tree.leaves(warm_p))

    def boundaries(clear_every: bool):
        def fn():
            sw._cache.clear()
            hits = 0
            out = None
            for _ in range(SWAP_BOUNDARIES):
                if clear_every:
                    sw._cache.clear()
                out, ev = sw.apply(plan)
                hits += ev.cache_hit
            jax.block_until_ready(jax.tree.leaves(out))
            assert hits == (0 if clear_every else SWAP_BOUNDARIES - 1)
        return fn

    t_naive, t_cached = _time_interleaved(
        [boundaries(True), boundaries(False)], REPEATS)
    phase = {
        "n_layers": cfg.n_layers,
        "boundaries": SWAP_BOUNDARIES,
        "naive_wall_s": t_naive,
        "cached_wall_s": t_cached,
        "cold_swap_s": t_naive / SWAP_BOUNDARIES,
        "speedup": t_naive / t_cached if t_cached > 0 else float("inf"),
        "warm_cache_hits": SWAP_BOUNDARIES - 1,
    }
    if verbose:
        print(f"  width_swap: naive {t_naive*1e3:8.2f}ms -> plan-cached "
              f"{t_cached*1e3:8.2f}ms over {SWAP_BOUNDARIES} boundaries  "
              f"{phase['speedup']:6.1f}x "
              f"(cold swap {phase['cold_swap_s']*1e6:.0f}us)")
    return phase


BURST_SLOTS = 4
BURST_CAP = 3                       # admission queue cap, in batches
BURST_N = 4 * BURST_SLOTS * BURST_CAP   # 4x the sustainable queue


def _bursty_serving_phase(verbose: bool) -> dict:
    """Open-loop burst under overload: full width vs the degradation
    ladder, on a virtual clock advanced by modeled batch costs (plus
    seeded straggler batches), so every number here is deterministic —
    the gated p99_speedup is pure width policy, no host noise.

    Two runs on the identical 4x burst:

      * ``tight``   — 0.6s deadlines + admission control + the ladder:
        reports shed / deadline-miss counts (misses must be zero);
      * ``relaxed`` — generous deadlines so nothing sheds, full width
        vs degraded: the p50/p99 gap is the ladder's modeled win.
    """
    import jax
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serving import (
        AdmissionControl, DegradationController, DegradationLadder,
        ServeEngine, ServingWidthPlanner, TrafficClass, WidthSwapper,
        serving_templates,
    )
    from repro.serving.chaos import (
        LoadReport, SlowBatchInjector, SwapFailureInjector, VirtualClock,
        burst_requests, modeled_batch_cost,
    )

    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)
    templates, modules = serving_templates(cfg, HW, tokens=96,
                                           sites=("mlp",))
    planner = ServingWidthPlanner(HW, templates, modules=modules)
    traffic = [TrafficClass("burst", 96)]
    planner.plan(traffic)
    ladder = DegradationLadder.build(planner, traffic, deltas=(0.8, 0.6))

    def engine(degrade: bool):
        swapper = degrader = eng_planner = None
        if degrade:
            eng_planner = planner
            swapper = WidthSwapper(
                params, cfg,
                fault_hook=SwapFailureInjector(0.2, seed=1,
                                               steps=("begin",)))
            degrader = DegradationController(
                ladder, down_threshold=1.0, up_threshold=0.5,
                down_patience=1, up_patience=2)
        return ServeEngine(
            params, cfg, max_len=48, batch_slots=BURST_SLOTS,
            planner=eng_planner, swapper=swapper,
            admission=AdmissionControl(max_queue_batches=BURST_CAP,
                                       target_batch_s=0.25,
                                       ewma_alpha=0.5, headroom=2.0),
            degrader=degrader, clock=VirtualClock(),
            batch_cost_fn=modeled_batch_cost(
                1e-3, overhead_s=0.01,
                slow=SlowBatchInjector(0.25, 0.05, seed=11)))

    def burst(deadline_s):
        return burst_requests(cfg.vocab_size, n=BURST_N, prompt_len=16,
                              max_new_tokens=8, deadline_s=deadline_s,
                              seed=3)

    # tight deadlines: admission sheds, nobody admitted misses
    eng_tight = engine(degrade=True)
    tight = LoadReport.from_results(eng_tight.generate(burst(0.6)))
    assert tight.deadline_missed == 0, "admitted request missed deadline"
    rolled = sum(ev.outcome == "rolled_back" for ev in eng_tight.swap_log)
    downs = sum(s.direction == "down"
                for s in eng_tight.degrader.shift_log)
    assert downs >= 1, "burst never triggered a downshift"

    # relaxed deadlines: identical burst completes in both modes; the
    # p99 gap is the degradation ladder's win under the same overload
    full = LoadReport.from_results(
        engine(degrade=False).generate(burst(100.0)))
    deg = LoadReport.from_results(
        engine(degrade=True).generate(burst(100.0)))
    assert full.shed == deg.shed == 0
    assert deg.p99_s < full.p99_s, "degraded mode must beat full width"

    phase = {
        "burst_requests": BURST_N,
        "queue_cap_batches": BURST_CAP,
        "tight_shed": tight.shed,
        "tight_completed": tight.completed,
        "tight_deadline_missed": tight.deadline_missed,
        "tight_downshifts": downs,
        "tight_rolled_back_swaps": rolled,
        "full_p50_s": full.p50_s,
        "full_p99_s": full.p99_s,
        "degraded_p50_s": deg.p50_s,
        "degraded_p99_s": deg.p99_s,
        # deterministic (virtual clock): gate-safe down to the float
        "p99_speedup": full.p99_s / deg.p99_s,
    }
    if verbose:
        print(f"  bursty_serving: 4x burst ({BURST_N} reqs)  tight: "
              f"{tight.shed} shed / {tight.deadline_missed} missed, "
              f"{downs} downshifts, {rolled} rollbacks  relaxed p99: "
              f"full {full.p99_s*1e3:.0f}ms -> degraded "
              f"{deg.p99_s*1e3:.0f}ms  "
              f"{phase['p99_speedup']:.2f}x")
    return phase


def _continuous_serving_phase(verbose: bool) -> dict:
    """Continuous batching vs per-batch serving on the identical 4x
    burst, on a virtual clock with the same modeled per-token cost on
    both sides — the gated ``p99_speedup`` is pure scheduling policy.

    The workload is deliberately heterogeneous (alternating short/long
    decode budgets and prompt lengths): the per-batch engine pads every
    prompt to the batch max and holds every slot until the batch's
    longest request finishes, so short requests pay the long tail
    (head-of-line blocking) and padded tokens are billed as real work.
    The continuous engine retires each request the step it finishes and
    re-fills the slot in flight, so the same requests see a shorter
    tail from scheduling alone — no width plans, no faults, no overlap
    with what ``bursty_serving`` measures.
    """
    import jax
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serving import ContinuousServeEngine, Request, ServeEngine
    from repro.serving.chaos import (
        LoadReport, VirtualClock, modeled_batch_cost,
    )

    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(3)
    requests = []
    for i in range(BURST_N):
        plen = 16 if i % 2 else 8
        requests.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(plen,))
            .astype(np.int32),
            max_new_tokens=16 if i % 3 == 0 else 4))

    cost = modeled_batch_cost(1e-3)      # same per-token price both sides

    eng_batch = ServeEngine(params, cfg, max_len=48,
                            batch_slots=BURST_SLOTS, clock=VirtualClock(),
                            batch_cost_fn=cost)
    batch = LoadReport.from_results(eng_batch.generate(list(requests)))

    eng_cont = ContinuousServeEngine(params, cfg, max_len=48,
                                     batch_slots=BURST_SLOTS,
                                     clock=VirtualClock(),
                                     batch_cost_fn=cost)
    cont = LoadReport.from_results(eng_cont.run(list(requests)))
    ledger = eng_cont.drain()
    assert ledger.complete and ledger.finished == BURST_N
    assert batch.completed == cont.completed == BURST_N
    assert cont.p99_s < batch.p99_s, \
        "continuous batching must beat the per-batch engine's tail"

    phase = {
        "burst_requests": BURST_N,
        "batch_slots": BURST_SLOTS,
        "batch_p50_s": batch.p50_s,
        "batch_p99_s": batch.p99_s,
        "continuous_p50_s": cont.p50_s,
        "continuous_p99_s": cont.p99_s,
        "in_flight_joins": eng_cont.join_count,
        # deterministic (virtual clock): gate-safe down to the float
        "p99_speedup": batch.p99_s / cont.p99_s,
    }
    if verbose:
        print(f"  continuous_serving: 4x burst ({BURST_N} reqs, "
              f"mixed lengths)  p99: per-batch {batch.p99_s*1e3:.0f}ms "
              f"-> continuous {cont.p99_s*1e3:.0f}ms  "
              f"{phase['p99_speedup']:.2f}x "
              f"({eng_cont.join_count} in-flight joins)")
    return phase


def _hedged_serving_phase(verbose: bool) -> dict:
    """Hedged vs unhedged p99.9 on a straggler burst — the tail-at-scale
    payoff of width-variant hedging, measured end to end.

    Two replicas on per-replica virtual clocks behind a
    ``ReplicaRouter``; replica 0 is an 8x gray-failure straggler
    (``ReplicaStallInjector``: every costed step pays, modeling a
    throttling machine, not an occasional slow batch).  Health-based
    draining is disabled (``slow_factor=None``) so the entire tail
    improvement is attributable to hedging: requests that outlive the
    hedge delay launch a backup leg on the healthy sibling, first
    completion wins, the loser is cancelled slot-exactly, and the pair
    accounts as one logical request.  Both runs serve the identical
    arrival schedule with identical chunked-prefill engines, so the
    gated ``p999_speedup`` is pure policy — deterministic down to the
    float on the virtual clocks.
    """
    import jax
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.serving import (
        Arrival, ContinuousServeEngine, HedgePolicy, ReplicaRouter,
        Request, WidthVariantCompileCache,
    )
    from repro.serving.chaos import (
        ReplicaStallInjector, VirtualClock, modeled_batch_cost,
    )

    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(7)
    arrivals = [Arrival(t=0.001 * i,
                        request=Request(
                            prompt=rng.integers(0, cfg.vocab_size,
                                                size=(13,))
                            .astype(np.int32), max_new_tokens=8),
                        klass="burst")
                for i in range(BURST_N)]

    def serve(hedge: bool):
        cache = WidthVariantCompileCache(cfg)

        def replica(stall=None):
            return ContinuousServeEngine(
                params, cfg, max_len=64, batch_slots=4,
                clock=VirtualClock(), prefill_chunk=4,
                step_token_budget=16, compile_cache=cache,
                batch_cost_fn=modeled_batch_cost(1e-4, overhead_s=1e-4,
                                                 slow=stall))

        router = ReplicaRouter(
            {"r0": replica(ReplicaStallInjector(8.0)), "r1": replica()},
            hedge=(HedgePolicy(default_delay_s=0.01, rung=0)
                   if hedge else None),
            slow_factor=None)
        results = router.run([Arrival(a.t, a.request, a.klass)
                              for a in arrivals])
        ledger = router.ledger()
        assert ledger.complete and ledger.finished == BURST_N, ledger
        lats = np.asarray([r.latency_s for r in results])
        return router, lats

    _, lats_un = serve(hedge=False)
    router_h, lats_h = serve(hedge=True)
    p999_un = float(np.percentile(lats_un, 99.9))
    p999_h = float(np.percentile(lats_h, 99.9))
    assert p999_h < p999_un, \
        "hedging must beat the unhedged tail on a straggler burst"

    phase = {
        "burst_requests": BURST_N,
        "replicas": 2,
        "stall_factor": 8.0,
        "unhedged_p999_s": p999_un,
        "hedged_p999_s": p999_h,
        "hedges_launched": len(router_h.hedge_log),
        "hedge_wins_backup": router_h.ledger().hedge_wins_backup,
        # deterministic (virtual clocks): gate-safe down to the float
        "p999_speedup": p999_un / p999_h,
    }
    if verbose:
        print(f"  hedged_serving: straggler burst ({BURST_N} reqs, one "
              f"8x stalled replica)  p99.9: unhedged "
              f"{p999_un*1e3:.0f}ms -> hedged {p999_h*1e3:.0f}ms  "
              f"{phase['p999_speedup']:.2f}x "
              f"({phase['hedges_launched']} hedges, "
              f"{phase['hedge_wins_backup']} backup wins)")
    return phase


def _boundary_swap_latency_phase(verbose: bool) -> dict:
    """Cold-trace vs warm-AOT boundary crossing wall.

    Cold: a fresh compile cache addressed at a realized narrow key has
    no executable, so the first decode dispatch pays a full jit trace +
    XLA compile — the historical boundary-crossing spike.  Warm: the
    same dispatch after ``precompile`` is a table lookup + execute.
    Both sides time the identical ``cache.decode`` call; cold takes the
    min over fresh caches (each rebuilds its jit wrappers, so every
    repeat genuinely retraces), warm the min of repeats on one cache.
    The gated ``warm_speedup`` is the ratio — asserted >= 5x here, in
    practice orders of magnitude.

    A second scenario runs a *warmed* continuous engine through a mixed
    burst that crosses a width boundary mid-flight and asserts the whole
    run performs ZERO jit traces — the acceptance contract for the AOT
    serving hot path.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced_config
    from repro.models import init_params
    from repro.models import transformer as tfm
    from repro.serving import (
        AdmissionControl, ContinuousServeEngine, Request,
        ServingWidthPlanner, TrafficClass, WidthSwapper,
        WidthVariantCompileCache, realized_exec_key, serving_templates,
    )
    from repro.serving.chaos import VirtualClock, modeled_batch_cost

    cfg = reduced_config(get_config("qwen1.5-0.5b"), d_model=128,
                         n_layers=2, d_ff=576)
    params = init_params(jax.random.PRNGKey(0), cfg)
    templates, modules = serving_templates(cfg, HW, tokens=96,
                                           sites=("mlp",))
    planner = ServingWidthPlanner(HW, templates, modules=modules)
    planner.plan([TrafficClass("burst", 96)])
    narrow = planner.select(96)
    assert narrow.widths, "planner produced no narrowed plan"
    # pin the crossover economics: modeled saving dwarfs one compile,
    # so the plan is realized sliced (its own executable)
    narrow = _dc.replace(narrow, latency_s=0.5, baseline_latency_s=1.0)

    swapper = WidthSwapper(params, cfg)
    params_n, _ = swapper.apply(narrow)
    key_n = realized_exec_key(*swapper.realize_plan(narrow))
    b, max_len = 2, 32
    tok = jnp.zeros((b,), jnp.int32)
    posv = jnp.zeros((b,), jnp.int32)
    states = tfm.init_decode_state(cfg, b, max_len)

    def cold_once():
        cache = WidthVariantCompileCache(cfg)
        cache.set_active(key_n)
        t0 = time.perf_counter()
        out = cache.decode(params_n, tok, posv, states)
        jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        assert cache.tracer.count == 1      # the boundary retraced
        return wall

    cold = min(cold_once() for _ in range(REPEATS))

    warm_cache = WidthVariantCompileCache(cfg)
    warm_cache.precompile("decode", key_n, (b,),
                          (params_n, tok, posv, states))
    warm_cache.set_active(key_n)
    traced = warm_cache.tracer.count

    def warm_once():
        t0 = time.perf_counter()
        out = warm_cache.decode(params_n, tok, posv, states)
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    warm_once()                             # executable warm-up dispatch
    warm = min(warm_once() for _ in range(10))
    assert warm_cache.tracer.count == traced
    warm_speedup = cold / warm if warm > 0 else float("inf")
    assert warm_speedup >= 5.0, \
        f"warm AOT boundary must be >=5x a cold trace ({warm_speedup:.1f}x)"

    # ---- warmed mixed-burst: an entire serving run with zero traces --
    class _Scripted:
        def __init__(self, plans):
            self.plans = list(plans)

        def select(self, tokens):
            plan = self.plans[0]
            if len(self.plans) > 1:
                self.plans.pop(0)
            return plan

        def observe(self, signal):
            return 0

    burst_cache = WidthVariantCompileCache(cfg)
    eng = ContinuousServeEngine(
        params, cfg, max_len=48, batch_slots=4, clock=VirtualClock(),
        swapper=WidthSwapper(params, cfg), compile_cache=burst_cache,
        batch_cost_fn=modeled_batch_cost(1e-3),
        boundary_every=2, boundary_cooldown=1000)
    eng.planner = None
    eng.degrader = _Scripted([narrow])
    eng.admission = AdmissionControl(max_queue_batches=100)

    rng = np.random.default_rng(3)
    requests = []
    for i in range(16):
        plen = 13 if i % 2 else 6           # two pow2 buckets {8, 16}
        requests.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, size=(plen,))
            .astype(np.int32),
            max_new_tokens=8 if i % 3 == 0 else 4))
    eng.warm_compile([narrow], prefill_lengths=(6, 13))
    traced_at_warm = burst_cache.tracer.count
    results = eng.run(requests)
    assert burst_cache.tracer.count == traced_at_warm, \
        "warmed burst run must perform zero jit traces"
    assert eng.ledger().complete
    assert any(bv.outcome == "ok" for bv in eng.boundary_log)
    assert all(not r.failed and not r.shed for r in results)

    phase = {
        "cold_boundary_wall_s": cold,
        "warm_boundary_wall_s": warm,
        "warm_speedup": warm_speedup,
        "burst_requests": len(requests),
        "burst_in_flight_joins": eng.join_count,
        "burst_run_traces": burst_cache.tracer.count - traced_at_warm,
        "burst_warm_hits": burst_cache.stats["hits"],
        "aot_compiles": burst_cache.stats["aot_compiles"],
    }
    if verbose:
        print(f"  boundary_swap_latency: cold trace {cold*1e3:8.2f}ms "
              f"-> warm AOT {warm*1e6:8.1f}us  {warm_speedup:6.1f}x  "
              f"(burst: {burst_cache.stats['hits']} warm hits, "
              f"0 traces)")
    return phase


# Shapes the kernel wrappers actually serve (matmul M/N/K; flash
# (b, sq, skv, h, kv_heads, dh); moe (e, c, d, f)) — mirrors the golden
# set in tests/test_autotune.py.
TUNE_MATMUL = [(1024, 1024, 1024), (8192, 4096, 4096),
               (256, 8192, 2048), (4096, 11008, 4096)]
TUNE_FLASH = [(2, 1024, 1024, 8, 2, 128), (1, 4096, 4096, 16, 16, 64)]
TUNE_MOE = [(8, 256, 512, 1024), (16, 512, 1024, 2048)]


def _tile_autotune_phase(verbose: bool) -> dict:
    """Wave-aware tile selection vs the historical fixed blocks.

    ``modeled_speedup`` is the geometric mean of (fixed-default modeled
    latency / autotuned modeled latency) over the bench shapes — a pure
    deterministic function of the cost model and HardwareSpec, so the
    --check gate on it is stable down to the float.  Wall times cover the
    cold enumeration and the warm ``ProfileTableCache`` reload."""
    from repro.kernels import autotune
    from repro.kernels.autotune import (
        _flash_config, _force_config, _matmul_config, _moe_config,
        autotune_flash_attention, autotune_matmul, autotune_moe_gmm,
    )

    jobs = []
    for m, n, k in TUNE_MATMUL:
        jobs.append((lambda m=m, n=n, k=k, **kw:
                     autotune_matmul(HW, m, n, k, **kw),
                     _force_config(_matmul_config, HW, (m, n, k),
                                   (min(256, m), min(256, n), min(512, k)),
                                   16)))
    for b, sq, skv, h, kvh, dh in TUNE_FLASH:
        jobs.append((lambda b=b, sq=sq, skv=skv, h=h, kvh=kvh, dh=dh, **kw:
                     autotune_flash_attention(HW, b, sq, skv, h, kvh, dh,
                                              **kw),
                     _force_config(_flash_config, HW,
                                   (b, sq, skv, h, kvh, dh),
                                   (min(512, sq), min(512, skv)), 16)))
    for e, c, d, f in TUNE_MOE:
        jobs.append((lambda e=e, c=c, d=d, f=f, **kw:
                     autotune_moe_gmm(HW, e, c, d, f, **kw),
                     _force_config(_moe_config, HW, (e, c, d, f),
                                   (min(128, c), min(256, f), min(256, d)),
                                   16)))

    def enumerate_all(**kw):
        autotune.clear_memo()
        return [fn(**kw) for fn, _ in jobs]

    t_cold, chosen = _time_best_of(enumerate_all)

    with tempfile.TemporaryDirectory() as cache_dir:
        cache = ProfileTableCache(cache_dir)
        enumerate_all(cache=cache)          # populate the tiles cache
        assert cache.stats.writes == len(jobs)

        def warm():
            cfgs = enumerate_all(cache=cache)
            assert all(a.blocks == b.blocks for a, b in zip(cfgs, chosen))
            return cfgs
        t_warm, _ = _time_best_of(warm)
        warm_hits = cache.stats.hits

    ratios = [default.latency_s / cfg.latency_s
              for cfg, (_, default) in zip(chosen, jobs)]
    modeled_speedup = float(np.exp(np.mean(np.log(ratios))))
    assert modeled_speedup >= 1.0, "autotuner regressed vs fixed defaults"
    assert all(c.tail_free for c in chosen), \
        "bench shapes admit tail-free tilings; autotuner must find them"

    phase = {
        "shapes": len(jobs),
        "cold_wall_s": t_cold,
        "warm_wall_s": t_warm,
        "cold_over_warm": t_cold / t_warm if t_warm > 0 else float("inf"),
        # deterministic cost-model ratio: gate-safe down to the float
        "modeled_speedup": modeled_speedup,
        "tail_free_configs": sum(c.tail_free for c in chosen),
        "worst_ratio": min(ratios),
        "best_ratio": max(ratios),
    }
    if verbose:
        print(f"  tile_autotune: {len(jobs)} shapes enumerated in "
              f"{t_cold*1e3:8.2f}ms (warm cache {t_warm*1e3:8.2f}ms)  "
              f"modeled vs fixed defaults {modeled_speedup:.2f}x "
              f"(all {phase['tail_free_configs']} tail-free)")
    return phase


def run(csv_rows: list, verbose: bool = True,
        out_path: str = "BENCH_tail_optimizer.json"):
    layers = scenario()
    total_p = sum(tl.params(tl.layer.width) for tl in layers)
    tau = 0.02 * total_p
    slack = 0.05

    scalar_model = ScalarWaveModel(HW)
    scalar_opt = ScalarTailEffectOptimizer(scalar_model)
    batched_model = WaveQuantizationModel(HW)
    batched_opt = TailEffectOptimizer(batched_model)

    phases = {}
    results = {}
    for phase, scalar_fn, batched_fn in (
        ("optimize_latency",
         lambda: scalar_opt.optimize_latency(layers, tau=tau, delta=0.5),
         lambda: batched_opt.optimize_latency(layers, tau=tau, delta=0.5)),
        ("optimize_accuracy",
         lambda: scalar_opt.optimize_accuracy(layers, latency_slack=slack),
         lambda: batched_opt.optimize_accuracy(layers, latency_slack=slack)),
    ):
        scalar_model.eval_calls = scalar_model.eval_points = 0
        batched_model.eval_calls = batched_model.eval_points = 0
        t_scalar, res_s = _time_best_of(scalar_fn)
        s_calls, s_pts = scalar_model.eval_calls, scalar_model.eval_points
        t_batched, res_b = _time_best_of(batched_fn)
        b_calls, b_pts = batched_model.eval_calls, batched_model.eval_points

        # the refactor is only a refactor if the answers are identical
        assert res_s.new_widths == res_b.new_widths, phase
        assert res_s.moves == res_b.moves, phase

        speedup = t_scalar / t_batched if t_batched > 0 else float("inf")
        phases[phase] = {
            "scalar_wall_s": t_scalar,
            "batched_wall_s": t_batched,
            "speedup": speedup,
            # counts are per single run (REPEATS runs were timed)
            "scalar_eval_calls": s_calls // REPEATS,
            "scalar_eval_points": s_pts // REPEATS,
            "batched_eval_calls": b_calls // REPEATS,
            "batched_eval_points": b_pts // REPEATS,
        }
        results[phase] = res_b
        if verbose:
            print(f"  {phase:>18}: scalar {t_scalar*1e3:8.2f}ms "
                  f"({s_pts // REPEATS} evals) -> batched "
                  f"{t_batched*1e3:8.2f}ms "
                  f"({b_calls // REPEATS} batch calls, "
                  f"{b_pts // REPEATS} pts)  {speedup:6.1f}x")

    # ---- stacked model-level table build (1024 x 1024, heterogeneous) --
    stack = stacked_scenario()
    opt = TailEffectOptimizer(WaveQuantizationModel(HW))
    fused_opt = TailEffectOptimizer(WaveQuantizationModel(HW,
                                                          backend="fused"))

    def check_equal(full):
        a = opt._build_tables(stack, full=full, stacked=False)
        b = opt._build_tables(stack, full=full, stacked=True)
        c = fused_opt._build_tables(stack, full=full, stacked=True)
        for x, y, z in zip(a, b, c):
            ok = (np.array_equal(x.lat, y.lat) if full else x.lat == y.lat)
            assert ok and x.start_lat == y.start_lat, "stacked != grouped"
            # the fused factoring reassociates float ops: tolerance-based
            # parity (the DIFFERENTIAL tests pin the staircase structure
            # — identical waves and edges — exactly); in latency mode
            # ``lat`` is the sparse {index: latency} probe dict
            if full:
                assert np.allclose(x.lat, z.lat, rtol=1e-9, atol=0.0)
            else:
                assert x.lat.keys() == z.lat.keys()
                assert np.allclose([x.lat[i] for i in x.lat],
                                   [z.lat[i] for i in x.lat],
                                   rtol=1e-9, atol=0.0)
            assert np.isclose(x.start_lat, z.start_lat, rtol=1e-9)

    # interleaved best-of-11: the builds are milliseconds, so the extra
    # repeats cost little and the grouped/stacked ratio stays stable on
    # noisy shared machines
    (t_group, t_stack, t_group_full, t_stack_full, t_fused,
     t_fused_full) = _time_interleaved(
        [lambda: opt._build_tables(stack, full=False, stacked=False),
         lambda: opt._build_tables(stack, full=False, stacked=True),
         lambda: opt._build_tables(stack, full=True, stacked=False),
         lambda: opt._build_tables(stack, full=True, stacked=True),
         lambda: fused_opt._build_tables(stack, full=False, stacked=True),
         lambda: fused_opt._build_tables(stack, full=True, stacked=True)],
        11)
    check_equal(False)
    check_equal(True)
    phases["table_build_1024x1024"] = {
        "n_layers": STACK_LAYERS,
        "n_candidates": N_CANDIDATES,
        "grouped_wall_s": t_group,
        "stacked_wall_s": t_stack,
        "speedup": t_group / t_stack if t_stack > 0 else float("inf"),
        "grouped_full_wall_s": t_group_full,
        "stacked_full_wall_s": t_stack_full,
        # the historical stacked-vs-grouped full-table ratio
        "stacked_full_speedup": (t_group_full / t_stack_full
                                 if t_stack_full > 0 else float("inf")),
        "fused_wall_s": t_fused,
        "fused_full_wall_s": t_fused_full,
        # headline ratio: grouped numpy -> fused-staircase stacked build
        "full_speedup": (t_group_full / t_fused_full
                         if t_fused_full > 0 else float("inf")),
    }
    if verbose:
        p = phases["table_build_1024x1024"]
        print(f"  table_build_1024x1024: per-group {t_group*1e3:8.2f}ms -> "
              f"stacked {t_stack*1e3:8.2f}ms  {p['speedup']:6.1f}x "
              f"(full tables: {t_group_full*1e3:.2f}ms -> stacked "
              f"{t_stack_full*1e3:.2f}ms "
              f"{p['stacked_full_speedup']:.1f}x -> fused "
              f"{t_fused_full*1e3:.2f}ms {p['full_speedup']:.1f}x)")

    # ---- cold vs warm profile-table cache (1024 layers) ----------------
    stack_tau = 0.02 * sum(tl.params(tl.layer.width) for tl in stack)
    with tempfile.TemporaryDirectory() as cache_dir:
        cold_model = WaveQuantizationModel(HW)
        cold_opt = TailEffectOptimizer(cold_model,
                                       cache=ProfileTableCache(cache_dir))
        t0 = time.perf_counter()
        res_cold = cold_opt.optimize_latency(stack, tau=stack_tau,
                                             delta=0.5)
        t_cold = time.perf_counter() - t0

        def warm_run():
            model = WaveQuantizationModel(HW)
            o = TailEffectOptimizer(model,
                                    cache=ProfileTableCache(cache_dir))
            r = o.optimize_latency(stack, tau=stack_tau, delta=0.5)
            assert model.eval_calls == 0, "warm cache must skip all sweeps"
            return r
        t_warm, res_warm = _time_best_of(warm_run)
        assert res_warm.new_widths == res_cold.new_widths
    phases["table_cache_1024x1024"] = {
        "n_layers": STACK_LAYERS,
        "cold_wall_s": t_cold,
        "warm_wall_s": t_warm,
        # deliberately NOT named "speedup": both runs are dominated by the
        # same Algorithm 2 rounds, so the wall ratio is noise-bound; the
        # cache's contract is the warm run making ZERO model sweeps
        # (asserted above), which run.py --check cannot time-regress.
        "cold_over_warm": t_cold / t_warm if t_warm > 0 else float("inf"),
        "warm_eval_calls": 0,
    }
    if verbose:
        print(f"  table_cache_1024x1024: cold {t_cold*1e3:8.2f}ms -> warm "
              f"{t_warm*1e3:8.2f}ms "
              f"{phases['table_cache_1024x1024']['cold_over_warm']:6.1f}x "
              f"(warm model sweeps: 0)")

    phases["tile_autotune"] = _tile_autotune_phase(verbose)
    phases["width_swap"] = _width_swap_phase(verbose)
    phases["bursty_serving"] = _bursty_serving_phase(verbose)
    phases["continuous_serving"] = _continuous_serving_phase(verbose)
    phases["hedged_serving"] = _hedged_serving_phase(verbose)
    phases["boundary_swap_latency"] = _boundary_swap_latency_phase(verbose)

    report = {
        "benchmark": "optimizer_scale",
        "scenario": {
            "n_layers": N_LAYERS,
            "n_candidates": N_CANDIDATES,
            "stacked_n_layers": STACK_LAYERS,
            "hardware": HW.name,
            "tau_frac": 0.02,
            "latency_slack": slack,
            "repeats": REPEATS,
        },
        "phases": phases,
        "latency_reduction": results["optimize_latency"].latency_reduction,
        "accuracy_param_gain_frac":
            results["optimize_accuracy"].param_gain / total_p,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    if verbose:
        print(f"  wrote {out_path}")

    lat = phases["optimize_latency"]
    csv_rows.append(("optimizer_scale_64x1024",
                     f"{lat['batched_wall_s'] * 1e6:.0f}",
                     f"speedup={lat['speedup']:.1f}x;"
                     f"acc_speedup={phases['optimize_accuracy']['speedup']:.1f}x;"
                     f"scalar_evals={lat['scalar_eval_points']};"
                     f"batched_pts={lat['batched_eval_points']}"))
    tb = phases["table_build_1024x1024"]
    csv_rows.append(("table_build_1024x1024",
                     f"{tb['stacked_wall_s'] * 1e6:.0f}",
                     f"speedup={tb['speedup']:.1f}x;"
                     f"full_speedup={tb['full_speedup']:.1f}x;"
                     f"stacked_full_speedup="
                     f"{tb['stacked_full_speedup']:.1f}x"))
    ta = phases["tile_autotune"]
    csv_rows.append(("tile_autotune",
                     f"{ta['cold_wall_s'] * 1e6:.0f}",
                     f"modeled_speedup={ta['modeled_speedup']:.2f}x;"
                     f"shapes={ta['shapes']};"
                     f"tail_free={ta['tail_free_configs']};"
                     f"cold/warm={ta['cold_over_warm']:.1f}x"))
    cc = phases["table_cache_1024x1024"]
    csv_rows.append(("table_cache_1024x1024",
                     f"{cc['warm_wall_s'] * 1e6:.0f}",
                     f"cold/warm={cc['cold_over_warm']:.1f}x;"
                     f"warm_sweeps=0"))
    ws = phases["width_swap"]
    csv_rows.append(("width_swap_32_boundaries",
                     f"{ws['cached_wall_s'] * 1e6:.0f}",
                     f"speedup={ws['speedup']:.1f}x;"
                     f"cold_swap_us={ws['cold_swap_s'] * 1e6:.0f}"))
    bs = phases["bursty_serving"]
    csv_rows.append(("bursty_serving_4x",
                     f"{bs['degraded_p99_s'] * 1e6:.0f}",
                     f"p99_speedup={bs['p99_speedup']:.2f}x;"
                     f"shed={bs['tight_shed']};"
                     f"missed={bs['tight_deadline_missed']};"
                     f"rollbacks={bs['tight_rolled_back_swaps']}"))
    cs = phases["continuous_serving"]
    csv_rows.append(("continuous_serving_4x",
                     f"{cs['continuous_p99_s'] * 1e6:.0f}",
                     f"p99_speedup={cs['p99_speedup']:.2f}x;"
                     f"joins={cs['in_flight_joins']}"))
    hs = phases["hedged_serving"]
    csv_rows.append(("hedged_serving_straggler",
                     f"{hs['hedged_p999_s'] * 1e6:.0f}",
                     f"p999_speedup={hs['p999_speedup']:.2f}x;"
                     f"hedges={hs['hedges_launched']};"
                     f"backup_wins={hs['hedge_wins_backup']}"))
    bw = phases["boundary_swap_latency"]
    csv_rows.append(("boundary_swap_latency",
                     f"{bw['warm_boundary_wall_s'] * 1e6:.0f}",
                     f"warm_speedup={bw['warm_speedup']:.1f}x;"
                     f"cold_ms={bw['cold_boundary_wall_s'] * 1e3:.1f};"
                     f"burst_traces={bw['burst_run_traces']};"
                     f"warm_hits={bw['burst_warm_hits']}"))
    return report


if __name__ == "__main__":
    # PYTHONPATH=src python benchmarks/optimizer_scale.py
    rows = []
    run(rows)
    for r in rows:
        print(",".join(r))
