"""Paper Fig. 1/3: the latency staircase, per assigned-arch FFN layer.

For each arch we sweep its d_ff width through the wave-quantization model
(TP=16 on v5e) and cross-check the useful-FLOPs accounting against compiled
XLA (cost_analysis of the actual matmul at each width).  Emits the stairs +
where each arch's own d_ff sits in its wave (the tail it carries today).
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config, list_archs
from repro.core import (
    LayerShape, TPU_V5E, WaveQuantizationModel, analytic_candidates,
)


def run(csv_rows: list, verbose: bool = True):
    hw = TPU_V5E
    model = WaveQuantizationModel(hw)
    t0 = time.time()
    lines = []
    for arch in list_archs():
        cfg = get_config(arch)
        d_ff = cfg.moe_d_ff if (cfg.moe and cfg.moe_d_ff) else cfg.d_ff
        # MoE expert FFNs are expert-parallel, not width-sharded
        shard = 1 if cfg.moe else (16 if d_ff % 16 == 0 else 1)
        layer = LayerShape(f"{arch}/ffn", tokens=8192, d_in=cfg.d_model,
                           width=d_ff, shard_out=shard)
        q = model.width_quantum(shard)
        # One batched sweep covers the arch's own d_ff (last row) and the
        # full staircase around it.
        widths = np.arange(q // 2, d_ff + q + 1, q // 2)
        table = model.evaluate_batch(layer, np.append(widths, d_ff))
        pt = table.point(len(table) - 1)
        # position within the wave: 1.0 = right edge (no tail)
        frac = d_ff / (pt.waves * q)
        lines.append((arch, d_ff, q, pt.waves, frac, pt.utilization))
        n_steps = int(np.unique(np.round(table.latency_s[:-1], 12)).size)
        if verbose:
            print(f"  {arch:>28} d_ff={d_ff:>6} q={q:>5} waves={pt.waves:>3} "
                  f"wave-fill={frac:5.3f} util={pt.utilization:5.3f} "
                  f"stairs={n_steps}")
    dt_us = (time.time() - t0) * 1e6 / max(len(lines), 1)
    worst = min(lines, key=lambda r: r[4])
    csv_rows.append(("staircase_fig1_3", f"{dt_us:.1f}",
                     f"worst_wave_fill={worst[0]}:{worst[4]:.3f}"))
    return lines
