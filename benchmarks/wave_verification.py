"""Paper Fig. 5: B (blocks), W (waves), L (latency) verification.

Sweeps filter count F for a fixed-input matmul through the *actual* Pallas
kernel grid (grid_blocks) and checks the analytic GridWaveModel reproduces
the block counts and the ceil-quantized latency — paper's Verification 1-3,
with the TPU tile grid playing the SM-wave role.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import GridWaveModel, TPU_V5E, ceil_div
from repro.kernels.matmul_tiled import grid_blocks


def run(csv_rows: list, verbose: bool = True):
    hw = TPU_V5E
    bm, bn, bk = 256, 256, 512
    m, k = 4096, 4608          # input feature map (fixed, paper Fig. 5)
    gw = GridWaveModel(hw, block_flops=2.0 * bm * bn * bk)
    t0 = time.time()
    checks = 0
    v1 = v2 = v3 = True
    prev_b = None
    rows = []
    for f_ in range(64, 2049, 64):
        b = grid_blocks(m, f_, k, bm, bn, bk)
        r = gw.evaluate(b)
        # Verification 1: blocks grow stepwise with F (one col-block / bn)
        if prev_b is not None:
            v1 &= b - prev_b in (0, (m // bm) * (k // bk))
        prev_b = b
        # Verification 2: latency step granularity == cores_per_chip
        v2 &= r.waves == ceil_div(b, hw.cores_per_chip)
        # Verification 3: within a wave count, latency identical
        b_pad = grid_blocks(m, ceil_div(f_, bn) * bn, k, bm, bn, bk)
        v3 &= gw.evaluate(b_pad).latency_s == r.latency_s
        rows.append((f_, b, r.waves, r.latency_s))
        checks += 1
    dt_us = (time.time() - t0) * 1e6 / checks
    if verbose:
        for f_, b, w, lat in rows[::8]:
            print(f"  F={f_:>5} B={b:>5} W={w:>5} L={lat * 1e6:8.2f}us")
        print(f"  verification1={v1} verification2={v2} verification3={v3}")
    csv_rows.append(("wave_verification_fig5", f"{dt_us:.1f}",
                     f"v1={v1};v2={v2};v3={v3}"))
    assert v1 and v2 and v3
    return rows
