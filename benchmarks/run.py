# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import (
        nas_scaleup, platform_generality, pruning_opt, roofline_report,
        staircase, wave_verification,
    )

    csv_rows = []
    print("== staircase (paper Fig. 1/3) ==")
    staircase.run(csv_rows)
    print("== wave verification (paper Fig. 5) ==")
    wave_verification.run(csv_rows)
    print("== pruning optimization (paper Table 2) ==")
    pruning_opt.run(csv_rows)
    print("== NAS scale-up (paper Table 3) ==")
    nas_scaleup.run(csv_rows)
    print("== platform generality (paper Tables 4/5) ==")
    platform_generality.run(csv_rows)
    print("== roofline table (EXPERIMENTS.md section Roofline) ==")
    roofline_report.run(csv_rows)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
