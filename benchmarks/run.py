# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# ``--json PATH`` additionally writes the rows as machine-readable JSON.
# ``--check`` reruns only the optimizer-scale benchmark and exits nonzero
# when any phase speedup regresses >30% versus the committed
# BENCH_tail_optimizer.json (the perf regression gate for the table-driven
# engine; see ROADMAP "Quick tier").
import argparse
import json
import os
import sys
import tempfile

# Fresh speedups may be at most this fraction of the committed value
# before --check fails (speedup ratios are far more stable than absolute
# wall times on shared machines, but still leave 30% slack).  When
# regenerating BENCH_tail_optimizer.json, commit the MINIMUM speedup
# observed over several repeats — a lucky single-run snapshot makes the
# floor flaky for everyone after you.
CHECK_TOLERANCE = 0.7


def run_check(root: str) -> int:
    """Rerun optimizer_scale; compare per-phase speedups to the committed
    BENCH_tail_optimizer.json.  Returns a process exit code."""
    from benchmarks import optimizer_scale

    committed_path = os.path.join(root, "BENCH_tail_optimizer.json")
    with open(committed_path) as f:
        committed = json.load(f)

    # Never clobber the committed trajectory file during a check run.
    with tempfile.TemporaryDirectory() as d:
        fresh = optimizer_scale.run([], verbose=True,
                                    out_path=os.path.join(d, "fresh.json"))

    failures = []
    for phase, entry in committed.get("phases", {}).items():
        for key in sorted(entry):
            if not key.endswith("speedup"):
                continue
            want = entry[key]
            got = fresh.get("phases", {}).get(phase, {}).get(key)
            if want is None or got is None:
                continue
            label = phase if key == "speedup" else f"{phase}:{key}"
            floor = want * CHECK_TOLERANCE
            status = "ok" if got >= floor else "REGRESSED"
            print(f"  check {label:>22}: committed {want:8.1f}x  "
                  f"fresh {got:8.1f}x  floor {floor:6.1f}x  [{status}]")
            if got < floor:
                failures.append(label)
    if failures:
        print(f"--check FAILED: speedup regressed >30% in: "
              f"{', '.join(failures)}")
        return 1
    print("--check passed: no phase regressed >30%")
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the result rows as JSON to PATH")
    ap.add_argument("--check", action="store_true",
                    help="rerun optimizer_scale and fail if any phase "
                         "speedup regressed >30% vs the committed "
                         "BENCH_tail_optimizer.json")
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)

    if args.check:
        sys.exit(run_check(root))

    from benchmarks import (
        nas_scaleup, optimizer_scale, platform_generality, pruning_opt,
        roofline_report, staircase, wave_verification,
    )

    csv_rows = []
    print("== staircase (paper Fig. 1/3) ==")
    staircase.run(csv_rows)
    print("== wave verification (paper Fig. 5) ==")
    wave_verification.run(csv_rows)
    print("== pruning optimization (paper Table 2) ==")
    pruning_opt.run(csv_rows)
    print("== NAS scale-up (paper Table 3) ==")
    nas_scaleup.run(csv_rows)
    print("== platform generality (paper Tables 4/5) ==")
    platform_generality.run(csv_rows)
    print("== optimizer scaling (table-driven vs scalar Algorithm 2) ==")
    optimizer_scale.run(csv_rows)
    print("== roofline table (EXPERIMENTS.md section Roofline) ==")
    roofline_report.run(csv_rows)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us},{derived}")

    if args.json:
        rows = [{"name": n, "us_per_call": float(us), "derived": d}
                for n, us, d in csv_rows]
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
