# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# ``--json PATH`` additionally writes the rows as machine-readable JSON.
import argparse
import json
import os
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the result rows as JSON to PATH")
    args = ap.parse_args(argv)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(root, "src"))
    sys.path.insert(0, root)
    from benchmarks import (
        nas_scaleup, optimizer_scale, platform_generality, pruning_opt,
        roofline_report, staircase, wave_verification,
    )

    csv_rows = []
    print("== staircase (paper Fig. 1/3) ==")
    staircase.run(csv_rows)
    print("== wave verification (paper Fig. 5) ==")
    wave_verification.run(csv_rows)
    print("== pruning optimization (paper Table 2) ==")
    pruning_opt.run(csv_rows)
    print("== NAS scale-up (paper Table 3) ==")
    nas_scaleup.run(csv_rows)
    print("== platform generality (paper Tables 4/5) ==")
    platform_generality.run(csv_rows)
    print("== optimizer scaling (table-driven vs scalar Algorithm 2) ==")
    optimizer_scale.run(csv_rows)
    print("== roofline table (EXPERIMENTS.md section Roofline) ==")
    roofline_report.run(csv_rows)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us},{derived}")

    if args.json:
        rows = [{"name": n, "us_per_call": float(us), "derived": d}
                for n, us, d in csv_rows]
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
