"""Paper Table 3: accuracy-oriented optimization — grow capacity for free.

For each assigned arch (standing in for the EfficientNet series), run the
accuracy-oriented Algorithm 2 over its width-tunable dims (d_ff, and the
head count where it is TP-ragged) on the v5e TP=16 quanta: parameters
gained at identical modeled latency (the paper's +3.97% accuracy at +0.1ms
move, here reported as capacity gain at iso-latency).
"""

from __future__ import annotations

import time

from repro.configs import get_config, list_archs
from repro.core import (
    LayerShape, TPU_V5E, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates,
)

HW = TPU_V5E


def arch_tunables(cfg, tokens=8192, tp=16):
    tls = []
    d_ff = cfg.moe_d_ff if (cfg.moe and cfg.moe_d_ff) else cfg.d_ff
    shard = 1 if cfg.moe else (tp if d_ff % tp == 0 else 1)
    ffn = LayerShape("d_ff", tokens=tokens, d_in=cfg.d_model, width=d_ff,
                     shard_out=shard)
    tls.append(TunableLayer(
        layer=ffn,
        candidates=analytic_candidates(HW, ffn,
                                       max_width=int(d_ff * 1.5)),
        params_per_unit=(3 if cfg.mlp_gated else 2) * cfg.d_model
        * (cfg.n_experts if cfg.moe else 1) * cfg.n_layers))
    # attention width (heads*head_dim): ragged head counts leave tail
    attn_w = cfg.n_heads * cfg.head_dim
    shard_a = tp if cfg.n_heads % tp == 0 else 1
    att = LayerShape("attn_width", tokens=tokens, d_in=cfg.d_model,
                     width=attn_w, shard_out=shard_a)
    tls.append(TunableLayer(
        layer=att,
        candidates=analytic_candidates(HW, att,
                                       max_width=int(attn_w * 1.5)),
        params_per_unit=2 * cfg.d_model * cfg.n_layers))
    return tls


def run(csv_rows: list, verbose: bool = True):
    t0 = time.time()
    model = WaveQuantizationModel(HW)
    opt = TailEffectOptimizer(model)
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        tls = arch_tunables(cfg)
        res = opt.optimize_accuracy(tls, latency_slack=0.0)
        gain_frac = res.param_gain / max(res.params_old, 1)
        rows.append((arch, res.old_widths, res.new_widths, gain_frac,
                     res.latency_new_s <= res.latency_old_s + 1e-15))
        if verbose:
            moved = {k: (res.old_widths[k], v)
                     for k, v in res.new_widths.items()
                     if v != res.old_widths[k]}
            print(f"  {arch:>28}: +{gain_frac*100:5.2f}% params free "
                  f"{moved if moved else '(already wave-aligned)'}")
    dt_us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    best = max(rows, key=lambda r: r[3])
    # Table-driven engine: one evaluate_batch per tunable layer per call.
    csv_rows.append(("nas_scaleup_table3", f"{dt_us:.1f}",
                     f"best_free_gain={best[0]}:+{best[3]*100:.2f}%;"
                     f"batched_evals={model.eval_calls}"
                     f"({model.eval_points}pts)"))
    return rows
