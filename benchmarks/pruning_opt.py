"""Paper Table 2: tail-aware optimization on top of pruning baselines.

VGG-style convnet on a synthetic CIFAR-class task.  Pipeline per method:
  1. train a base model;
  2. HRank (feature-map rank) / SOFT (L2) pruning to a FLOPs target with
     *continuous* per-layer widths (the baselines' own behaviour);
  3. ours: the same criteria but widths snapped by Algorithm 2 to the
     wave-aligned candidates (section 4.4 "Advancing Filter Pruning");
  4. finetune both, report params / FLOPs / modeled latency / throughput /
     accuracy — the Table 2 columns.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    LayerShape, TPU_LITE, TailEffectOptimizer, TunableLayer,
    WaveQuantizationModel, analytic_candidates, pruning,
)
from repro.models import convnet as cn

HW = TPU_LITE      # embedded-class chip: quanta bite at small widths
BATCH = 32
IMAGE = 16


def train(params, steps: int, seed: int = 0, lr: float = 3e-3):
    @jax.jit
    def step(params, batch):
        (loss, acc), g = jax.value_and_grad(cn.convnet_loss,
                                            has_aux=True)(params, batch)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, loss, acc

    acc = 0.0
    for s in range(steps):
        batch = cn.synthetic_cifar(s, BATCH, IMAGE)
        params, loss, acc = step(params, batch)
    return params, float(acc)


def eval_acc(params, steps: int = 8, seed: int = 10_000):
    accs = []
    for s in range(steps):
        batch = cn.synthetic_cifar(seed + s, BATCH, IMAGE)
        _, acc = cn.convnet_loss(params, batch)
        accs.append(float(acc))
    return float(np.mean(accs))


MODEL = WaveQuantizationModel(HW)


def model_latency(widths) -> float:
    shapes = cn.conv_layer_shapes(widths, batch=1, image=IMAGE)
    return sum(float(MODEL.evaluate_batch(s, [s.width]).latency_s[0])
               for s in shapes)


def tunables(widths, max_scale=1.5):
    out = []
    shapes = cn.conv_layer_shapes(widths, batch=1, image=IMAGE)
    for s in shapes:
        cands = analytic_candidates(HW, s,
                                    max_width=int(s.width * max_scale),
                                    min_width=8)
        out.append(TunableLayer(layer=s, candidates=cands,
                                params_per_unit=s.d_in))
    return out


def run(csv_rows: list, verbose: bool = True, train_steps: int = 150,
        finetune_steps: int = 80):
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    base_widths = cn.DEFAULT_WIDTHS
    params, _ = train(cn.init_convnet(key, base_widths, image=IMAGE), train_steps)
    base_acc = eval_acc(params)

    # probe batch for HRank activations
    probe = cn.synthetic_cifar(77, 32, IMAGE)
    _, acts = cn.forward_convnet(params, probe["images"],
                                 collect_acts=True)

    names = cn.conv_names(base_widths)
    results = []
    for method in ("HRank", "SOFT"):
        if method == "HRank":
            score_fn = lambda n: pruning.feature_map_rank_scores(acts[n])
        else:
            score_fn = lambda n: pruning.l2_filter_scores(
                params[n]["kernel"])

        # --- baseline: continuous uniform-ratio targets -------------------
        targets = pruning.uniform_flops_plan(
            dict(zip(names, base_widths)), 0.66)
        plan_b = pruning.build_plan(score_fn, targets)
        pruned_b = cn.prune_convnet(params, plan_b.indices)
        pruned_b, _ = train(pruned_b, finetune_steps, lr=1e-3)
        wb = [plan_b.widths[n] for n in names]

        # --- ours: Algorithm 2 over the baseline's widths (table-driven) ---
        opt = TailEffectOptimizer(MODEL)
        tls = tunables(wb)
        total_p = sum(tl.params(tl.layer.width) for tl in tls)
        res = opt.optimize_latency(tls, tau=0.25 * total_p, delta=0.92)
        w_ours = {n: res.new_widths[f"conv{i}"]
                  for i, n in enumerate(names)}
        # honour max available filters
        w_ours = {n: min(w, dict(zip(names, base_widths))[n])
                  for n, w in w_ours.items()}
        plan_o = pruning.build_plan(score_fn, w_ours)
        pruned_o = cn.prune_convnet(params, plan_o.indices)
        pruned_o, _ = train(pruned_o, finetune_steps, lr=1e-3)
        wo = [plan_o.widths[n] for n in names]

        for tag, w_, p_ in ((method, wb, pruned_b),
                            (f"{method}+Ours", wo, pruned_o)):
            n_par = cn.count_conv_params(w_, image=IMAGE)
            fl = cn.count_conv_flops(w_, image=IMAGE)
            lat = model_latency(w_)
            results.append({
                "method": tag, "widths": w_, "params": n_par,
                "flops": fl, "latency_us": lat * 1e6,
                "tflops": fl / lat / 1e12,
                "acc": eval_acc(p_),
            })

    if verbose:
        print(f"  base widths={list(base_widths)} acc={base_acc:.3f}")
        for r in results:
            print(f"  {r['method']:>12}: widths={r['widths']} "
                  f"params={r['params']/1e3:7.1f}k "
                  f"FLOPs={r['flops']/1e6:7.1f}M "
                  f"L={r['latency_us']:7.2f}us "
                  f"T={r['tflops']:6.3f}TF/s acc={r['acc']:.3f}")
    # latency reduction of ours vs each baseline
    reds = []
    for m in ("HRank", "SOFT"):
        lb = next(r for r in results if r["method"] == m)["latency_us"]
        lo = next(r for r in results
                  if r["method"] == f"{m}+Ours")["latency_us"]
        reds.append((m, 1 - lo / lb))
    dt_us = (time.time() - t0) * 1e6
    csv_rows.append(("pruning_table2", f"{dt_us:.0f}",
                     ";".join(f"{m}:-{r*100:.1f}%lat" for m, r in reds)))
    return results
