"""Section Roofline: aggregate the dry-run JSONs into the per-(arch x shape
x mesh) three-term roofline table used by EXPERIMENTS.md.  Also surfaces
the optimizer perf trajectory (BENCH_tail_optimizer.json) when present, so
one report covers both the model-quality and engine-speed axes."""

from __future__ import annotations

import glob
import json
import os
import time


def load(results_dir: str = "results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_row(r) -> str:
    mem = r.get("memory_per_device_adjusted") \
        or r.get("memory_per_device_bytes") or 0
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_flops_fraction']:.3f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {mem / 2**30:.1f} | {'Y' if r.get('hbm_ok') else 'N'} |")


HEADER = ("| arch | shape | mesh | compute_s | memory_s | collective_s "
          "| dominant | useful_frac | roofline_frac | HBM GiB | fits |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def load_perf_trajectory(path: str = "BENCH_tail_optimizer.json"):
    """The table-driven-optimizer perf record, if the benchmark has run."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run(csv_rows: list, verbose: bool = True,
        results_dir: str = "results/dryrun"):
    t0 = time.time()
    rows = load(results_dir)
    perf = load_perf_trajectory()
    if verbose and perf:
        lat = perf["phases"]["optimize_latency"]
        print(f"  optimizer engine: {lat['speedup']:.1f}x vs scalar "
              f"({lat['batched_wall_s']*1e3:.2f}ms on "
              f"{perf['scenario']['n_layers']}x"
              f"{perf['scenario']['n_candidates']})")
    if verbose:
        if not rows:
            print("  (no dry-run results found — run "
                  "`python -m repro.launch.dryrun` first)")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                             r["mesh"])):
            if r["mesh"] == "single":
                print(f"  {r['arch']:>28} {r['shape']:>12} "
                      f"dom={r['dominant']:<10} "
                      f"rf={r['roofline_fraction']:.3f} "
                      f"useful={r['useful_flops_fraction']:.3f}")
    n = len(rows)
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    dt_us = (time.time() - t0) * 1e6
    csv_rows.append(("roofline_table", f"{dt_us:.0f}",
                     f"cells={n};" + ";".join(f"{k}={v}"
                                              for k, v in dom.items())))
    return rows


def markdown_table(results_dir: str = "results/dryrun") -> str:
    rows = load(results_dir)
    lines = [HEADER]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(fmt_row(r))
    return "\n".join(lines)
